"""End-to-end training driver: train a reduced model for a few hundred steps
with checkpoint/restart fault tolerance, and verify the loss goes down.

Run:  PYTHONPATH=src python examples/train_losscurve.py
(Full-size variant on a real pod: python -m repro.launch.train --arch qwen2.5-3b
 --steps 500 --batch 256 --seq 4096.)
"""
import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "qwen2.5-3b", "--smoke",
       "--steps", "200", "--batch", "8", "--seq", "128",
       "--ckpt-dir", "results/ckpt_example", "--ckpt-every", "50",
       "--log-every", "20"]
print("launching:", " ".join(cmd))
sys.exit(subprocess.run(cmd, env={"PYTHONPATH": "src", **__import__('os').environ}).returncode)
