"""Quickstart: the paper's experiment in five lines, then the LLM substrate.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

# ---- 1. the paper: application-data auto-scaling on a match trace ---------------
from repro.core.autoscaler import AppDataPolicy, CompositePolicy, LoadPolicy, ThresholdPolicy
from repro.core.simulator import SimConfig, generate_trace, run_scenario
from repro.core.simulator.distributions import ServiceModel

trace = generate_trace("uruguay", seed=0)
sm = ServiceModel()
for policy in [
    ThresholdPolicy(0.6),
    LoadPolicy(sm, quantile=0.99999),
    CompositePolicy([LoadPolicy(sm, quantile=0.99999), AppDataPolicy(extra_units=5)]),
]:
    res = run_scenario(trace, policy, SimConfig())
    print(f"{res.policy:35s} violations {100 * res.violation_rate:6.2f}%  "
          f"cost {res.cpu_hours:6.2f} CPU-h")

# ---- 2. the substrate: train a small LM for a few steps -------------------------
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.training import make_train_step
from repro.data import DataConfig, TokenStream

cfg = get_smoke_config("smollm-135m")
model = build_model(cfg)
params = model.init_params(jax.random.key(0))
opt = adamw_init(params)
step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=20)),
               donate_argnums=(0, 1))
data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
for i in range(20):
    params, opt, m = step(params, opt, data.batch(i))
    if i % 5 == 0:
        print(f"step {i:3d}  loss {float(m['loss']):.4f}")

# ---- 3. serve it with continuous batching ----------------------------------------
from repro.serving import Request, ServeConfig, ServingEngine

eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_len=96))
rng = np.random.default_rng(0)
for i in range(6):
    eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                       max_new_tokens=4))
eng.run_until_drained()
print(f"served {len(eng.completed)} requests in {eng.step_count} engine steps")
