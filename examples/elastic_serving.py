"""End-to-end driver: SLA-aware elastic LLM serving with application-data
auto-scaling (the paper's technique as a first-class feature of the fleet),
running on the unified scaling control plane (repro.core.scaling; DESIGN.md).

Phase A (mechanism, real JAX): scale a serving replica set out and in by
re-meshing + re-sharding live parameters, measuring re-provisioning cost.

Phase B (policy, fleet scale): threshold / target-tracking / load /
load+appdata policies managing a 64-replica fleet against a bursty request
stream carrying two named output-signal channels (`output_score`,
`breaking_news`) that lead the bursts -- reports SLA violations and
chip-hours per policy, including a multi-channel appdata scenario pinned to
the `breaking_news` channel.

Phase C (economics, typed capacity): the same fleet priced over two replica
pools -- guaranteed on-demand capacity plus a 3x-cheaper preemptible spot
pool with a seeded revocation process -- under a cheapest-first router and a
per-class SLA (interactive requests get a tighter deadline than batch).  The
run report prices the bill per pool and breaks violations out per class.

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.elastic import (
    ClusterConfig,
    measure_provision_delay,
    provisioned_cluster_config,
)
from repro.models import build_model

# ---------- Phase A: real re-mesh / re-shard, measured -----------------------------
print("=== Phase A: elastic re-mesh (8 host devices), measured ===")
cfg = get_smoke_config("smollm-360m")
model = build_model(cfg)
params = model.init_params(jax.random.key(0))
devs = jax.devices()

delays = []
for n, tp in [(2, 2), (4, 2), (8, 2), (4, 4)]:
    dt, mesh, params = measure_provision_delay(
        model, params, devices=devs[:n], model_parallel=tp)
    delays.append(dt)
    dp = n // tp
    print(f"  re-meshed to dp={dp} tp={tp} ({n} devices) in {dt:.2f}s"
          f"  (provisioning-delay analogue)")

measured = float(np.max(delays))     # worst transition = conservative delay
print(f"  measured provision delay: {measured:.2f}s "
      f"(feeds ClusterConfig.provision_delay_s)")

# ---------- Phase B: policy-driven fleet -------------------------------------------
# The fleet simulation now pays the MEASURED re-provisioning cost from Phase A
# instead of the assumed default -- application-measured data all the way down.
print("\n=== Phase B: fleet under the three policies (measured delay) ===")
import sys
sys.path.insert(0, ".")
from benchmarks.elastic_serving import run as elastic_bench
measured_cfg = provisioned_cluster_config(ClusterConfig(), measured)
elastic_bench(quick=True, cfg=measured_cfg)

# ---------- Phase C: typed capacity -- spot pools, per-class SLAs ------------------
# The paper's economics made explicit: the same burst is served once on pure
# on-demand replicas and once buying cheap preemptible capacity first (the
# controller releases the expensive pool first on the way down, and the seeded
# revocation process yanks spot replicas mid-burst).  Interactive requests
# carry a tighter deadline than batch ones, and the RunReport prices the bill
# per pool and reports violations per class.
print("\n=== Phase C: typed capacity (on-demand + revocable spot, per-class SLA) ===")
import dataclasses
from benchmarks.elastic_serving import _workload
from repro.core.autoscaler import CheapestFirstRouter, ThresholdPolicy
from repro.core.elastic import ElasticCluster
from repro.core.scaling import Sla, UnitPool

def _classed_workload():
    reqs = _workload(n=4000)
    for r in reqs:                 # short answers are the interactive class
        r.request_class = "interactive" if r.decode_len <= 80 else "batch"
    return reqs

sla = Sla(default_s=measured_cfg.sla_s,
          per_class={"interactive": measured_cfg.sla_s / 2})

delay = measured_cfg.provision_delay_s
pool_sets = {
    "on-demand only": (UnitPool("on-demand", provision_delay_s=delay,
                                cost_rate=3.0, min_units=1),),
    "on-demand + spot": (
        UnitPool("on-demand", provision_delay_s=delay, cost_rate=3.0,
                 min_units=1),
        UnitPool("spot", provision_delay_s=delay, cost_rate=1.0, max_units=16,
                 preemptible=True, revoke_rate=1.0 / 120.0, revoke_seed=11),
    ),
}
for name, pools in pool_sets.items():
    cfg_c = dataclasses.replace(measured_cfg, pools=pools, sla=sla)
    pol = CheapestFirstRouter(ThresholdPolicy(0.7))
    rep = ElasticCluster(cfg_c, pol, _classed_workload()).run()
    worst, worst_rate = rep.worst_class
    print(f"  {name:18s} cost {rep.cost:6.2f}  "
          f"viol {100 * rep.violation_rate:5.2f}%  "
          f"worst {worst} {100 * worst_rate:.2f}%  "
          f"revoked {rep.n_revocations}")
print("  (cheapest-first buys spot, revocations land mid-burst, the "
      "controller re-buys;\n   the mixed fleet undercuts the pure "
      "on-demand bill)")

# ---------- Phase D: convergence under faults --------------------------------------
# Desired-state reconciliation (repro.core.convergence): the same fleet with
# seeded unit loss injected mid-burst, run imperatively (policy deltas only)
# and in convergence mode (the converger relaunches every lost replica on the
# next step and audits every observation -> plan -> step -> outcome).  This
# phase keeps the fault drill's 45 s provisioning delay rather than Phase A's
# measured re-mesh time: with near-instant provisioning the utilization
# detour barely costs anything, and it is exactly when restores are expensive
# that reconciling on the very next step pays.
print("\n=== Phase D: convergence plane heals injected unit loss ===")
from repro.core.convergence import replay
from benchmarks.convergence_faults import CONVERGE, LOSS, POOL, _RestartFloor

for mode, convergence in (("imperative", False), ("convergence", True)):
    cfg_d = ClusterConfig(pools=POOL, faults=LOSS, convergence=convergence,
                          converge=CONVERGE if convergence else None)
    cluster = ElasticCluster(cfg_d, _RestartFloor(ThresholdPolicy(0.7)),
                             _workload(n=3000))
    rep = cluster.run()
    ctrl = cluster.controller
    lost = sum(m.lost for m in ctrl.plan.meters().values())
    line = (f"  {mode:12s} viol {100 * rep.violation_rate:5.2f}%  "
            f"replica-s {rep.unit_seconds:6.0f}  units lost {lost}")
    if convergence:
        final = {p: {"live": s.units, "pending": s.pending}
                 for p, s in ctrl.plan.stats().items()}
        assert replay(ctrl.audit.records) == final
        line += f"  audit records {len(ctrl.audit.records)} (replay == fleet)"
    print(line)
print("  (the converger restores the desired fleet after every loss; the "
      "imperative\n   baseline only limps back via utilization, one adapt "
      "period + delay later)")
