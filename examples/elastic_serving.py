"""End-to-end driver: SLA-aware elastic LLM serving with application-data
auto-scaling (the paper's technique as a first-class feature of the fleet),
running on the unified scaling control plane (repro.core.scaling; DESIGN.md).

Phase A (mechanism, real JAX): scale a serving replica set out and in by
re-meshing + re-sharding live parameters, measuring re-provisioning cost.

Phase B (policy, fleet scale): threshold / target-tracking / load /
load+appdata policies managing a 64-replica fleet against a bursty request
stream carrying two named output-signal channels (`output_score`,
`breaking_news`) that lead the bursts -- reports SLA violations and
chip-hours per policy, including a multi-channel appdata scenario pinned to
the `breaking_news` channel.

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.elastic import (
    ClusterConfig,
    measure_provision_delay,
    provisioned_cluster_config,
)
from repro.models import build_model

# ---------- Phase A: real re-mesh / re-shard, measured -----------------------------
print("=== Phase A: elastic re-mesh (8 host devices), measured ===")
cfg = get_smoke_config("smollm-360m")
model = build_model(cfg)
params = model.init_params(jax.random.key(0))
devs = jax.devices()

delays = []
for n, tp in [(2, 2), (4, 2), (8, 2), (4, 4)]:
    dt, mesh, params = measure_provision_delay(
        model, params, devices=devs[:n], model_parallel=tp)
    delays.append(dt)
    dp = n // tp
    print(f"  re-meshed to dp={dp} tp={tp} ({n} devices) in {dt:.2f}s"
          f"  (provisioning-delay analogue)")

measured = float(np.max(delays))     # worst transition = conservative delay
print(f"  measured provision delay: {measured:.2f}s "
      f"(feeds ClusterConfig.provision_delay_s)")

# ---------- Phase B: policy-driven fleet -------------------------------------------
# The fleet simulation now pays the MEASURED re-provisioning cost from Phase A
# instead of the assumed default -- application-measured data all the way down.
print("\n=== Phase B: fleet under the three policies (measured delay) ===")
import sys
sys.path.insert(0, ".")
from benchmarks.elastic_serving import run as elastic_bench
elastic_bench(quick=True,
              cfg=provisioned_cluster_config(ClusterConfig(), measured))
