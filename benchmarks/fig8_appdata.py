"""Fig 8: the appdata algorithm on Brazil vs Spain -- extra CPUs 1..10 allocated
on detected sentiment peaks, on top of load(q=99.999%)."""
from __future__ import annotations

from benchmarks.common import Rows, banner
from repro.core.autoscaler import AppDataPolicy, CompositePolicy, LoadPolicy
from repro.core.simulator import SimConfig, generate_trace, run_scenario
from repro.core.simulator.distributions import ServiceModel


def run(quick: bool = False) -> Rows:
    banner("Fig 8: appdata extra-CPU sweep (Spain)")
    rows = Rows("fig8")
    sm = ServiceModel()
    cfg = SimConfig()
    seeds = [0] if quick else [0, 1]
    extras = [1, 5, 10] if quick else list(range(1, 11))
    traces = [generate_trace("spain", seed=s) for s in seeds]

    v = c = 0.0
    for tr in traces:
        r = run_scenario(tr, LoadPolicy(sm, quantile=0.99999), cfg)
        v += 100.0 * r.violation_rate / len(traces)
        c += r.cpu_hours / len(traces)
    rows.add("load_alone.viol_pct", v, "paper 1.67")
    rows.add("load_alone.cpu_hours", c, "paper 20.97")
    base_v = v

    for extra in extras:
        v = c = 0.0
        for tr in traces:
            pol = CompositePolicy([
                LoadPolicy(sm, quantile=0.99999),
                AppDataPolicy(extra_units=extra),
            ])
            r = run_scenario(tr, pol, cfg)
            v += 100.0 * r.violation_rate / len(traces)
            c += r.cpu_hours / len(traces)
        ref = "paper 1.23, 21.27" if extra == 1 else ("paper 0.12, 34.78" if extra == 10 else "")
        rows.add(f"appdata+{extra}.viol_pct", v, ref)
        rows.add(f"appdata+{extra}.cpu_hours", c)
        if extra == extras[-1]:
            rows.add("improvement_vs_load_pct",
                     100.0 * (base_v - v) / max(base_v, 1e-9), "paper 92.81")
    return rows


if __name__ == "__main__":
    run()
