"""Kernel micro-benchmarks: interpret-mode correctness + jnp-path timing on CPU
(the TPU numbers come from the dry-run roofline, not from wall clock here)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, banner


def run(quick: bool = False) -> Rows:
    banner("Kernels: interpret-mode validation + oracle timing")
    rows = Rows("kernels")

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, Hq, Hkv, D = 1, 256 if quick else 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = flash_attention(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), None).transpose(0, 2, 1, 3)
    rows.add("flash_attention.max_err", float(jnp.abs(out - ref).max()))
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, None))
    qT = q.transpose(0, 2, 1, 3); kT = k.transpose(0, 2, 1, 3); vT = v.transpose(0, 2, 1, 3)
    f(qT, kT, vT).block_until_ready()
    t0 = time.perf_counter(); f(qT, kT, vT).block_until_ready()
    rows.add("attention_ref.us_per_call", (time.perf_counter() - t0) * 1e6)

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    S2 = 512 if quick else 2048
    kc = jax.random.normal(ks[1], (B, S2, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S2, Hkv, D))
    q1 = jax.random.normal(ks[0], (B, 1, Hq, D))
    out = decode_attention(q1, kc, vc, S2 // 2, block_k=256)
    ref = decode_attention_ref(q1[:, 0], kc, vc, S2 // 2)[:, None]
    rows.add("decode_attention.max_err", float(jnp.abs(out - ref).max()))

    from repro.kernels.ssd.ops import ssd_intra
    from repro.kernels.ssd.ref import ssd_intra_ref
    b, nc, qq, h, p, n = 1, 2, 64, 4, 32, 16
    ks4 = jax.random.split(jax.random.PRNGKey(1), 4)
    xb = jax.random.normal(ks4[0], (b, nc, qq, h, p))
    acs = -jnp.abs(jax.random.normal(ks4[1], (b, nc, qq, h))).cumsum(2) * 0.1
    Bh = jax.random.normal(ks4[2], (b, nc, qq, h, n))
    Ch = jax.random.normal(ks4[3], (b, nc, qq, h, n))
    out = ssd_intra(xb, acs, Bh, Ch)
    ref = jnp.stack([ssd_intra_ref(xb[:, i], acs[:, i], Bh[:, i], Ch[:, i])
                     for i in range(nc)], 1)
    rows.add("ssd_intra.max_err", float(jnp.abs(out - ref).max()))
    return rows


if __name__ == "__main__":
    run()
