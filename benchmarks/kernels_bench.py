"""Kernel micro-benchmarks: interpret-mode correctness + jnp-path timing on CPU
(the TPU numbers come from the dry-run roofline, not from wall clock here),
plus the paged-decode page-size / block-k autotune sweep whose JSON artifact
(``benchmarks/artifacts/kernels_paged_sweep.json``) seeds the defaults table
in ``repro.kernels.decode_attention.autotune``."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, banner

SWEEP_ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                              "kernels_paged_sweep.json")


def run(quick: bool = False) -> Rows:
    banner("Kernels: interpret-mode validation + oracle timing")
    rows = Rows("kernels")

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, Hq, Hkv, D = 1, 256 if quick else 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = flash_attention(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), None).transpose(0, 2, 1, 3)
    rows.add("flash_attention.max_err", float(jnp.abs(out - ref).max()))
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, None))
    qT = q.transpose(0, 2, 1, 3); kT = k.transpose(0, 2, 1, 3); vT = v.transpose(0, 2, 1, 3)
    f(qT, kT, vT).block_until_ready()
    t0 = time.perf_counter(); f(qT, kT, vT).block_until_ready()
    rows.add("attention_ref.us_per_call", (time.perf_counter() - t0) * 1e6)

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    S2 = 512 if quick else 2048
    kc = jax.random.normal(ks[1], (B, S2, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S2, Hkv, D))
    q1 = jax.random.normal(ks[0], (B, 1, Hq, D))
    out = decode_attention(q1, kc, vc, S2 // 2, block_k=256)
    ref = decode_attention_ref(q1[:, 0], kc, vc, S2 // 2)[:, None]
    rows.add("decode_attention.max_err", float(jnp.abs(out - ref).max()))

    # paged decode: block-table kernel correctness + the autotune data source
    from repro.kernels.decode_attention.ops import decode_attention_paged
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    ps_, npg = 16, 4
    P = B * npg + 2
    kp = jax.random.normal(ks[1], (P, ps_, Hkv, D))
    vp = jax.random.normal(ks[2], (P, ps_, Hkv, D))
    perm = np.random.default_rng(0).permutation(np.arange(1, P))
    tbl = jnp.asarray(perm[:B * npg].reshape(B, npg).astype(np.int32))
    lens = jnp.full((B,), npg * ps_ - 3, jnp.int32)
    out = decode_attention_paged(q1, kp, vp, tbl, lens)
    ref = paged_decode_attention_ref(q1[:, 0], kp, vp, tbl, lens)[:, None]
    rows.add("paged_decode_attention.max_err", float(jnp.abs(out - ref).max()))

    from repro.kernels.decode_attention import autotune
    reps = 3 if quick else 10
    page_rows = autotune.sweep_page_size(
        (8, 16, 32) if quick else (8, 16, 32, 64),
        total_tokens=128 if quick else 256, reps=reps)
    block_rows = autotune.sweep_block_k(
        (128, 256) if quick else (128, 256, 512, 1024),
        S=256 if quick else 1024, reps=reps)
    for r in page_rows:
        rows.add(f"paged_sweep.ps{r['page_size']}.us_per_step", r["us_per_step"])
    for r in block_rows:
        rows.add(f"dense_sweep.bk{r['block_k']}.us_per_step", r["us_per_step"])
    picked = autotune.pick_defaults(page_rows, block_rows)
    rows.add("autotune.page_size", float(picked["page_size"]))
    rows.add("autotune.block_k", float(picked["block_k"]))
    os.makedirs(os.path.dirname(SWEEP_ARTIFACT), exist_ok=True)
    with open(SWEEP_ARTIFACT, "w") as f:
        json.dump({"page_size_sweep": page_rows, "block_k_sweep": block_rows,
                   "picked": picked, "shipped_defaults": autotune.DEFAULTS},
                  f, indent=2)
    print(f"[artifact] {SWEEP_ARTIFACT}")

    from repro.kernels.ssd.ops import ssd_intra
    from repro.kernels.ssd.ref import ssd_intra_ref
    b, nc, qq, h, p, n = 1, 2, 64, 4, 32, 16
    ks4 = jax.random.split(jax.random.PRNGKey(1), 4)
    xb = jax.random.normal(ks4[0], (b, nc, qq, h, p))
    acs = -jnp.abs(jax.random.normal(ks4[1], (b, nc, qq, h))).cumsum(2) * 0.1
    Bh = jax.random.normal(ks4[2], (b, nc, qq, h, n))
    Ch = jax.random.normal(ks4[3], (b, nc, qq, h, n))
    out = ssd_intra(xb, acs, Bh, Ch)
    ref = jnp.stack([ssd_intra_ref(xb[:, i], acs[:, i], Bh[:, i], Ch[:, i])
                     for i in range(nc)], 1)
    rows.add("ssd_intra.max_err", float(jnp.abs(out - ref).max()))
    return rows


if __name__ == "__main__":
    run()
