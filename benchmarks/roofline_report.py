"""Aggregate results/dryrun.jsonl into the EXPERIMENTS.md roofline tables.

Per (arch x shape) on the single-pod mesh:
  compute / memory / collective terms (s), dominant bottleneck,
  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per device,
  usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Caveat recorded in EXPERIMENTS.md: HLO 'bytes accessed' from the CPU-compiled
module over-counts HBM traffic (no TPU fusion/layout pipeline), so the memory
term is an upper bound; the compute term (FLOPs) matches analytic 6ND closely.
"""
from __future__ import annotations

import json
import sys

from repro.configs import ARCHS, SHAPES, get_config
from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS_BF16


def model_flops_per_device(arch: str, shape: str, n_dev: int, mesh_kind: str) -> float:
    cfg = get_config(arch)
    sp = SHAPES[shape]
    n_active = cfg.active_param_count()
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * n_active * tokens / n_dev
    if sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * n_active * tokens / n_dev
    # decode: one token per sequence
    return 2.0 * n_active * sp.global_batch / n_dev


def _default_path():
    import os
    return ("results/dryrun_v2.jsonl" if os.path.exists("results/dryrun_v2.jsonl")
            else "results/dryrun.jsonl")


def analytic_memory_bytes_per_device(arch: str, shape: str, n_dev: int) -> float:
    """TPU-side HBM-traffic estimate per device per step (lower bound):
    weights read (bf16, sharded) + KV/state cache read+write (decode) +
    activation traffic ~ 2 x weights for train (grad+opt update)."""
    cfg = get_config(arch)
    sp = SHAPES[shape]
    w_bytes = cfg.param_count() * 2 / n_dev
    if sp.kind == "train":
        # weights + grads f32 + adam m,v f32 touched once each, plus saved
        # activations written fwd / read bwd (~4 passes with block remat)
        acts = sp.global_batch * sp.seq_len * cfg.d_model * 2             * max(cfg.n_layers, 1) * 4 / n_dev
        return w_bytes * (1 + 2 + 4 + 4 + 4) + acts
    if sp.kind == "prefill":
        return w_bytes + _cache_bytes(cfg, sp) / n_dev
    return w_bytes + 2.0 * _cache_bytes(cfg, sp) / n_dev   # decode: read+write


def _cache_bytes(cfg, sp) -> float:
    hd = cfg.resolved_head_dim
    per_tok = cfg.kv_cache_dtype == "int8" and (hd + 4) or 2 * hd
    attn_layers = cfg.n_layers if cfg.family not in ("ssm", "hybrid") else (
        0 if not cfg.shared_attn_every else cfg.n_layers // cfg.shared_attn_every)
    kv = 2 * attn_layers * sp.global_batch * sp.seq_len * cfg.n_kv_heads * per_tok
    if cfg.ssm:
        d_in = cfg.ssm.expand * cfg.d_model
        h = d_in // cfg.ssm.head_dim
        kv += cfg.n_layers * sp.global_batch * h * cfg.ssm.head_dim             * cfg.ssm.d_state * 4
    return float(kv)


def load(path=None, mesh="single"):
    path = path or _default_path()
    rows = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("mesh") != mesh:
            continue
        rows[(r["arch"], r["shape"])] = r
    return rows


def report(path=None, mesh="single", out=sys.stdout):
    rows = load(path, mesh)
    w = out.write
    w(f"| arch | shape | t_comp 6ND (s) | t_mem analytic (s) | t_mem HLO (s) | "
      f"t_coll (s) | dominant | 6ND/dev (TF) | HLO/dev (TF) | useful | coll MB/dev |\n")
    w("|---|---|---|---|---|---|---|---|---|---|---|\n")
    for arch in ARCHS:
        for shape in SHAPES:
            r = rows.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                w(f"| {arch} | {shape} | - | - | - | skipped (full attention) "
                  f"| - | - | - | - |\n")
                continue
            t = r["roofline"]
            hlo_f = r["cost"].get("flops", 0.0) or 0.0
            mf = model_flops_per_device(arch, shape, r["devices"], mesh)
            useful = mf / hlo_f if hlo_f else float("nan")
            coll = r["collectives"]["total_bytes"] / r["devices"] / 1e6
            t_c6 = mf / PEAK_FLOPS_BF16
            t_ma = analytic_memory_bytes_per_device(arch, shape, r["devices"]) / HBM_BW
            dom = "compute" if t_c6 >= max(t_ma, t["t_collective_s"]) else (
                "memory" if t_ma >= t["t_collective_s"] else "collective")
            w(f"| {arch} | {shape} | {t_c6:.2e} | {t_ma:.2e} | {t['t_memory_s']:.2e} "
              f"| {t['t_collective_s']:.2e} | {dom} "
              f"| {mf / 1e12:.3f} | {hlo_f / 1e12:.3f} | {useful:.2f} | {coll:.1f} |\n")


def pick_hillclimb_cells(path=None):
    """(worst useful-ratio, most collective-bound, paper-representative)."""
    rows = load(path)
    scored = []
    for (arch, shape), r in rows.items():
        if r["status"] != "ok":
            continue
        hlo_f = r["cost"].get("flops", 0.0) or 0.0
        mf = model_flops_per_device(arch, shape, r["devices"], "single")
        useful = mf / hlo_f if hlo_f else 0.0
        coll_frac = r["roofline"]["t_collective_s"] / max(
            sum(r["roofline"][k] for k in
                ("t_compute_s", "t_memory_s", "t_collective_s")), 1e-30)
        scored.append(((arch, shape), useful, coll_frac))
    worst_useful = min(scored, key=lambda s: s[1])
    most_coll = max(scored, key=lambda s: s[2])
    return worst_useful, most_coll


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    report(mesh=mesh)
    if mesh == "single":
        wu, mc = pick_hillclimb_cells()
        print(f"\nworst-useful cell: {wu[0]} ratio={wu[1]:.3f}")
        print(f"most-collective cell: {mc[0]} frac={mc[2]:.3f}")
