"""Fig 3: sentiment-variation spikes precede tweet bursts by 1-2 minutes, with
some false positives and false negatives."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, banner
from repro.core.signals import burst_lead_report
from repro.core.simulator import MATCHES, generate_trace


def run(quick: bool = False) -> Rows:
    banner("Fig 3: burst early-warning structure")
    rows = Rows("fig3")
    matches = ["spain"] if quick else list(MATCHES)
    seeds = [0] if quick else [0, 1, 2]
    tot_b = tot_d = tot_fp = 0
    leads = []
    for m in matches:
        for s in seeds:
            tr = generate_trace(m, seed=s)
            rep = burst_lead_report(tr)
            tot_b += rep["n_bursts"]
            tot_d += rep["n_detected"]
            tot_fp += rep["n_false_positives"]
            if np.isfinite(rep["mean_lead_s"]):
                leads.append(rep["mean_lead_s"])
    rows.add("bursts_total", tot_b)
    rows.add("bursts_detected", tot_d)
    rows.add("detection_rate", tot_d / max(tot_b, 1),
             "paper: most peaks detected, some FN")
    rows.add("mean_lead_seconds", float(np.mean(leads)),
             "paper: 'a minute or two before'")
    rows.add("false_positives_total", tot_fp, "paper: 'some false positives'")
    return rows


if __name__ == "__main__":
    run()
