"""Beyond-paper integration benchmark: the paper's auto-scaling policies (plus
the redesign's target-tracking rule) driving an elastic LLM-serving fleet
through the shared scaling control plane (replica = unit of elasticity,
roofline-priced request classes, *named* application-output signal channels).

The multi-channel scenario runs on a *flat-score* variant of the workload:
the primary ``output_score`` channel stays flat at ~0.5 while a secondary
``breaking_news`` channel (fraction of breaking-news-shaped answers) still
leads each burst.  An AppDataPolicy watching only the primary channel can
never fire there; one pinned to the ``breaking_news`` channel pre-provisions
-- the capability the redesign adds."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows, banner
from repro.core.autoscaler import (
    AppDataPolicy,
    CompositePolicy,
    LoadPolicy,
    TargetTrackingPolicy,
    ThresholdPolicy,
)
from repro.core.elastic import ClusterConfig, ElasticCluster, ServeRequest
from repro.core.scaling import RunReport


class _ReplicaLoadPolicy(LoadPolicy):
    """LoadPolicy re-based on the cluster's request-class model (seconds, not
    cycles): expectedDelay = n_in_system * quantile_seconds / replicas."""

    def __init__(self, cluster_holder, *, quantile=0.99, sla_s=30.0):
        self.holder = cluster_holder
        self.quantile = quantile
        self.sla_s = sla_s
        self.count_pending = True

    def reset(self):
        pass

    def decide(self, obs):
        import math
        from repro.core.autoscaler.base import Decision
        cluster = self.holder[0]
        units = obs.n_units + obs.n_pending
        exp = cluster.expected_delay(obs.n_in_system, units, self.quantile)
        if exp > self.sla_s:
            target = math.ceil(units * exp / self.sla_s)
            delta = target - units
            if delta > 0:
                return Decision(delta, f"drain {exp:.0f}s > SLA")
            return Decision()
        if exp < 0.5 * self.sla_s and obs.n_units > 1:
            return Decision(-1, "drain < SLA/2")
        return Decision()

    def describe(self):
        return f"replica-load(q={self.quantile:g})"


def _workload(seed: int = 0, n: int = 12_000, horizon: float = 1200.0,
              flat_score: bool = False):
    """Bursty request stream with two application-output channels that shift
    ~60 s before each burst: ``output_score`` (mean answer score) and
    ``breaking_news`` (fraction of breaking-news-shaped answers).
    ``flat_score=True`` pins the mean output score at ~0.5 so only the
    ``breaking_news`` channel carries the early warning."""
    rng = np.random.default_rng(seed)
    bursts = [400.0, 800.0]
    t_axis = np.arange(int(horizon))
    lam = np.ones(int(horizon))
    for b in bursts:
        prof = np.where(t_axis < b, np.exp(-((t_axis - b) ** 2) / (2 * 25.0 ** 2)),
                        np.exp(-(t_axis - b) / 90.0))
        lam *= 1.0 + 5.0 * prof
    lam *= n / lam.sum()
    reqs = []
    rid = 0
    for sec, lam_t in enumerate(lam):
        for _ in range(rng.poisson(lam_t)):
            hot = any(b - 75.0 <= sec <= b + 60.0 for b in bursts)
            reqs.append(ServeRequest(
                rid=rid, arrival_s=sec + rng.random(),
                prefill_len=int(rng.exponential(3000)) + 256,
                decode_len=int(rng.exponential(100)) + 16,
                score=float(np.clip(
                    (0.5 if flat_score else (0.92 if hot else 0.35))
                    + rng.normal(0, 0.05), 0, 1)),
                signals={"breaking_news":
                         1.0 if (hot and rng.random() < 0.9) else 0.0},
            ))
            rid += 1
    return reqs


def _scale_workload(n: int, seed: int = 7) -> list[ServeRequest]:
    """Flat-rate stream of ``n`` light requests (vectorized generation) --
    the overload scenario for the 100k+-request scale proof."""
    rng = np.random.default_rng(seed)
    horizon = n / 250.0                     # ~250 req/s
    arrival = np.sort(rng.uniform(0.0, horizon, size=n))
    prefill = (rng.exponential(1500.0, size=n) + 128).astype(np.int64)
    decode = (rng.exponential(32.0, size=n) + 8).astype(np.int64)
    score = rng.uniform(0.3, 0.7, size=n)
    return [ServeRequest(rid=i, arrival_s=float(arrival[i]),
                         prefill_len=int(prefill[i]), decode_len=int(decode[i]),
                         score=float(score[i]))
            for i in range(n)]


def run_scale(n: int = 100_000) -> Rows:
    """Scale proof: an n-request stream through the vectorized water-filling
    elastic backend completes in seconds (the old per-request equal-share loop
    with its O(queue) pops took minutes at this size)."""
    banner(f"Elastic fleet at scale: {n:,} requests (water-filling core)")
    rows = Rows("elastic_scale")
    reqs = _scale_workload(n)
    cluster = ElasticCluster(ClusterConfig(max_replicas=96, starting_replicas=16),
                             TargetTrackingPolicy(target=0.75), reqs)
    t0 = time.perf_counter()
    res = cluster.run()
    wall = time.perf_counter() - t0
    assert res.n_done == n, f"only {res.n_done}/{n} requests completed"
    # conservation: water-filling never wastes a replica-second under load
    waste = np.abs(res.consumed_t - np.minimum(res.demand_t, res.capacity_t))
    rows.add("n_requests", float(n))
    rows.add("run_wall_s", wall)
    rows.add("requests_per_wall_s", n / wall)
    rows.add("sim_steps", float(res.units_t.size))
    rows.add("max_wasted_replica_s_per_step", float(waste.max()))
    rows.add("viol_pct", 100 * res.violation_rate)
    rows.add("max_replicas", res.max_units)
    rows.add("chip_hours", res["chip_hours"])
    return rows


def run(quick: bool = False, cfg: ClusterConfig | None = None) -> Rows:
    """``cfg`` lets callers supply a measured ClusterConfig (e.g. the remesh
    provisioning cost from examples/elastic_serving.py Phase A)."""
    banner("Elastic LLM serving on the scaling control plane (beyond-paper)")
    rows = Rows("elastic")
    cfg = cfg or ClusterConfig()
    n = 4_000 if quick else 12_000

    results: dict[str, RunReport] = {}
    for name, mk in [
        ("threshold60", lambda h: ThresholdPolicy(0.6)),
        ("target75", lambda h: TargetTrackingPolicy(target=0.75)),
        ("load_q99", lambda h: _ReplicaLoadPolicy(h, quantile=0.99, sla_s=cfg.sla_s)),
        ("load+appdata", lambda h: CompositePolicy([
            _ReplicaLoadPolicy(h, quantile=0.99, sla_s=cfg.sla_s),
            AppDataPolicy(extra_units=4, jump=0.5)])),
        # multi-channel demo on the FLAT-score workload: the primary channel
        # carries no warning, only breaking_news does
        ("flat.load+appdata", lambda h: CompositePolicy([
            _ReplicaLoadPolicy(h, quantile=0.99, sla_s=cfg.sla_s),
            AppDataPolicy(extra_units=4, jump=0.5)])),
        ("flat.load+appdata[breaking]", lambda h: CompositePolicy([
            _ReplicaLoadPolicy(h, quantile=0.99, sla_s=cfg.sla_s),
            AppDataPolicy(extra_units=4, jump=0.5, relative=False,
                          channel="breaking_news")])),
    ]:
        holder = [None]
        policy = mk(holder)
        cluster = ElasticCluster(
            cfg, policy, _workload(n=n, flat_score=name.startswith("flat.")))
        holder[0] = cluster
        res = cluster.run()
        results[name] = res
        rows.add(f"{name}.viol_pct", 100 * res.violation_rate)
        rows.add(f"{name}.chip_hours", res["chip_hours"])
        rows.add(f"{name}.p99_latency_s", res.p99_latency_s)
        rows.add(f"{name}.max_replicas", res.max_units)

    thr, app = results["threshold60"], results["load+appdata"]
    if thr.violation_rate > 0:
        rows.add("appdata_vs_threshold_viol_reduction_pct",
                 100 * (thr.violation_rate - app.violation_rate)
                 / thr.violation_rate)
    blind = results["flat.load+appdata"]
    multi = results["flat.load+appdata[breaking]"]
    rows.add("breaking_channel_fired",
             float(any("breaking_news" in r.reason for r in multi.decisions)))
    if blind.violation_rate > 0:
        rows.add("breaking_vs_blind_viol_reduction_pct",
                 100 * (blind.violation_rate - multi.violation_rate)
                 / blind.violation_rate)

    run_scale(25_000 if quick else 100_000)
    return rows


if __name__ == "__main__":
    run()
