"""Fig 7: quality (% tweets above SLA) and cost (CPU-hours) of the threshold
algorithm (60..99% CPU usage) vs the load algorithm (quantiles 90..99.999%) on
five matches (england/france left out of the figure by the paper: all-perfect)."""
from __future__ import annotations

from benchmarks.common import Rows, banner
from repro.core.autoscaler import LoadPolicy, ThresholdPolicy
from repro.core.simulator import SimConfig, generate_trace, run_scenario
from repro.core.simulator.distributions import ServiceModel

THRESHOLDS = [0.60, 0.70, 0.80, 0.90, 0.99]
QUANTILES = [0.90, 0.99, 0.999, 0.9999, 0.99999]
MATCHES5 = ["japan", "mexico", "italy", "uruguay", "spain"]

#: paper §V-A reference points
PAPER_POINTS = {
    ("spain", "load", 0.99999): (1.67, 20.97),
    ("spain", "threshold", 0.60): (2.52, 31.04),
    ("uruguay", "load", 0.99999): (0.05, 7.14),
    ("uruguay", "threshold", 0.60): (0.25, 12.46),
}


def run(quick: bool = False) -> Rows:
    banner("Fig 7: threshold vs load across matches")
    rows = Rows("fig7")
    sm = ServiceModel()
    matches = ["spain", "uruguay"] if quick else MATCHES5
    ths = [0.60, 0.90] if quick else THRESHOLDS
    qs = [0.90, 0.99999] if quick else QUANTILES
    seeds = [0] if quick else [0, 1]
    cfg = SimConfig()
    for m in matches:
        traces = [generate_trace(m, seed=s) for s in seeds]
        for th in ths:
            v = c = 0.0
            for tr in traces:
                r = run_scenario(tr, ThresholdPolicy(th), cfg)
                v += 100.0 * r.violation_rate / len(traces)
                c += r.cpu_hours / len(traces)
            ref = PAPER_POINTS.get((m, "threshold", th))
            rows.add(f"{m}.threshold{int(th * 100)}.viol_pct", v,
                     f"paper {ref[0]}" if ref else "")
            rows.add(f"{m}.threshold{int(th * 100)}.cpu_hours", c,
                     f"paper {ref[1]}" if ref else "")
        for q in qs:
            v = c = 0.0
            for tr in traces:
                r = run_scenario(tr, LoadPolicy(sm, quantile=q), cfg)
                v += 100.0 * r.violation_rate / len(traces)
                c += r.cpu_hours / len(traces)
            ref = PAPER_POINTS.get((m, "load", q))
            rows.add(f"{m}.load{q:g}.viol_pct", v, f"paper {ref[0]}" if ref else "")
            rows.add(f"{m}.load{q:g}.cpu_hours", c, f"paper {ref[1]}" if ref else "")
    return rows


if __name__ == "__main__":
    run()
