"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time


class Rows:
    """Collects (name, value, derived) rows and prints them as CSV."""

    def __init__(self, title: str):
        self.title = title
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, value: float, derived: str = "") -> None:
        self.rows.append((name, value, derived))
        print(f"{name},{value:.6g},{derived}")

    def timeit(self, name: str, fn, *args, **kwargs):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        us = (time.perf_counter() - t0) * 1e6
        self.add(f"{name}.us_per_call", us)
        return out


def banner(title: str) -> None:
    print(f"\n=== {title} ===")
