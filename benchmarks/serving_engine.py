"""Serving-engine smoke benchmark: the overlapped chunked-prefill +
speculative-decode engine under a mixed-length workload, with HARD regression
gates on the properties the mixed device loop bought (scripts/check.sh runs
this in the verify pass):

* WARM tokens/s must beat the recorded pre-overlap baseline by 1.5x -- a
  revert to per-token host syncs or to serialized prefill dispatches fails
  CI rather than just getting slower.  Warmup (compile) syncs are excluded
  from both the throughput window and the latency percentiles; the old
  bench folded trace time into p50 "latency", which measured the compiler,
  not the engine;
* the mixed loop must stay at ONE compiled variant (fixed max_batch width,
  step count as a traced operand) and must never trace a prefill graph;
* time-to-first-token under a bursty-arrival workload must improve vs the
  non-overlapped (bucketed-prefill) path driven over the same schedule;

and seeds the perf trajectory: every run writes
``benchmarks/artifacts/BENCH_serving.json`` (warm tokens/s vs the recorded
baseline, p50/p99 per-token latency, TTFT for both paths, speculation
acceptance counters, per-bucket prefill occupancy, jit trace counts) which
CI uploads alongside the other artifacts.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Rows, banner

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_serving.json")

WALL_BOUND_S = 240.0          # generous CPU bound; normal runs are ~10x faster

#: tokens/s of the pre-overlap engine (bucketed prefill dispatches + 1-token
#: device decode loop) on this workload, measured on the CI-class CPU runner
#: at the commit before the chunked/speculative PR.  The overlap PR must
#: beat it 1.5x WARM on the same machine; the margin leaves room for a
#: runner somewhat slower than the reference box while still failing any
#: revert to serialized prefill or one-token-per-step decode.
BASELINE_TOKENS_PER_S = {False: 35.7, True: 13.8}      # quick=False / True
GATE_MARGIN = 1.5             # hard floor on warm speedup vs the baseline


def _workload(cfg, rng, n):
    from repro.serving import Request
    reqs = []
    for i in range(n):
        # prompt lengths spread over three power-of-two buckets (<=16, 32, 64)
        plen = int(rng.integers(4, 60))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 10))))
    return reqs


def _warmup(eng, cfg, rng):
    """Compile every variant the measured run will touch (mixed loop or all
    three prefill buckets + decode widths) so the timed window is warm."""
    from repro.serving import Request
    for i, plen in enumerate((8, 20, 40, 56)):
        eng.submit(Request(
            rid=-1 - i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=6))
    eng.run_until_drained()
    eng.completed.clear()


def _drain_timed(eng):
    """Drain, timing each host sync; returns (wall_s, per-token latencies)."""
    lat = []
    done_before = sum(len(r.output) for r in eng.completed)
    t0 = time.perf_counter()
    while eng.queue or eng.active:
        tokens0 = sum(len(r.output) for r in eng.completed) \
            + sum(len(r.output) for r in eng.active.values())
        ts = time.perf_counter()
        eng.step(decode_steps=eng.decode_steps)
        dt = time.perf_counter() - ts
        tokens1 = sum(len(r.output) for r in eng.completed) \
            + sum(len(r.output) for r in eng.active.values())
        if tokens1 > tokens0:
            lat.append(dt / (tokens1 - tokens0))
    wall = time.perf_counter() - t0
    del done_before
    return wall, lat


def _bursty_ttft(model, params, cfg, *, chunked: bool) -> dict:
    """Real-time bursty-arrival schedule: two long decodes keep the engine
    busy, then lone requests arrive in cold buckets mid-flight.  Returns
    TTFT stats for the burst arrivals.  Both paths run warm over the same
    schedule; only ``chunked_prefill`` differs."""
    import jax  # noqa: F401  (engine already built; kept for parity)
    from repro.serving import Request, ServeConfig, ServingEngine

    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=4, max_len=128,
                                    chunked_prefill=chunked))
    rng = np.random.default_rng(2)
    _warmup(eng, cfg, rng)

    # TTFT is stamped here (post-step wall clock), not from first_token_s:
    # the engine stamps with the step-entry clock, which would exclude the
    # emitting step's own compute from the overlap path's TTFT
    first_seen: dict[int, float] = {}

    def _step():
        # per-token sync cadence: a latency-oriented server syncs every
        # token; K-step bursts would quantize TTFT to whole bursts
        eng.step()
        now = time.monotonic()
        live = list(eng.active.values()) + eng.completed
        for r in live:
            if r.rid >= 100 and r.output and r.rid not in first_seen:
                first_seen[r.rid] = now

    for i in range(2):      # base load: long decodes keep the engine busy
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
            max_new_tokens=60, arrival_s=time.monotonic()))
    _step()
    bursts = []
    for j, plen in enumerate((8, 12, 24, 8, 12, 24)):
        r = Request(rid=100 + j,
                    prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                    max_new_tokens=4, arrival_s=time.monotonic())
        bursts.append(r)
        eng.submit(r)       # lone arrival in a (now cold again) bucket
        _step()
        _step()
    while eng.queue or eng.active:
        _step()
    assert len(eng.completed) == 8, f"bursty drain dropped requests ({chunked=})"
    eng.kv.check_invariants()
    ttft = np.array([first_seen[r.rid] - r.arrival_s for r in bursts])
    return {"mean_s": float(ttft.mean()), "p50_s": float(np.median(ttft)),
            "max_s": float(ttft.max()),
            "bucket_occupancy": eng.bucket_occupancy}


def run(quick: bool = False) -> Rows:
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving import ServeConfig, ServingEngine

    banner("Serving engine smoke (chunked prefill + speculative decode)")
    rows = Rows("serving_engine")
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    # -- phase 1: warm throughput + per-token latency on the overlap path --
    eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_len=128))
    rng = np.random.default_rng(0)
    _warmup(eng, cfg, rng)
    n = 12 if quick else 32
    reqs = _workload(cfg, rng, n)
    for r in reqs:
        r.arrival_s = time.monotonic()
        eng.submit(r)
    wall, lat = _drain_timed(eng)
    assert len(eng.completed) == n, \
        f"engine dropped requests: {len(eng.completed)}/{n}"
    eng.kv.check_invariants()

    tokens = sum(len(r.output) for r in reqs)
    tokens_per_s = tokens / wall
    baseline = BASELINE_TOKENS_PER_S[quick]
    p50_tok_ms = float(np.median(lat) * 1e3)
    p99_tok_ms = float(np.percentile(lat, 99) * 1e3)
    ttft_all = np.array([r.first_token_s - r.arrival_s for r in reqs])
    spec = eng.speculation_stats

    rows.add("n_requests", float(n))
    rows.add("wall_s", wall, "warm drain (compile excluded)")
    rows.add("tokens", float(tokens))
    rows.add("tokens_per_s", tokens_per_s)
    rows.add("baseline_tokens_per_s", baseline, "pre-overlap engine, warm-equiv")
    rows.add("speedup_vs_baseline", tokens_per_s / baseline,
             f"gate: >= {GATE_MARGIN}x")
    rows.add("p50_token_latency_ms", p50_tok_ms, "per emitted token, warm")
    rows.add("p99_token_latency_ms", p99_tok_ms)
    rows.add("ttft_p50_ms", float(np.median(ttft_all) * 1e3), "batch arrival")
    rows.add("spec_tokens_per_row_step", spec["tokens_per_row_step"],
             "> 1: speculation beats 1-token steps")
    rows.add("mixed_traces", float(eng.mixed_trace_count))
    rows.add("prefill_traces", float(eng.prefill_trace_count))
    rows.add("mean_score_logprob", float(np.mean([r.score for r in reqs])))

    # -- phase 2: bursty-arrival TTFT A/B (overlap vs bucketed prefill) ----
    ttft_over = _bursty_ttft(model, params, cfg, chunked=True)
    ttft_bucketed = _bursty_ttft(model, params, cfg, chunked=False)
    rows.add("burst_ttft_p50_ms_overlap", ttft_over["p50_s"] * 1e3)
    rows.add("burst_ttft_p50_ms_bucketed", ttft_bucketed["p50_s"] * 1e3,
             "non-overlapped path, same schedule")

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump({
            "workload": {"n_requests": n, "quick": quick,
                         "max_batch": eng.cfg.max_batch,
                         "max_len": eng.cfg.max_len,
                         "page_size": eng.kv.page_size,
                         "decode_steps": eng.decode_steps,
                         "chunk_size": eng.span,
                         "draft_len": eng.spec_len,
                         "timing": "warm (compile/warmup syncs excluded)"},
            "tokens": tokens,
            "tokens_per_s": tokens_per_s,
            "baseline_tokens_per_s": baseline,
            "speedup_vs_baseline": tokens_per_s / baseline,
            "gate_margin": GATE_MARGIN,
            "p50_token_latency_ms": p50_tok_ms,
            "p99_token_latency_ms": p99_tok_ms,
            "ttft_batch_arrival_p50_ms": float(np.median(ttft_all) * 1e3),
            "burst_ttft": {"overlap": ttft_over, "bucketed": ttft_bucketed},
            "speculation": spec,
            "bucket_occupancy": ttft_bucketed["bucket_occupancy"],
            "engine_steps": eng.step_count,
            "mixed_traces": eng.mixed_trace_count,
            "prefill_traces": eng.prefill_trace_count,
            "decode_traces": eng.decode_trace_count,
        }, f, indent=2)
    print(f"[artifact] {ARTIFACT}")

    assert eng.prefill_trace_count == 0, (
        f"chunked engine traced {eng.prefill_trace_count} prefill graphs -- "
        f"prompts are no longer streaming through the mixed loop")
    assert eng.mixed_trace_count <= 1, (
        f"mixed loop retraced {eng.mixed_trace_count}x -- the fixed-width "
        f"single-variant contract is broken")
    assert wall < WALL_BOUND_S, f"serving smoke took {wall:.1f}s > {WALL_BOUND_S}s"
    assert tokens_per_s > GATE_MARGIN * baseline, (
        f"{tokens_per_s:.1f} tokens/s <= {GATE_MARGIN}x the pre-overlap "
        f"baseline {baseline:.1f} -- chunked/speculative decode regressed")
    assert ttft_over["p50_s"] < ttft_bucketed["p50_s"], (
        f"bursty TTFT p50 {ttft_over['p50_s'] * 1e3:.0f}ms (overlap) >= "
        f"{ttft_bucketed['p50_s'] * 1e3:.0f}ms (bucketed) -- chunked prefill "
        f"is no longer hiding prompt latency")
    return rows


if __name__ == "__main__":
    run(quick=bool(int(os.environ.get("BENCH_QUICK", "0"))))
