"""Serving-engine smoke benchmark: the paged continuous batcher under a small
mixed-bucket workload, with HARD regression gates on the two properties the
paged refactor bought (scripts/check.sh runs this in the verify pass):

* prefill jit retraces are bounded by the number of distinct request_class
  buckets (a per-length retrace regression fails the run);
* decode jit retraces are bounded by the power-of-two active-batch sizes
  (a per-step or per-slot-count retrace regression fails the run);

plus a generous wall-clock bound so a gross slowdown (e.g. decode falling
back to per-slot loops, gather turning O(S^2)) fails CI rather than just
getting slower.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows, banner

WALL_BOUND_S = 120.0          # generous CPU bound; normal runs are ~10x faster


def run(quick: bool = False) -> Rows:
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    banner("Serving engine smoke (paged KV, bucketed prefill, active-slot decode)")
    rows = Rows("serving_engine")
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_len=128))

    rng = np.random.default_rng(0)
    n = 12 if quick else 32
    reqs = []
    for i in range(n):
        # prompt lengths spread over three power-of-two buckets (<=16, 32, 64)
        plen = int(rng.integers(4, 60))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 10))))
        eng.submit(reqs[-1])
    buckets = {min(r.request_class[0], eng.cfg.max_len) for r in reqs}

    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    assert len(eng.completed) == n, f"engine dropped requests: {len(eng.completed)}/{n}"
    eng.kv.check_invariants()

    tokens = sum(len(r.output) for r in reqs)
    rows.add("n_requests", float(n))
    rows.add("wall_s", wall)
    rows.add("tokens", float(tokens))
    rows.add("tokens_per_s", tokens / wall)
    rows.add("engine_steps", float(eng.step_count))
    rows.add("n_buckets", float(len(buckets)))
    rows.add("prefill_traces", float(eng.prefill_trace_count))
    rows.add("decode_traces", float(eng.decode_trace_count))
    rows.add("mean_score_logprob",
             float(np.mean([r.score for r in reqs])))

    assert eng.prefill_trace_count <= len(buckets), (
        f"prefill retraced {eng.prefill_trace_count}x for {len(buckets)} "
        f"buckets -- per-length retracing is back")
    decode_bound = int(np.ceil(np.log2(eng.cfg.max_batch))) + 1
    assert eng.decode_trace_count <= decode_bound, (
        f"decode retraced {eng.decode_trace_count}x (bound {decode_bound}) -- "
        f"active-slot compaction is broken")
    assert wall < WALL_BOUND_S, f"serving smoke took {wall:.1f}s > {WALL_BOUND_S}s"
    return rows


if __name__ == "__main__":
    run()
