"""Serving-engine smoke benchmark: the paged continuous batcher under a small
mixed-bucket workload, with HARD regression gates on the properties the
device-resident decode loop bought (scripts/check.sh runs this in the verify
pass):

* prefill jit retraces are bounded by the number of distinct request_class
  buckets (a per-length retrace regression fails the run);
* decode jit retraces are bounded by the power-of-two active-batch sizes
  (a per-step, per-slot-count, or per-K retrace regression fails the run);
* tokens/s must beat the recorded pre-loop baseline (the per-token
  host-sync path) by a generous CI-noise margin -- a revert to per-token
  ``np.asarray`` round trips fails CI rather than just getting slower;

and seeds the perf trajectory: every run writes
``benchmarks/artifacts/BENCH_serving.json`` (tokens/s vs the recorded
baseline, jit trace counts, p50 per-sync step latency, prefill batch
occupancy) which CI uploads alongside the other artifacts.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Rows, banner

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_serving.json")

WALL_BOUND_S = 120.0          # generous CPU bound; normal runs are ~10x faster

#: tokens/s of the pre-device-resident engine (per-token host sync, one jit
#: call per prefill) on this workload, measured on the CI-class CPU runner
#: at the commit before the decode-loop PR.  The measured speedup on the
#: same machine was ~2.1-2.3x (recorded in BENCH_serving.json each run);
#: the HARD gate only requires beating the recorded baseline at par, so a
#: runner up to ~2x slower than the reference machine still passes while a
#: revert to per-token host syncs (which lands at ~1.0x baseline on a
#: comparable machine, ~0.5x on a half-speed one) still fails.
BASELINE_TOKENS_PER_S = {False: 35.7, True: 13.8}      # quick=False / True
GATE_MARGIN = 1.0             # hard floor; machine-speed headroom above


def run(quick: bool = False) -> Rows:
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    banner("Serving engine smoke (device-resident decode loop, paged KV)")
    rows = Rows("serving_engine")
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_len=128))

    rng = np.random.default_rng(0)
    n = 12 if quick else 32
    reqs = []
    for i in range(n):
        # prompt lengths spread over three power-of-two buckets (<=16, 32, 64)
        plen = int(rng.integers(4, 60))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 10))))
        eng.submit(reqs[-1])
    buckets = {min(r.request_class[0], eng.cfg.max_len) for r in reqs}

    # drive the drain loop by hand so each host sync (one K-step device
    # loop + refill) can be timed individually
    t0 = time.perf_counter()
    sync_lat = []
    while eng.queue or eng.active:
        ts = time.perf_counter()
        eng.step(decode_steps=eng.decode_steps)
        sync_lat.append(time.perf_counter() - ts)
    wall = time.perf_counter() - t0
    assert len(eng.completed) == n, f"engine dropped requests: {len(eng.completed)}/{n}"
    eng.kv.check_invariants()

    tokens = sum(len(r.output) for r in reqs)
    tokens_per_s = tokens / wall
    baseline = BASELINE_TOKENS_PER_S[quick]
    p50_ms = float(np.median(sync_lat) * 1e3)
    rows.add("n_requests", float(n))
    rows.add("wall_s", wall)
    rows.add("tokens", float(tokens))
    rows.add("tokens_per_s", tokens_per_s)
    rows.add("baseline_tokens_per_s", baseline, "pre-PR per-token sync path")
    rows.add("speedup_vs_baseline", tokens_per_s / baseline)
    rows.add("engine_steps", float(eng.step_count))
    rows.add("host_syncs", float(len(sync_lat)))
    rows.add("p50_step_latency_ms", p50_ms, "per host sync (K device steps)")
    rows.add("prefill_batch_occupancy", eng.prefill_occupancy)
    rows.add("n_buckets", float(len(buckets)))
    rows.add("prefill_traces", float(eng.prefill_trace_count))
    rows.add("decode_traces", float(eng.decode_trace_count))
    rows.add("mean_score_logprob",
             float(np.mean([r.score for r in reqs])))

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump({
            "workload": {"n_requests": n, "quick": quick,
                         "max_batch": eng.cfg.max_batch,
                         "max_len": eng.cfg.max_len,
                         "page_size": eng.kv.page_size,
                         "decode_steps": eng.decode_steps},
            "tokens": tokens,
            "tokens_per_s": tokens_per_s,
            "baseline_tokens_per_s": baseline,
            "speedup_vs_baseline": tokens_per_s / baseline,
            "p50_step_latency_ms": p50_ms,
            "host_syncs": len(sync_lat),
            "engine_steps": eng.step_count,
            "prefill_traces": eng.prefill_trace_count,
            "decode_traces": eng.decode_trace_count,
            "prefill_batch_occupancy": eng.prefill_occupancy,
        }, f, indent=2)
    print(f"[artifact] {ARTIFACT}")

    assert eng.prefill_trace_count <= len(buckets), (
        f"prefill retraced {eng.prefill_trace_count}x for {len(buckets)} "
        f"buckets -- per-length retracing is back")
    decode_bound = int(np.ceil(np.log2(eng.cfg.max_batch))) + 1
    assert eng.decode_trace_count <= decode_bound, (
        f"decode retraced {eng.decode_trace_count}x (bound {decode_bound}) -- "
        f"active-slot compaction is broken")
    assert wall < WALL_BOUND_S, f"serving smoke took {wall:.1f}s > {WALL_BOUND_S}s"
    assert tokens_per_s > GATE_MARGIN * baseline, (
        f"{tokens_per_s:.1f} tokens/s <= {GATE_MARGIN}x the pre-PR baseline "
        f"{baseline:.1f} -- the device-resident decode loop regressed")
    return rows


if __name__ == "__main__":
    run()
