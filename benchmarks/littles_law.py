"""Testbed calibration benchmark (paper §IV-A).

Validates that the calibrated service model reproduces the paper's testbed
statistics and Little's law:

  L = 15,875.32 in-flight tweets,  W = 192.09 s,  lambda = 82.65 tweets/s,
  L ~= lambda * W  (paper: 15,876.24)

The testbed read all tweets at once and processed them "as fast as its CPU was
able", holding a roughly constant in-flight population; we reproduce it with an
in-flight-capped processor-sharing drain at 2.6 GHz / 97.95% utilization.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, banner
from repro.core.simulator.distributions import (
    CYCLES_PER_DELAY_SECOND,
    TESTBED_FREQ_HZ,
    TESTBED_IN_FLIGHT,
    TESTBED_INPUT_RATE,
    TESTBED_MEAN_DELAY_S,
    TESTBED_UTILIZATION,
    ServiceModel,
)


def run(quick: bool = False) -> Rows:
    banner("Little's law / testbed calibration (paper SSIV-A)")
    rows = Rows("littles_law")
    sm = ServiceModel()

    # --- analytic identities -------------------------------------------------------
    mean_cycles = sm.mean_cycles()
    rows.add("mean_cycles_per_tweet", mean_cycles)
    # completion rate of a saturated 1-CPU 2.6 GHz testbed
    lam = TESTBED_FREQ_HZ * TESTBED_UTILIZATION / mean_cycles
    rows.add("implied_lambda_tweets_per_s", lam, f"paper {TESTBED_INPUT_RATE}")
    W = TESTBED_IN_FLIGHT / lam
    rows.add("implied_W_seconds", W, f"paper {TESTBED_MEAN_DELAY_S}")
    rows.add("littles_L_equals_lamW", lam * W, f"paper L={TESTBED_IN_FLIGHT}")

    # --- simulated capped-in-flight drain ------------------------------------------
    n = 60_000 if quick else 300_000
    rng = np.random.default_rng(0)
    cls = sm.sample_classes(rng, n)
    cycles = sm.sample_cycles(rng, cls)
    cycles = cycles[cycles > 0.0]
    cap = int(TESTBED_IN_FLIGHT)
    capacity = TESTBED_FREQ_HZ  # cycles per 1 s step, single CPU
    rem = cycles[:cap].copy()
    head = cap
    t = 0.0
    finish, enter = [], np.zeros(cycles.shape[0])
    enter[:cap] = 0.0
    done = 0
    while done < min(cycles.shape[0], n // 2):
        L = rem.shape[0]
        if L == 0:
            break
        share = capacity / L
        fin = rem <= share
        k = int(fin.sum())
        if k:
            finish.extend([t + 1.0] * k)
            done += k
            rem = rem[~fin]
            new = cycles[head : head + k]
            enter[head : head + k] = t + 1.0
            head += k
            rem = np.concatenate([rem, new])
        rem = rem - share  # approximate: excess of finished redistributed next step
        rem = np.maximum(rem, 0.0)
        t += 1.0
    # measured delay for the steady-state middle cohort
    fin_arr = np.asarray(finish)
    mid = slice(cap, min(head, fin_arr.shape[0]))
    delays = fin_arr[mid] - enter[cap : cap + (mid.stop - mid.start)]
    meas_W = float(np.mean(delays)) if delays.size else float("nan")
    meas_rate = done / t if t else float("nan")
    rows.add("simulated_W_seconds", meas_W, f"analytic {W:.1f}")
    rows.add("simulated_lambda", meas_rate, f"analytic {lam:.2f}")
    return rows


if __name__ == "__main__":
    run()
