"""Convergence-plane fault drill (ROADMAP convergence item).

The same bursty replica workload runs twice per fault scenario on the elastic
backend: once under the legacy imperative controller (policy deltas actuated
directly) and once with ``convergence=True`` (the policy's votes folded into a
desired state that the :class:`repro.core.convergence.Converger` reconciles
every step).  Seeded faults are injected through the shared
:class:`~repro.core.scaling.CapacityPlan`:

* **unit-loss** -- replicas are killed mid-burst; the imperative controller
  only notices through utilization (one adapt period + provision delay
  later), the converger relaunches on the very next step.
* **stuck-build** -- provisioning requests hang; imperatively they clog the
  pool's headroom forever, the converger times them out, cancels, backs off
  and retries.
* **brownout** -- builds land, but 8x later than promised; the converger
  sees them overdue against the *promised* landing time, cancels the
  latest-landing capacity first and relaunches, while the imperative
  controller just waits out the inflated delay.
* **corr-loss** -- AZ-scale events take half the live fleet in one step
  (a covariance no independent per-unit hazard produces); healing a bulk
  loss is where next-step reconciliation pays most.

The drill asserts the converger's SLA violation rate is *strictly* lower in
every fault scenario, that the fault-free run is bit-for-bit identical
between the two modes, and that replaying the convergence audit log
reproduces the final per-pool fleet state.  Emitted as
``benchmarks/artifacts/convergence_faults.json``.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Rows, banner
from repro.core.autoscaler import Policy, ThresholdPolicy
from repro.core.autoscaler.base import Decision
from repro.core.convergence import ConvergerConfig, FaultSpec, replay
from repro.core.elastic import ClusterConfig, ElasticCluster
from repro.core.scaling import UnitPool

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "convergence_faults.json")

#: fault windows sized to land inside the workload's two bursts (400 s, 800 s)
LOSS = (FaultSpec(loss_rate=1 / 40.0, start_s=380.0, end_s=900.0, seed=13),)
STUCK = (FaultSpec(stuck_p=0.9, start_s=350.0, end_s=900.0, seed=13),)
#: builds queued in the window land 8x late (45 s promise -> 360 s); the
#: window ends mid-burst so cancel-and-relaunch beats waiting it out
BROWNOUT = (FaultSpec(brownout_factor=8.0, start_s=350.0, end_s=500.0,
                      seed=13),)
#: ~1 AZ-scale event per minute of window, each taking half the live fleet
CORR = (FaultSpec(corr_loss_p=1 / 60.0, corr_loss_frac=0.5, start_s=380.0,
                  end_s=900.0, seed=13),)

CONVERGE = ConvergerConfig(build_timeout_s=75.0, backoff_base_s=10.0,
                           backoff_max_s=60.0, max_retries=10)

#: the ceiling makes stuck builds *bite* imperatively -- clogged pending
#: exhausts the pool's headroom, so further scale-up requests are clamped to
#: zero until something cancels them (which only the converger does)
POOL = (UnitPool("replica", provision_delay_s=45.0, min_units=1,
                 max_units=12),)

#: the brownout drill runs against a TIGHT ceiling: browned-out builds sit in
#: pending for 360 s and clog all headroom, so the imperative controller
#: cannot queue healthy replacements once the window closes -- only the
#: converger's overdue-cancel reclaims the ceiling before the burst decays
BROWNOUT_POOL = (UnitPool("replica", provision_delay_s=45.0, min_units=1,
                          max_units=4),)


class _RestartFloor(Policy):
    """ThresholdPolicy plus the one affordance every real deployment has: if
    the fleet is dead (no live, no pending) while work is queued, restart a
    unit.  Utilization-only rules read a dead fleet as 0%-busy and would
    otherwise never recover from total unit loss -- this keeps the imperative
    baseline *live* (it still limps through every loss the slow way: notice
    via utilization one adapt period later, then wait out the provision
    delay) so the drill measures degradation rather than deadlock."""

    name = "threshold+restart"

    def __init__(self, inner: Policy):
        self.inner = inner

    def reset(self):
        self.inner.reset()

    def decide(self, obs):
        if obs.n_units + obs.n_pending == 0 and obs.n_in_system > 0:
            return Decision(1, "dead-fleet restart")
        return self.inner.decide(obs)

    def describe(self):
        return self.inner.describe() + "+restart"


def _run(n: int, *, faults=None, convergence: bool, pools=POOL):
    from benchmarks.elastic_serving import _workload
    cfg = ClusterConfig(pools=pools, faults=faults, convergence=convergence,
                        converge=CONVERGE if convergence else None)
    cluster = ElasticCluster(cfg, _RestartFloor(ThresholdPolicy(0.7)),
                             _workload(n=n))
    rep = cluster.run()
    return rep, cluster.controller


def _fingerprint(rep) -> tuple:
    return (rep.violation_rate, rep.unit_seconds, rep.n_decisions_up,
            rep.n_decisions_down, int(rep.units_t.sum()),
            int(rep.units_t.max()))


def run(quick: bool = False) -> Rows:
    banner("Convergence plane under injected faults (elastic backend)")
    rows = Rows("convergence_faults")
    n = 2_000 if quick else 8_000

    scenarios = {}
    for name, faults in (("fault-free", None), ("unit-loss", LOSS),
                         ("stuck-build", STUCK), ("brownout", BROWNOUT),
                         ("corr-loss", CORR)):
        pools = BROWNOUT_POOL if name == "brownout" else POOL
        imp, _ = _run(n, faults=faults, convergence=False, pools=pools)
        conv, ctrl = _run(n, faults=faults, convergence=True, pools=pools)
        scenarios[name] = (imp, conv)
        for mode, rep in (("imperative", imp), ("converger", conv)):
            rows.add(f"{name}.{mode}.viol_pct", 100.0 * rep.violation_rate)
            rows.add(f"{name}.{mode}.unit_seconds", rep.unit_seconds)
        # the audit log is a faithful account: replaying it lands on the
        # exact final per-pool fleet state
        final = {p: {"live": s.units, "pending": s.pending}
                 for p, s in ctrl.plan.stats().items()}
        assert replay(ctrl.audit.records) == final, name
        rows.add(f"{name}.audit_records", float(len(ctrl.audit.records)))
        if faults is not None:
            fired = len(ctrl.plan.fault_events)
            assert fired > 0, f"{name}: no faults actually fired"
            rows.add(f"{name}.faults_fired", float(fired))
            kinds = {e.kind for e in ctrl.plan.fault_events}
            if name == "brownout":
                # builds really were browned out AND the converger gave up
                # on some of the late-landing capacity rather than waiting
                assert "brownout" in kinds, "no build was browned out"
                assert ctrl.plan.meters()["replica"].cancelled > 0, \
                    "converger never cancelled an overdue browned-out build"
            if name == "corr-loss":
                assert "corr_loss" in kinds, "no AZ-scale event fired"
                assert ctrl.plan.meters()["replica"].lost > 1, \
                    "corr-loss never took multiple units"

    # fault-free: convergence mode is bit-for-bit the imperative controller
    imp, conv = scenarios["fault-free"]
    assert _fingerprint(imp) == _fingerprint(conv), "fault-free parity broke"
    rows.add("fault-free.parity", 1.0, "fingerprints identical")

    # under faults: the converger restores SLA, the baseline stays degraded
    for name in ("unit-loss", "stuck-build", "brownout", "corr-loss"):
        imp, conv = scenarios[name]
        assert conv.violation_rate < imp.violation_rate, (
            f"{name}: converger {conv.violation_rate:.4f} !< "
            f"imperative {imp.violation_rate:.4f}")
        rows.add(f"{name}.viol_pct_saved",
                 100.0 * (imp.violation_rate - conv.violation_rate))

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    payload = {
        "description": "imperative vs convergence control plane under seeded "
                       "unit-loss, stuck-build, brownout and correlated-loss "
                       "faults (elastic backend, threshold70 policy)",
        "n_requests": n,
        "scenarios": {
            name: {mode: {"violation_rate": rep.violation_rate,
                          "unit_seconds": rep.unit_seconds,
                          "p99_latency_s": rep.p99_latency_s,
                          "max_units": rep.max_units}
                   for mode, rep in (("imperative", imp_),
                                     ("converger", conv_))}
            for name, (imp_, conv_) in scenarios.items()},
    }
    with open(ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    rows.add("artifact_scenarios", float(len(scenarios)), ARTIFACT)
    return rows


if __name__ == "__main__":
    run()
