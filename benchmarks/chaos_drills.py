"""Chaos-drill soak gate: scripted incidents with invariant-checked recovery.

Two layers, matching the incident-hardening design (DESIGN.md):

* **Elastic incidents** -- five deterministic fault scripts
  (:class:`~repro.core.convergence.ScriptedFaults`: timed unit kills,
  correlated AZ-scale loss, loss landing under a stuck-build window, and
  webhook capacity floors raised MID-INCIDENT while the converger is inside
  a retry/backoff cycle) each run twice on the elastic backend: imperative
  baseline vs ``convergence=True``.  The gate is strict on every script:
  the converger's SLA violation rate must be LOWER, and the convergence
  audit log must pass the full :func:`~repro.core.chaos.check_audit`
  battery (CRC-sealed tail, capacity replay equals the final fleet state,
  pure-planner replay reproduces every logged decision and generation).
* **Fleet drills** -- the same discipline against REAL serving engines:
  a :class:`~repro.core.chaos.ChaosDrill` kills 2 of 3 live replicas in one
  correlated event mid-burst (exactly-once completion, bit-identical
  outputs vs the no-fault reference, KV page conservation, audit replay),
  plus a webhook floor that lands while a failed respawn sits in backoff --
  the floor must supersede the stale retry ("superseded" in the audit),
  not wait it out.

Determinism is itself a gate: re-running the same seeded script produces a
byte-identical audit log on both backends.  All invariants hard-fail the
bench.  Emitted as ``benchmarks/artifacts/chaos_drills.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass

import numpy as np

from benchmarks.common import Rows, banner
from benchmarks.convergence_faults import (
    BROWNOUT_POOL, CONVERGE, POOL, _RestartFloor,
)
from repro.core.autoscaler import Policy, ThresholdPolicy
from repro.core.autoscaler.base import CompositePolicy, Decision
from repro.core.chaos import ChaosAction, ChaosDrill, ChaosScript, check_audit
from repro.core.convergence import (
    AuditLog,
    ConvergerConfig,
    ScriptedFault,
    ScriptedFaults,
)
from repro.core.convergence.groups import ScalingGroup
from repro.core.elastic import ClusterConfig, ElasticCluster

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "chaos_drills.json")

FLEET_SLA_S = 6.0             # tight enough that a 1-replica limp violates


def _surge_group(max_units: int, floor: int) -> ScalingGroup:
    """One webhook ('surge') raising the replica floor for 400 s."""
    return ScalingGroup.from_config({
        "name": "chaos-drills",
        "pools": [{"name": "replica", "provision_delay_s": 45.0,
                   "min_units": 1, "max_units": max_units}],
        "webhooks": [{"name": "surge", "hold_s": 400.0,
                      "targets": {"replica": floor}}],
    })


@dataclass(frozen=True)
class Incident:
    """One scripted elastic incident: timed faults + optional webhook fires."""

    name: str
    events: tuple
    pools: tuple = POOL
    group: ScalingGroup | None = None
    fires: tuple = ()            # (at_s, webhook name), fired mid-run
    floor: int = 0               # converger must reach this peak if set
    note: str = ""


#: the two workload bursts peak at 400 s and 800 s; every script is timed
#: against them (kills mid-ramp, windows covering the burst, floors raised
#: while the converger is mid-retry)
INCIDENTS = (
    Incident(
        "burst-kill",
        (ScriptedFault(405.0, "lose", count=2),
         ScriptedFault(430.0, "lose", count=1),
         ScriptedFault(810.0, "lose", count=2),
         ScriptedFault(950.0, "flap", count=1)),
        note="timed kills inside both bursts + a late health flap"),
    Incident(
        "corr-az-loss",
        (ScriptedFault(410.0, "corr_lose", frac=0.5),
         ScriptedFault(440.0, "corr_lose", frac=0.5),
         ScriptedFault(470.0, "corr_lose", frac=0.5),
         ScriptedFault(820.0, "corr_lose", frac=0.5),
         ScriptedFault(850.0, "corr_lose", frac=0.5)),
        note="repeated AZ-scale events each take half the live fleet -- "
             "losses compound faster than one +1 vote per adapt tick"),
    Incident(
        "loss-under-stuck",
        (ScriptedFault(370.0, "lose", count=2),
         ScriptedFault(390.0, "stick", until_s=600.0)),
        group=_surge_group(12, 5), fires=((640.0, "surge"),), floor=5,
        note="kills land just before every rebuild starts sticking; after "
             "the window an operator floor pins recovery capacity through "
             "the trough so the next burst is not served from a drained "
             "fleet"),
    Incident(
        "stuck-floor-race",
        (ScriptedFault(350.0, "stick", until_s=650.0),),
        group=_surge_group(12, 8), fires=((540.0, "surge"),), floor=8,
        note="operator floor raised mid-backoff during a stuck window"),
    Incident(
        "brownout-floor-race",
        (ScriptedFault(350.0, "brownout", until_s=520.0, factor=8.0),),
        pools=BROWNOUT_POOL, group=_surge_group(4, 4),
        fires=((430.0, "surge"),), floor=4,
        note="floor lands mid-retry while browned-out builds clog a tight "
             "ceiling"),
)


class _HoldPolicy(Policy):
    """Freeze capacity at the starting fleet: the fleet drills isolate the
    converger's healing (kill -> relaunch, floor -> supersede) from
    policy-driven scaling, so the imperative baseline's only affordance is
    whatever capacity survived the script."""

    name = "hold"

    def decide(self, obs) -> Decision:
        del obs
        return Decision()

    def describe(self) -> str:
        return "hold"


# ---------------------------------------------------------------------------------
# elastic incidents: imperative vs converger, audit battery, strict wins
# ---------------------------------------------------------------------------------

def _run_incident(n: int, inc: Incident, *, convergence: bool,
                  audit_path: str | None = None):
    from benchmarks.elastic_serving import _workload
    faults = ScriptedFaults(inc.events)
    policy: Policy = _RestartFloor(ThresholdPolicy(0.7))
    hook = None
    if convergence:
        if inc.fires:
            def hook(cluster, t):
                for at, name in inc.fires:
                    if at <= t < at + cluster.cfg.step_s:
                        cluster.controller.fire_webhook(name, t)
        cfg = ClusterConfig(pools=inc.pools, faults=faults, convergence=True,
                            converge=CONVERGE, group=inc.group,
                            audit_path=audit_path)
    else:
        if inc.fires:
            # legacy semantics: the group's floors only reach an imperative
            # controller as a delta-voting policy, so the baseline gets the
            # SAME operator intent through its own mechanism
            wh = inc.group.as_policy()
            policy = CompositePolicy([policy, wh])

            def hook(cluster, t):
                for at, name in inc.fires:
                    if at <= t < at + cluster.cfg.step_s:
                        wh.fire(name, t)
        cfg = ClusterConfig(pools=inc.pools, faults=faults, convergence=False)
    cluster = ElasticCluster(cfg, policy, _workload(n=n), on_step=hook)
    rep = cluster.run()
    return rep, cluster.controller


def _final_state(ctrl) -> dict:
    return {p: {"live": s.units, "pending": s.pending}
            for p, s in ctrl.plan.stats().items()}


def _elastic_incidents(n: int, tmp: str, rows: Rows) -> dict:
    out = {}
    for inc in INCIDENTS:
        imp, _ = _run_incident(n, inc, convergence=False)
        apath = os.path.join(tmp, f"{inc.name}.jsonl")
        conv, ctrl = _run_incident(n, inc, convergence=True, audit_path=apath)
        assert ctrl.plan.fault_events, f"{inc.name}: no scripted fault fired"

        # invariant battery on the convergence run's sealed audit log
        bad = check_audit(apath, _final_state(ctrl))
        assert not bad, (f"{inc.name}: audit invariants violated: "
                         + "; ".join(str(v) for v in bad))

        # the converger must strictly beat the imperative baseline
        assert conv.violation_rate < imp.violation_rate, (
            f"{inc.name}: converger {conv.violation_rate:.4f} !< "
            f"imperative {imp.violation_rate:.4f}")

        if inc.fires:
            kinds = {r["kind"] for r in ctrl.audit.records}
            assert "webhook" in kinds, \
                f"{inc.name}: webhook fire missing from the audit log"
            assert int(conv.units_t.max()) >= inc.floor, (
                f"{inc.name}: converger peaked at {int(conv.units_t.max())} "
                f"< webhook floor {inc.floor}")

        for mode, rep in (("imperative", imp), ("converger", conv)):
            rows.add(f"{inc.name}.{mode}.viol_pct", 100.0 * rep.violation_rate)
        rows.add(f"{inc.name}.viol_pct_saved",
                 100.0 * (imp.violation_rate - conv.violation_rate), inc.note)
        out[inc.name] = {
            mode: {"violation_rate": rep.violation_rate,
                   "unit_seconds": rep.unit_seconds,
                   "p99_latency_s": rep.p99_latency_s,
                   "max_units": rep.max_units}
            for mode, rep in (("imperative", imp), ("converger", conv))}
        out[inc.name]["faults_fired"] = len(ctrl.plan.fault_events)
    return out


def _elastic_byte_identity(n: int, tmp: str, rows: Rows) -> None:
    """Same script, same seed, fresh run: the audit log must be IDENTICAL."""
    inc = INCIDENTS[1]                       # corr-az-loss
    paths = [os.path.join(tmp, f"rerun{i}.jsonl") for i in (0, 1)]
    for p in paths:
        _run_incident(n, inc, convergence=True, audit_path=p)
    blobs = [open(p, "rb").read() for p in paths]
    assert blobs[0] and blobs[0] == blobs[1], (
        "elastic re-run audit log diverged -- scripted incidents are no "
        "longer deterministic")
    rows.add("elastic.audit_byte_identical", 1.0,
             f"{len(blobs[0])} bytes, {inc.name}")


# ---------------------------------------------------------------------------------
# fleet drills: real engines, full invariant battery
# ---------------------------------------------------------------------------------

def _burst_workload(cfg, rng, n: int):
    """Front-loaded arrivals: two thirds of the stream lands in one burst at
    t=2 s (the correlated kill hits mid-burst), the tail trickles 1/s."""
    from repro.serving import Request
    cut = (2 * n) // 3
    reqs = []
    for i in range(n):
        plen = int(rng.integers(6, 48))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 12)),
            arrival_s=2.0 if i < cut else float(3 + i - cut)))
    return reqs


def _drill_corr_kill(ckpt_dir: str, n: int, tmp: str, rows: Rows) -> dict:
    """Correlated loss of 2-of-3 REAL replicas under burst load: full
    invariant battery, byte-identical audit re-run, and a strict violation
    win over the imperative baseline (same kills, no healing)."""
    from benchmarks.fleet_serving import _make_pool
    from repro.serving.fleet import FleetBackend

    def make_backend(on_step=None, audit_path=None, convergence=True):
        cfg, pool = _make_pool(0, ckpt_dir)
        reqs = _burst_workload(cfg, np.random.default_rng(7), n)
        return FleetBackend(
            pool, reqs, sla_s=FLEET_SLA_S, horizon_s=float(n + 30),
            policy=_HoldPolicy(), starting_replicas=3, max_replicas=3,
            provision_delay_s=2.0, adapt_period_s=2.0, app_window_s=4.0,
            decode_steps=2, converge=ConvergerConfig(build_timeout_s=30.0),
            convergence=convergence, calibrate=False, on_step=on_step,
            audit_path=audit_path)

    script = ChaosScript([ChaosAction(3.0, "corr_kill", frac=0.5)], seed=9)
    apath = os.path.join(tmp, "fleet_corr.jsonl")
    drill = ChaosDrill("fleet-corr-kill", make_backend, script,
                       audit_path=apath)
    report = drill.run()
    assert report.ok, report.summary()
    assert len(report.fired) == 1 and len(report.fired[0]["victims"]) == 2, \
        f"correlated kill did not take 2 replicas: {report.fired}"
    assert report.n_completed == n == report.n_reference, report.summary()

    # determinism gate: a fresh same-seed faulted run writes the same bytes
    script.reset()
    p2 = os.path.join(tmp, "fleet_corr_rerun.jsonl")
    conv_rep = make_backend(on_step=script.on_step, audit_path=p2).run()
    blobs = [open(p, "rb").read() for p in (apath, p2)]
    assert blobs[0] and blobs[0] == blobs[1], (
        "fleet re-run audit log diverged -- the drill is no longer "
        "deterministic (did calibrate=False stop pinning the landing clock?)")
    rows.add("fleet.audit_byte_identical", 1.0, f"{len(blobs[0])} bytes")

    # imperative baseline: same script, no desired state -- the dead
    # replicas stay dead and the burst drains on whatever survived
    script.reset()
    imp_rep = make_backend(on_step=script.on_step, convergence=False).run()
    assert conv_rep.violation_rate < imp_rep.violation_rate, (
        f"fleet-corr-kill: converger {conv_rep.violation_rate:.4f} !< "
        f"imperative {imp_rep.violation_rate:.4f}")
    rows.add("fleet-corr-kill.converger.viol_pct",
             100.0 * conv_rep.violation_rate)
    rows.add("fleet-corr-kill.imperative.viol_pct",
             100.0 * imp_rep.violation_rate)
    return {"violations": [str(v) for v in report.violations],
            "fired": report.fired, "n_completed": report.n_completed,
            "converger_violation_rate": conv_rep.violation_rate,
            "imperative_violation_rate": imp_rep.violation_rate,
            "audit_bytes": len(blobs[0])}


def _drill_floor_mid_retry(ckpt_dir: str, n: int, tmp: str,
                           rows: Rows) -> dict:
    """Webhook floor landing mid-retry on the real fleet: a kill's respawn
    fails (measured stuck build), the converger cancels and parks the pool
    behind a LONG backoff -- then the operator floor arrives and must
    supersede the stale retry state, relaunching immediately."""
    from benchmarks.fleet_serving import _make_pool
    from repro.serving.fleet import FleetBackend

    group = ScalingGroup.from_config({
        "name": "fleet-chaos",
        "pools": [{"name": "replica", "provision_delay_s": 2.0,
                   "min_units": 1, "max_units": 3}],
        "webhooks": [{"name": "surge", "hold_s": 30.0,
                      "targets": {"replica": 3}}],
    })

    def make_backend(on_step=None, audit_path=None):
        cfg, pool = _make_pool(0, ckpt_dir)
        spawns = [0]

        def third_spawn_fails():
            # spawns 1-2 bring up the starting fleet; the kill's respawn
            # (spawn 3) fails, so the heal sits in timeout -> cancel ->
            # backoff when the webhook floor lands
            spawns[0] += 1
            return spawns[0] == 3

        pool.spawn_fault = third_spawn_fails
        reqs = _burst_workload(cfg, np.random.default_rng(11), n)
        return FleetBackend(
            pool, reqs, sla_s=FLEET_SLA_S, horizon_s=float(n + 60),
            policy=_HoldPolicy(), starting_replicas=2, max_replicas=3,
            provision_delay_s=2.0, adapt_period_s=2.0, app_window_s=4.0,
            decode_steps=2,
            converge=ConvergerConfig(build_timeout_s=4.0, backoff_base_s=50.0,
                                     backoff_max_s=50.0, max_retries=10),
            group=group, calibrate=False, on_step=on_step,
            audit_path=audit_path)

    script = ChaosScript([
        ChaosAction(3.0, "kill", count=1),
        ChaosAction(12.0, "webhook", name="surge"),   # backoff holds to t=60
    ], seed=13)
    apath = os.path.join(tmp, "fleet_floor.jsonl")
    drill = ChaosDrill("fleet-floor-mid-retry", make_backend, script,
                       audit_path=apath)
    report = drill.run()
    assert report.ok, report.summary()
    assert report.n_completed == n == report.n_reference, report.summary()
    kinds = {r["kind"] for r in AuditLog.load(apath, verify=True)}
    assert "webhook" in kinds, "webhook fire never reached the audit log"
    assert "superseded" in kinds, (
        "floor raise did not supersede the in-flight retry backoff -- the "
        "fleet would have waited out a 50 s gate against operator intent")
    rows.add("fleet-floor-mid-retry.ok", 1.0,
             f"{len(report.fired)} actions, webhook superseded stale retry")
    return {"violations": [str(v) for v in report.violations],
            "fired": report.fired, "n_completed": report.n_completed,
            "audit_kinds": sorted(kinds)}


def run(quick: bool = False) -> Rows:
    import time
    banner("Chaos drills: scripted incidents, invariant-checked recovery")
    rows = Rows("chaos_drills")
    n_elastic = 2_000 if quick else 8_000
    n_fleet = 12 if quick else 24
    t0 = time.perf_counter()

    with tempfile.TemporaryDirectory() as tmp:
        incidents = _elastic_incidents(n_elastic, tmp, rows)
        _elastic_byte_identity(n_elastic, tmp, rows)
        with tempfile.TemporaryDirectory() as ckpt_dir:
            corr = _drill_corr_kill(ckpt_dir, n_fleet, tmp, rows)
            floor = _drill_floor_mid_retry(ckpt_dir, n_fleet, tmp, rows)
    wall = time.perf_counter() - t0
    rows.add("wall_s", wall)

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    payload = {
        "description": "chaos drills: 5 scripted elastic incidents "
                       "(imperative vs converger, strict violation wins, "
                       "full audit battery) + 2 real-fleet drills "
                       "(correlated kill under burst load, webhook floor "
                       "superseding a mid-flight retry) with byte-identical "
                       "same-seed audit re-runs on both backends",
        "n_requests": {"elastic": n_elastic, "fleet": n_fleet},
        "incidents": incidents,
        "fleet_drills": {"fleet-corr-kill": corr,
                         "fleet-floor-mid-retry": floor},
        "wall_s": wall,
    }
    with open(ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[artifact] {ARTIFACT}")
    return rows


if __name__ == "__main__":
    run(quick=bool(int(os.environ.get("BENCH_QUICK", "0"))))
