"""SSV-B ablation: appdata detection window length.

Paper: "In practice, windows of 60 seconds of length are not large enough for
efficiently detecting peaks ... the one that rendered the best results was the
one of 120 seconds" (too few tweets finish processing within 60 s of their
post time).
"""
from __future__ import annotations

from benchmarks.common import Rows, banner
from repro.core.autoscaler import AppDataPolicy, CompositePolicy, LoadPolicy
from repro.core.simulator import SimConfig, generate_trace, run_scenario
from repro.core.simulator.distributions import ServiceModel


def run(quick: bool = False) -> Rows:
    banner("SSV-B ablation: appdata window length (Spain)")
    rows = Rows("ablation_window")
    sm = ServiceModel()
    seeds = [0] if quick else [0, 1]
    for w in [60.0, 120.0, 180.0]:
        v = c = ups = 0.0
        for s in seeds:
            tr = generate_trace("spain", seed=s)
            pol = CompositePolicy([LoadPolicy(sm, quantile=0.99999),
                                   AppDataPolicy(extra_units=5)])
            r = run_scenario(tr, pol, SimConfig(app_window_s=w))
            v += 100.0 * r.violation_rate / len(seeds)
            c += r.cpu_hours / len(seeds)
            ups += r.n_decisions_up / len(seeds)
        note = "paper: 60s windows have too few completed tweets" if w == 60 \
            else ("paper: best" if w == 120 else "")
        rows.add(f"window{int(w)}.viol_pct", v, note)
        rows.add(f"window{int(w)}.cpu_hours", c)
    return rows


if __name__ == "__main__":
    run()
