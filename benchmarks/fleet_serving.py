"""Replica-fleet smoke benchmark: real multi-engine serving actuated by the
convergence plane, with HARD gates on the three properties the fleet layer
exists for (scripts/check.sh runs this in the full verify pass):

* **elastic throughput** -- aggregate WARM tokens/s over 2 replicas must be
  >= 1.5x the single-replica rate on the same workload.  On the time-sliced
  single-core runner each replica's rate is its tokens over ITS OWN stepping
  wall time (the per-host rate), so the fleet aggregate is the sum across
  replicas -- a scale-out that silently serialized through one engine, or a
  router that starves the second replica, fails CI rather than just getting
  slower;
* **lossless drain** -- a mid-burst DrainUnit (through the real
  FleetExecutor + CapacityPlan path) must migrate every in-flight request
  onto the survivor with BIT-IDENTICAL final outputs vs an unmigrated
  reference run, and the page free-lists of both engines must conserve
  (drained side back to empty, survivor invariant-clean);
* **measured provisioning** -- the fleet's RunReport must carry a
  provisioning delay measured at spawn (checkpoint load + remesh + engine
  build + probe-decode compile), not the configured guess.

Every run writes ``benchmarks/artifacts/BENCH_fleet.json`` (aggregate and
per-replica throughput, migration counts, measured vs configured delay)
which CI uploads alongside the other artifacts.
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np

from benchmarks.common import Rows, banner

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_fleet.json")

WALL_BOUND_S = 300.0          # generous CPU bound; normal runs are ~5x faster
SCALE_GATE = 1.5              # hard floor on 2-replica aggregate speedup
CONFIGURED_DELAY_S = 3.0      # the deliberate wrong guess phase C must beat


def _workload(cfg, rng, n):
    from repro.serving import Request
    reqs = []
    for i in range(n):
        plen = int(rng.integers(6, 48))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 12))))
    return reqs


def _make_pool(n_replicas: int, ckpt_dir: str):
    import jax

    from repro.checkpoint import CheckpointManager, save_checkpoint
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving import ServeConfig
    from repro.serving.fleet import ReplicaPool

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False)
    if mgr.latest() is None:
        params = model.init_params(jax.random.key(0))
        save_checkpoint(os.path.join(ckpt_dir, "ckpt_00000001.npz"),
                        params, step=1)
    pool = ReplicaPool(model, mgr,
                       ServeConfig(max_batch=4, max_len=128, decode_steps=4))
    for _ in range(n_replicas):
        rep, _ = pool.spawn()
        pool.serving.append(rep)
    return cfg, pool


def _drive_drained(pool, router, *, max_steps=10_000) -> None:
    """Step the whole fleet until every engine and backlog is empty."""
    for t in range(max_steps):
        router.dispatch(float(t))
        for r in list(pool.serving):
            r.step(float(t), decode_steps=r.eng.decode_steps)
        if not router.backlog and not any(r.eng.n_in_system
                                          for r in pool.serving):
            return
    raise RuntimeError("fleet failed to drain")


def _aggregate_tokens_per_s(pool) -> float:
    return sum(r.tokens_per_busy_s for r in pool.serving + pool.retired
               if r.busy_s > 0)


def _phase_throughput(ckpt_dir: str, n: int, rows: Rows) -> dict:
    """1 vs 2 replicas over the same workload: the fleet aggregate must
    scale.  Spawn's probe decode leaves each replica warm, so the measured
    window never includes compile."""
    from repro.serving.fleet import FleetRouter
    out = {}
    for n_rep in (1, 2):
        cfg, pool = _make_pool(n_rep, ckpt_dir)
        router = FleetRouter(pool)
        for r in _workload(cfg, np.random.default_rng(1), n):
            router.submit(r)
        _drive_drained(pool, router)
        done = sum(len(r.eng.completed) for r in pool.serving)
        assert done == n, f"{n_rep}-replica fleet dropped requests {done}/{n}"
        for r in pool.serving:
            r.eng.kv.check_invariants()
        agg = _aggregate_tokens_per_s(pool)
        per = {f"replica{r.rix}": {"tokens": r.tokens, "busy_s": r.busy_s,
                                   "tokens_per_s": r.tokens_per_busy_s}
               for r in pool.serving}
        out[n_rep] = {"aggregate_tokens_per_s": agg, "per_replica": per}
        rows.add(f"replicas{n_rep}.aggregate_tokens_per_s", agg)
        # the router must actually spread load: with 2 replicas both serve
        if n_rep == 2:
            assert all(r.tokens > 0 for r in pool.serving), (
                "router starved a replica: "
                + str({r.rix: r.tokens for r in pool.serving}))
    speedup = (out[2]["aggregate_tokens_per_s"]
               / out[1]["aggregate_tokens_per_s"])
    out["speedup"] = speedup
    rows.add("scale_speedup_2x", speedup, f"gate: >= {SCALE_GATE}x")
    assert speedup >= SCALE_GATE, (
        f"2-replica aggregate {out[2]['aggregate_tokens_per_s']:.1f} tok/s is "
        f"only {speedup:.2f}x the single replica -- fleet scale-out regressed")
    return out


def _phase_drain_migration(ckpt_dir: str, n: int, rows: Rows) -> dict:
    """Mid-burst DrainUnit through the FleetExecutor: every in-flight
    request migrates to the survivor and finishes with the exact tokens the
    unmigrated reference produced."""
    from repro.core.scaling import CapacityPlan, UnitPool
    from repro.serving.fleet import FLEET_POOL, FleetExecutor, FleetRouter

    # reference: the same workload on one replica, no migration
    cfg, ref_pool = _make_pool(1, ckpt_dir)
    ref_router = FleetRouter(ref_pool)
    reqs = _workload(cfg, np.random.default_rng(2), n)
    for r in reqs:
        ref_router.submit(r)
    _drive_drained(ref_pool, ref_router)
    reference = {r.rid: list(r.output)
                 for r in ref_pool.serving[0].eng.completed}

    # fleet of 2, drained to 1 mid-burst through the executor + plan
    cfg, pool = _make_pool(2, ckpt_dir)
    plan = CapacityPlan((UnitPool(FLEET_POOL, min_units=1, max_units=4),),
                        starting_units=2)
    executor = FleetExecutor(pool, plan)
    router = FleetRouter(pool)
    reqs2 = _workload(cfg, np.random.default_rng(2), n)
    for r in reqs2:
        router.submit(r)
    for t in range(3):                      # both replicas mid-decode
        router.dispatch(float(t))
        for r in list(pool.serving):
            r.step(float(t), decode_steps=2)
    victim = pool.serving[-1]
    in_flight = len(victim.eng.active)
    assert in_flight > 0, "drain happened with nothing in flight -- no test"
    took = executor.drain(FLEET_POOL, 1, 3.0)
    assert took == 1 and plan.total_live == 1
    assert victim not in pool.serving and not victim.eng.active
    victim.eng.kv.check_invariants()        # drained side: free list whole
    assert int(victim.eng.kv.held.sum()) == 0 and \
        int(victim.eng.kv.worst.sum()) == 0, "drained engine leaked pages"
    _drive_drained(pool, router)
    survivor = pool.serving[0]
    survivor.eng.kv.check_invariants()      # survivor side conserves too
    done = {r.rid: list(r.output)
            for rep in pool.serving + pool.retired
            for r in rep.eng.completed}
    assert len(done) == n, f"drain lost requests: {len(done)}/{n}"
    mismatches = [rid for rid in reference if done[rid] != reference[rid]]
    assert not mismatches, (
        f"migrated outputs diverged from the unmigrated reference for "
        f"rids {mismatches[:5]} -- bit-exact drain is broken")
    rows.add("drain.in_flight_migrated", float(in_flight),
             "requests mid-decode on the drained replica")
    rows.add("drain.bit_identical", 1.0, f"all {n} outputs match reference")
    return {"in_flight_migrated": in_flight, "n_requests": n,
            "bit_identical": True}


def _phase_measured_delay(ckpt_dir: str, n: int, rows: Rows) -> dict:
    """FleetBackend end-to-end: the RunReport's provisioning delay is the
    spawn-measured one, not the configured guess."""
    cfg, pool = _make_pool(0, ckpt_dir)
    workload = _workload(cfg, np.random.default_rng(3), n)
    for i, r in enumerate(workload):
        r.arrival_s = float(i // 4)
    from repro.serving.fleet import FleetBackend
    be = FleetBackend(pool, workload, sla_s=30.0, horizon_s=float(n),
                      starting_replicas=1, max_replicas=3,
                      provision_delay_s=CONFIGURED_DELAY_S,
                      adapt_period_s=2.0, app_window_s=4.0, decode_steps=2)
    rep = be.run()
    assert rep.n_done == n, f"fleet backend dropped requests {rep.n_done}/{n}"
    measured = rep.pool_provision_delay_s.get("replica")
    assert measured is not None and measured > 0.0, (
        "RunReport carries no measured provisioning delay -- the executor "
        "stopped calibrating from real spawns")
    assert abs(measured - CONFIGURED_DELAY_S) > 1e-6, (
        "measured delay equals the configured guess exactly -- suspicious")
    assert "measured_delay_s.replica" in rep.summary()
    rows.add("measured_delay_s", measured,
             f"configured guess was {CONFIGURED_DELAY_S}s")
    rows.add("fleet_peak_replicas", float(rep.max_units))
    return {"measured_delay_s": measured,
            "configured_delay_s": CONFIGURED_DELAY_S,
            "peak_replicas": rep.max_units, "n_done": rep.n_done}


def run(quick: bool = False) -> Rows:
    import time
    banner("Replica fleet (spawn / route / drain-migrate / measured delay)")
    rows = Rows("fleet_serving")
    n = 16 if quick else 32
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        thr = _phase_throughput(ckpt_dir, n, rows)
        mig = _phase_drain_migration(ckpt_dir, max(n // 2, 8), rows)
        dly = _phase_measured_delay(ckpt_dir, min(n, 16), rows)
    wall = time.perf_counter() - t0
    rows.add("wall_s", wall)
    assert wall < WALL_BOUND_S, f"fleet smoke took {wall:.1f}s > {WALL_BOUND_S}s"

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump({
            "workload": {"n_requests": n, "quick": quick,
                         "arch": "smollm-135m (smoke)", "max_batch": 4,
                         "max_len": 128,
                         "timing": "warm (spawn probe compiles the loop)"},
            "throughput": {str(k): v for k, v in thr.items()},
            "scale_gate": SCALE_GATE,
            "drain_migration": mig,
            "measured_delay": dly,
            "wall_s": wall,
        }, f, indent=2)
    print(f"[artifact] {ARTIFACT}")
    return rows


if __name__ == "__main__":
    run(quick=bool(int(os.environ.get("BENCH_QUICK", "0"))))
