"""Benchmark harness: one module per paper table/figure + framework benches.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick|--smoke] [--only NAME]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "littles_law",
    "table1_correlation",
    "fig3_burst_lead",
    "fig7_threshold_vs_load",
    "fig8_appdata",
    "ablation_window",
    "headline_claims",
    "elastic_serving",
    "serving_engine",
    "fleet_serving",
    "policy_table",
    "convergence_faults",
    "chaos_drills",
    "kernels_bench",
]

#: fast subset exercising every control-plane path (simulator backend, elastic
#: backend, multi-channel signals, and the priced spot-revocation capacity
#: scenario incl. the live serve backend) -- the scripts/check.sh verify gate;
#: policy_table emits the benchmarks/artifacts/ JSON that CI uploads, and
#: check.sh additionally runs serving_engine (which writes BENCH_serving.json
#: and enforces the tokens/s floor vs the pre-device-resident baseline)
SMOKE_MODULES = ["littles_law", "fig8_appdata", "elastic_serving",
                 "policy_table", "convergence_faults"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced seeds/configs")
    ap.add_argument("--smoke", action="store_true",
                    help="fast verify pass: quick mode over a reduced module set")
    ap.add_argument("--only", default=None, help="run a single benchmark module")
    args = ap.parse_args()
    if args.smoke:
        args.quick = True

    names = [args.only] if args.only else (SMOKE_MODULES if args.smoke else MODULES)
    t0 = time.time()
    failures = []
    for name in names:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(quick=args.quick)
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"{name},FAILED,{e!r}")
    print(f"\nbenchmarks done in {time.time() - t0:.1f}s")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
