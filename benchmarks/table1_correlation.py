"""Table I: Pearson correlation of per-minute sentiment with tweet volume at lags
0..10 minutes, on the Brazil vs Spain trace (ensemble over seeds)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, banner
from repro.core.signals import lag_correlation_table
from repro.core.simulator import generate_trace

PAPER = [0.79, 0.78, 0.76, 0.76, 0.76, 0.75, 0.75, 0.74, 0.72, 0.71, 0.70]


def run(quick: bool = False) -> Rows:
    banner("Table I: sentiment<->volume lag correlation (Spain)")
    rows = Rows("table1")
    seeds = [0] if quick else [0, 1, 2, 3, 4]
    acc = np.zeros(11)
    for seed in seeds:
        tr = generate_trace("spain", seed=seed)
        acc += np.array([c for _, c in lag_correlation_table(tr)])
    acc /= len(seeds)
    for lag in range(11):
        rows.add(f"pearson_lag{lag}", float(acc[lag]), f"paper {PAPER[lag]}")
    rows.add("decay_ratio_r10_over_r0", float(acc[10] / acc[0]),
             f"paper {PAPER[10] / PAPER[0]:.2f}")
    return rows


if __name__ == "__main__":
    run()
