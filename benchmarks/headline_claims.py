"""The abstract's headline claims:

* "reduce the number of SLA violations by up to 95%"  (appdata vs threshold, Spain)
* "reduce resource requirements by up to 33%"          (load vs threshold@60, Spain;
   43% on Uruguay per §V-A)
"""
from __future__ import annotations

from benchmarks.common import Rows, banner
from repro.core.autoscaler import AppDataPolicy, CompositePolicy, LoadPolicy, ThresholdPolicy
from repro.core.simulator import SimConfig, generate_trace, run_scenario
from repro.core.simulator.distributions import ServiceModel


def run(quick: bool = False) -> Rows:
    banner("Headline claims (abstract / SSV)")
    rows = Rows("headline")
    sm = ServiceModel()
    cfg = SimConfig()
    seeds = [0] if quick else [0, 1]

    def avg(match, mk):
        v = c = 0.0
        for s in seeds:
            tr = generate_trace(match, seed=s)
            r = run_scenario(tr, mk(), cfg)
            v += 100.0 * r.violation_rate / len(seeds)
            c += r.cpu_hours / len(seeds)
        return v, c

    for match, paper_save in [("uruguay", 43.0), ("spain", 33.0)]:
        lv, lc = avg(match, lambda: LoadPolicy(sm, quantile=0.99999))
        tv, tc = avg(match, lambda: ThresholdPolicy(0.60))
        save = 100.0 * (tc - lc) / tc
        rows.add(f"{match}.load_vs_thr60_cpu_saving_pct", save, f"paper {paper_save}")
        rows.add(f"{match}.load.viol_pct", lv)
        rows.add(f"{match}.thr60.viol_pct", tv)

    av, ac = avg("spain", lambda: CompositePolicy(
        [LoadPolicy(sm, quantile=0.99999), AppDataPolicy(extra_units=10)]))
    lv, lc = avg("spain", lambda: LoadPolicy(sm, quantile=0.99999))
    tv, tc = avg("spain", lambda: ThresholdPolicy(0.60))
    rows.add("spain.appdata10.viol_pct", av, "paper 0.12")
    rows.add("spain.appdata10.cpu_hours", ac, "paper 34.78")
    rows.add("spain.appdata_vs_load_viol_reduction_pct",
             100.0 * (lv - av) / max(lv, 1e-9), "paper 92.81")
    rows.add("spain.appdata_vs_thr60_viol_reduction_pct",
             100.0 * (tv - av) / max(tv, 1e-9), "paper 95.24")
    rows.add("spain.appdata_vs_thr60_cost_increase_pct",
             100.0 * (ac - tc) / tc, "paper 12.05")
    return rows


if __name__ == "__main__":
    run()
