"""Cross-backend policy comparison table (ROADMAP benchmarks item).

The same policy families drive every :class:`repro.core.scaling.ScalableBackend`
-- the tweet simulator (unit = CPU), the elastic replica fleet (unit =
replica), and the LIVE serving engine (unit = decode slot, real JAX
prefill/decode with engine-computed logprob scores) -- and the per-backend
RunReports are flattened through :func:`repro.core.scaling.compare` into one
table, emitted as a JSON artifact under ``benchmarks/artifacts/``.

This is the redesign's payoff made visible: one control plane, one report
schema, three very different service processes in a single comparison.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Rows, banner
from repro.core.autoscaler import (
    AppDataPolicy,
    CompositePolicy,
    LoadPolicy,
    TargetTrackingPolicy,
    ThresholdPolicy,
)
from repro.core.scaling import RunReport, compare

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "policy_table.json")


def _simulator_reports(quick: bool) -> dict[str, RunReport]:
    from repro.core.simulator import SimConfig, generate_trace, run_scenario
    from repro.core.simulator.distributions import ServiceModel
    sm = ServiceModel()
    cfg = SimConfig()
    # england is the smallest calibrated trace (~370k tweets vs uruguay's 1.8M)
    trace = generate_trace("england" if quick else "uruguay", seed=0)
    mk = {
        "threshold70": lambda: ThresholdPolicy(0.7),
        "target75": lambda: TargetTrackingPolicy(target=0.75),
        "load+appdata": lambda: CompositePolicy(
            [LoadPolicy(sm, quantile=0.99999), AppDataPolicy(extra_units=1)]),
    }
    return {f"sim.{name}": run_scenario(trace, factory(), cfg)
            for name, factory in mk.items()}


def _elastic_reports(quick: bool) -> dict[str, RunReport]:
    from benchmarks.elastic_serving import _ReplicaLoadPolicy, _workload
    from repro.core.elastic import ClusterConfig, ElasticCluster
    cfg = ClusterConfig()
    n = 2_000 if quick else 8_000
    out: dict[str, RunReport] = {}
    for name, mk in [
        ("threshold70", lambda h: ThresholdPolicy(0.7)),
        ("target75", lambda h: TargetTrackingPolicy(target=0.75)),
        ("load+appdata", lambda h: CompositePolicy([
            _ReplicaLoadPolicy(h, quantile=0.99, sla_s=cfg.sla_s),
            AppDataPolicy(extra_units=4, jump=0.5)])),
    ]:
        holder = [None]
        cluster = ElasticCluster(cfg, mk(holder), _workload(n=n))
        holder[0] = cluster
        out[f"elastic.{name}"] = cluster.run()
    return out


def _serve_reports(quick: bool) -> dict[str, RunReport]:
    """Live backend: a real ServingEngine per policy (paged KV cache, engine
    logprob scores feeding the output_score channel)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.core.scaling import make_policy
    from repro.data import request_stream
    from repro.launch.serve import ServeBackend
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_req, horizon = (12, 20.0) if quick else (30, 40.0)
    out: dict[str, RunReport] = {}
    for name in ("threshold", "target"):
        eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_len=128))
        reqs = []
        stream = request_stream(n_requests=n_req, seed=0, mean_prompt=12,
                                mean_decode=6, burst_times=(horizon * 0.5,),
                                horizon_s=horizon)
        for i, (t, p, d) in enumerate(stream):
            reqs.append(Request(
                rid=i, arrival_s=t,
                prompt=np.random.default_rng(i).integers(
                    0, cfg.vocab, min(p, 48)).astype(np.int32),
                max_new_tokens=max(min(d, 24), 1)))
        backend = ServeBackend(eng, reqs, sla_s=15.0, horizon_s=horizon,
                               policy=make_policy(name))
        out[f"serve.{name}"] = backend.run()
    return out


def run(quick: bool = False) -> Rows:
    banner("Cross-backend policy table (simulator / elastic / live serve)")
    rows = Rows("policy_table")
    reports: dict[str, RunReport] = {}
    reports.update(_simulator_reports(quick))
    reports.update(_elastic_reports(quick))
    reports.update(_serve_reports(quick))

    table = compare(reports)
    for row in table:
        rows.add(f"{row['name']}.viol_pct", row["violation_pct"])
        rows.add(f"{row['name']}.p99_latency_s", row["p99_latency_s"])
        rows.add(f"{row['name']}.max_units", float(row["max_units"]))

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    payload = {
        "description": "same policy families across every ScalableBackend "
                       "(unit: sim=CPU, elastic=replica, serve=decode slot)",
        "columns": sorted({k for r in table for k in r}),
        "rows": [{k: (v.item() if isinstance(v, np.generic) else v)
                  for k, v in r.items()} for r in table],
    }
    with open(ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    rows.add("artifact_rows", float(len(table)), ARTIFACT)
    return rows


if __name__ == "__main__":
    run()
