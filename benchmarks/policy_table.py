"""Cross-backend policy comparison table (ROADMAP benchmarks item).

The same policy families drive every :class:`repro.core.scaling.ScalableBackend`
-- the tweet simulator (unit = CPU), the elastic replica fleet (unit =
replica), and the LIVE serving engine (unit = decode slot, real JAX
prefill/decode with engine-computed logprob scores) -- and the per-backend
RunReports are flattened through :func:`repro.core.scaling.compare` into one
table, emitted as a JSON artifact under ``benchmarks/artifacts/``.

Every row now carries a ``cost`` column priced from the per-pool capacity
accounting, and a *spot-revocation* scenario runs on both simulation
backends: a cheap preemptible pool alongside on-demand capacity, a
cheapest-first router buying into it, and the seeded revocation process
killing those units mid-burst -- the controller re-buys, the report shows
the on-demand/spot cost split and the revocation count.

This is the redesign's payoff made visible: one control plane, one report
schema, three very different service processes -- now in one *priced*
comparison.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Rows, banner
from repro.core.autoscaler import (
    AppDataPolicy,
    CheapestFirstRouter,
    CompositePolicy,
    LoadPolicy,
    TargetTrackingPolicy,
    ThresholdPolicy,
)
from repro.core.scaling import RunReport, Sla, UnitPool, compare

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "policy_table.json")

#: ~3x price ratio between guaranteed and preemptible capacity, the typical
#: cloud spot discount; the revocation hazard (mean spot-unit lifetime) is
#: sized per backend so units bought for a burst are revoked inside it
ON_DEMAND_RATE = 3.0
SPOT_RATE = 1.0


def _spot_pools(max_spot: int, *, delay_s: float, lifetime_s: float,
                min_od: int = 1, seed: int = 7) -> tuple[UnitPool, ...]:
    return (
        UnitPool("on-demand", provision_delay_s=delay_s,
                 cost_rate=ON_DEMAND_RATE, min_units=min_od),
        UnitPool("spot", provision_delay_s=delay_s, cost_rate=SPOT_RATE,
                 max_units=max_spot, preemptible=True,
                 revoke_rate=1.0 / lifetime_s, revoke_seed=seed),
    )


def _simulator_reports(quick: bool) -> dict[str, RunReport]:
    from repro.core.simulator import SimConfig, generate_trace, run_scenario
    from repro.core.simulator.distributions import ServiceModel
    sm = ServiceModel()
    cfg = SimConfig()
    # england is the smallest calibrated trace (~370k tweets vs uruguay's 1.8M)
    trace = generate_trace("england" if quick else "uruguay", seed=0)
    mk = {
        "threshold70": lambda: ThresholdPolicy(0.7),
        "target75": lambda: TargetTrackingPolicy(target=0.75),
        "load+appdata": lambda: CompositePolicy(
            [LoadPolicy(sm, quantile=0.99999), AppDataPolicy(extra_units=1)]),
    }
    return {f"sim.{name}": run_scenario(trace, factory(), cfg)
            for name, factory in mk.items()}


def _elastic_reports(quick: bool) -> dict[str, RunReport]:
    from benchmarks.elastic_serving import _ReplicaLoadPolicy, _workload
    from repro.core.elastic import ClusterConfig, ElasticCluster
    cfg = ClusterConfig()
    n = 2_000 if quick else 8_000
    out: dict[str, RunReport] = {}
    for name, mk in [
        ("threshold70", lambda h: ThresholdPolicy(0.7)),
        ("target75", lambda h: TargetTrackingPolicy(target=0.75)),
        ("load+appdata", lambda h: CompositePolicy([
            _ReplicaLoadPolicy(h, quantile=0.99, sla_s=cfg.sla_s),
            AppDataPolicy(extra_units=4, jump=0.5)])),
    ]:
        holder = [None]
        cluster = ElasticCluster(cfg, mk(holder), _workload(n=n))
        holder[0] = cluster
        out[f"elastic.{name}"] = cluster.run()
    return out


def _spot_reports(quick: bool) -> dict[str, RunReport]:
    """Spot-revocation scenario on both simulation backends: the same
    threshold rule once on pure on-demand capacity and once behind a
    cheapest-first router over (on-demand, spot) pools whose preemptible
    units get revoked mid-burst."""
    from benchmarks.elastic_serving import _workload
    from repro.core.elastic import ClusterConfig, ElasticCluster
    from repro.core.simulator import SimConfig, generate_trace, run_scenario

    out: dict[str, RunReport] = {}
    # -- simulator (unit = CPU): price the paper's Table III configuration ---------
    trace = generate_trace("england" if quick else "uruguay", seed=0)
    sla = Sla(300.0, {"full_pipeline": 150.0})     # tighter deadline for the
    # tweets that traverse the full operator graph -- per-class SLA reporting
    base = SimConfig(sla=sla,
                     pools=(UnitPool("on-demand", provision_delay_s=60.0,
                                     cost_rate=ON_DEMAND_RATE, min_units=1),))
    out["sim.spot.ondemand-only"] = run_scenario(
        trace, ThresholdPolicy(0.7), base)
    spot = SimConfig(sla=sla, pools=_spot_pools(8, delay_s=60.0,
                                                lifetime_s=600.0))
    out["sim.spot.cheapest"] = run_scenario(
        trace, CheapestFirstRouter(ThresholdPolicy(0.7)), spot)

    # -- elastic fleet (unit = replica) --------------------------------------------
    n = 2_000 if quick else 8_000
    ecfg = ClusterConfig()
    e_base = ClusterConfig(pools=(
        UnitPool("on-demand", provision_delay_s=ecfg.provision_delay_s,
                 cost_rate=ON_DEMAND_RATE, min_units=1),))
    out["elastic.spot.ondemand-only"] = ElasticCluster(
        e_base, ThresholdPolicy(0.7), _workload(n=n)).run()
    # the ~20-min request stream needs a proportionally shorter spot lifetime
    # for churn to land inside its bursts
    e_spot = ClusterConfig(pools=_spot_pools(
        16, delay_s=ecfg.provision_delay_s, lifetime_s=120.0))
    out["elastic.spot.cheapest"] = ElasticCluster(
        e_spot, CheapestFirstRouter(ThresholdPolicy(0.7)), _workload(n=n)).run()
    return out


def _serve_reports(quick: bool) -> dict[str, RunReport]:
    """Live backend: a real ServingEngine per policy (paged KV cache, engine
    logprob scores feeding the output_score channel)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.core.scaling import make_policy
    from repro.data import request_stream
    from repro.launch.serve import ServeBackend
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_req, horizon = (12, 20.0) if quick else (30, 40.0)
    out: dict[str, RunReport] = {}
    for name in ("threshold", "target"):
        eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_len=128))
        reqs = []
        stream = request_stream(n_requests=n_req, seed=0, mean_prompt=12,
                                mean_decode=6, burst_times=(horizon * 0.5,),
                                horizon_s=horizon)
        for i, (t, p, d) in enumerate(stream):
            reqs.append(Request(
                rid=i, arrival_s=t,
                prompt=np.random.default_rng(i).integers(
                    0, cfg.vocab, min(p, 48)).astype(np.int32),
                max_new_tokens=max(min(d, 24), 1)))
        backend = ServeBackend(eng, reqs, sla_s=15.0, horizon_s=horizon,
                               policy=make_policy(name))
        out[f"serve.{name}"] = backend.run()
    return out


def run(quick: bool = False) -> Rows:
    banner("Cross-backend policy table (simulator / elastic / live serve)")
    rows = Rows("policy_table")
    reports: dict[str, RunReport] = {}
    reports.update(_simulator_reports(quick))
    reports.update(_elastic_reports(quick))
    reports.update(_spot_reports(quick))
    reports.update(_serve_reports(quick))

    table = compare(reports)
    for row in table:
        rows.add(f"{row['name']}.viol_pct", row["violation_pct"])
        rows.add(f"{row['name']}.p99_latency_s", row["p99_latency_s"])
        rows.add(f"{row['name']}.max_units", float(row["max_units"]))
        rows.add(f"{row['name']}.cost", row["cost"])
        if row.get("n_revocations"):
            rows.add(f"{row['name']}.n_revocations",
                     float(row["n_revocations"]))
        if "worst_class_viol_pct" in row:
            rows.add(f"{row['name']}.worst_class_viol_pct",
                     row["worst_class_viol_pct"], str(row["worst_class"]))

    # the preemptible pool must actually have been revoked mid-burst, and the
    # mixed fleet must undercut the pure on-demand bill on both backends
    for bk in ("sim", "elastic"):
        assert reports[f"{bk}.spot.cheapest"].n_revocations > 0, bk
        saving = (reports[f"{bk}.spot.ondemand-only"].cost
                  - reports[f"{bk}.spot.cheapest"].cost)
        assert saving > 0.0, f"{bk}: mixed fleet cost more than on-demand"
        rows.add(f"{bk}.spot.cost_saving", saving)

    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    payload = {
        "description": "same policy families across every ScalableBackend "
                       "(unit: sim=CPU, elastic=replica, serve=decode slot)",
        "columns": sorted({k for r in table for k in r}),
        "rows": [{k: (v.item() if isinstance(v, np.generic) else v)
                  for k, v in r.items()} for r in table],
    }
    with open(ARTIFACT, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    rows.add("artifact_rows", float(len(table)), ARTIFACT)
    return rows


if __name__ == "__main__":
    run()
