"""Elastic serving cluster: policy behaviour + SLA/cost accounting."""
import numpy as np
import pytest

from repro.core.autoscaler import AppDataPolicy, CompositePolicy, ThresholdPolicy
from repro.core.elastic import ClusterConfig, ElasticCluster, ServeRequest


def _requests(n=2000, horizon=400.0, burst_at=200.0, seed=0):
    rng = np.random.default_rng(seed)
    t_axis = np.arange(int(horizon))
    lam = np.ones(int(horizon))
    prof = np.where(t_axis < burst_at,
                    np.exp(-((t_axis - burst_at) ** 2) / (2 * 20.0 ** 2)),
                    np.exp(-(t_axis - burst_at) / 60.0))
    lam *= 1.0 + 4.0 * prof
    lam *= n / lam.sum()
    out, rid = [], 0
    for sec, l in enumerate(lam):
        for _ in range(rng.poisson(l)):
            hot = burst_at - 70 <= sec <= burst_at + 50
            out.append(ServeRequest(
                rid=rid, arrival_s=sec + rng.random(),
                prefill_len=int(rng.exponential(2000)) + 128,
                decode_len=int(rng.exponential(64)) + 8,
                score=float(np.clip((0.9 if hot else 0.3) + rng.normal(0, .05), 0, 1))))
            rid += 1
    return out


def test_cluster_completes_all_requests():
    reqs = _requests(800)
    c = ElasticCluster(ClusterConfig(), ThresholdPolicy(0.7), reqs)
    res = c.run()
    assert res["n_done"] == len(reqs)
    assert res["chip_hours"] > 0


def test_appdata_preprovisions_on_output_signal():
    reqs = _requests(3000)
    cfg = ClusterConfig()
    base = ElasticCluster(cfg, ThresholdPolicy(0.7), _requests(3000))
    r_thr = base.run()
    comp = CompositePolicy([ThresholdPolicy(0.7), AppDataPolicy(extra_units=4)])
    r_app = ElasticCluster(cfg, comp, _requests(3000)).run()
    # the application-data trigger should not hurt and typically helps
    assert r_app["violation_rate"] <= r_thr["violation_rate"] + 0.02
    assert r_app["max_replicas"] >= r_thr["max_replicas"]


def test_replica_floor_and_scale_down():
    reqs = _requests(300, horizon=600.0)
    res = ElasticCluster(ClusterConfig(starting_replicas=4),
                         ThresholdPolicy(0.9), reqs).run()
    assert res["n_scale_downs"] > 0            # idle fleet shrinks
    assert res["n_done"] == len(reqs)
