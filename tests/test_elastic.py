"""Elastic serving cluster: policy behaviour + SLA/cost accounting."""
import time

import numpy as np
import pytest

from repro.core.autoscaler import (
    AppDataPolicy,
    CompositePolicy,
    TargetTrackingPolicy,
    ThresholdPolicy,
)
from repro.core.elastic import (
    ClusterConfig,
    ElasticCluster,
    ServeRequest,
    measure_provision_delay,
    provisioned_cluster_config,
)


def _requests(n=2000, horizon=400.0, burst_at=200.0, seed=0):
    rng = np.random.default_rng(seed)
    t_axis = np.arange(int(horizon))
    lam = np.ones(int(horizon))
    prof = np.where(t_axis < burst_at,
                    np.exp(-((t_axis - burst_at) ** 2) / (2 * 20.0 ** 2)),
                    np.exp(-(t_axis - burst_at) / 60.0))
    lam *= 1.0 + 4.0 * prof
    lam *= n / lam.sum()
    out, rid = [], 0
    for sec, l in enumerate(lam):
        for _ in range(rng.poisson(l)):
            hot = burst_at - 70 <= sec <= burst_at + 50
            out.append(ServeRequest(
                rid=rid, arrival_s=sec + rng.random(),
                prefill_len=int(rng.exponential(2000)) + 128,
                decode_len=int(rng.exponential(64)) + 8,
                score=float(np.clip((0.9 if hot else 0.3) + rng.normal(0, .05), 0, 1))))
            rid += 1
    return out


def test_cluster_completes_all_requests():
    reqs = _requests(800)
    c = ElasticCluster(ClusterConfig(), ThresholdPolicy(0.7), reqs)
    res = c.run()
    assert res["n_done"] == len(reqs)
    assert res["chip_hours"] > 0


def test_appdata_preprovisions_on_output_signal():
    cfg = ClusterConfig()
    base = ElasticCluster(cfg, ThresholdPolicy(0.7), _requests(3000))
    r_thr = base.run()
    comp = CompositePolicy([ThresholdPolicy(0.7), AppDataPolicy(extra_units=4)])
    r_app = ElasticCluster(cfg, comp, _requests(3000)).run()
    # the application-data trigger should not hurt and typically helps
    assert r_app["violation_rate"] <= r_thr["violation_rate"] + 0.02
    assert r_app["max_replicas"] >= r_thr["max_replicas"]


def test_replica_floor_and_scale_down():
    reqs = _requests(300, horizon=600.0)
    res = ElasticCluster(ClusterConfig(starting_replicas=4),
                         ThresholdPolicy(0.9), reqs).run()
    assert res["n_scale_downs"] > 0            # idle fleet shrinks
    assert res["n_done"] == len(reqs)


def test_slot_cap_staggers_equal_work_batches():
    """Admission is slot-capped: 3 * max_slots identical requests arriving at
    once drain in (at least) three distinct FIFO waves -- without the cap,
    equal-work requests would all water-fill together and finish in one step."""
    spec = ClusterConfig().replica
    reqs = [ServeRequest(rid=i, arrival_s=0.5, prefill_len=1000, decode_len=32)
            for i in range(3 * spec.max_slots)]
    res = ElasticCluster(ClusterConfig(), ThresholdPolicy(0.7), reqs).run()
    assert res["n_done"] == len(reqs)
    assert int(res.in_system_t.max()) == len(reqs)
    done_times = np.array([r.done_s for r in reqs])
    assert np.unique(done_times).size >= 3
    assert done_times[0] < done_times[-1]          # FIFO order across waves


def test_class_model_quantile_cache():
    """The sorted-sample cache must match np.quantile on the live sample set,
    through observes (invalidation) and the trim at 50k samples."""
    from repro.core.elastic import ReplicaSpec
    from repro.core.elastic.cluster import _ClassModel
    rng = np.random.default_rng(0)
    m = _ClassModel(ReplicaSpec())
    m.observe_seconds(rng.exponential(1.0, size=1000))
    for q in (0.5, 0.9, 0.99):
        assert m.quantile_seconds(q) == pytest.approx(
            float(np.quantile(np.asarray(m._samples), q)))
    # repeated reads hit the cache, observes invalidate it
    m.quantile_seconds(0.9)
    m.observe_seconds(np.array([100.0]))
    assert m.quantile_seconds(1.0) == pytest.approx(100.0)
    m.observe(ServeRequest(rid=0, arrival_s=0.0, prefill_len=500_000,
                           decode_len=10_000))
    assert m.quantile_seconds(1.0) == pytest.approx(max(m._samples))
    # trim at 50k: quantiles track the surviving samples
    m.observe_seconds(rng.exponential(1.0, size=60_000))
    assert len(m._samples) <= 50_000
    for q in (0.1, 0.9):
        assert m.quantile_seconds(q) == pytest.approx(
            float(np.quantile(np.asarray(m._samples), q)))
    # one bulk observe far past the cap (e.g. a 250k-request stream priced at
    # construction) must still land under it
    m2 = _ClassModel(ReplicaSpec())
    m2.observe_seconds(rng.exponential(1.0, size=250_000))
    assert len(m2._samples) <= 50_000
    assert m2.quantile_seconds(0.5) == pytest.approx(
        float(np.quantile(np.asarray(m2._samples), 0.5)))


def test_100k_request_stream_completes_in_seconds():
    """Acceptance: a 100k-request overload stream through the vectorized
    water-filling backend finishes well under 30 s wall."""
    from benchmarks.elastic_serving import _scale_workload
    reqs = _scale_workload(100_000)
    clu = ElasticCluster(ClusterConfig(max_replicas=96, starting_replicas=16),
                         TargetTrackingPolicy(target=0.75), reqs)
    t0 = time.perf_counter()
    res = clu.run()
    wall = time.perf_counter() - t0
    assert res.n_done == 100_000
    assert np.allclose(res.consumed_t,
                       np.minimum(res.demand_t, res.capacity_t))
    assert wall < 30.0, f"100k-request run took {wall:.1f}s"


def test_measured_provision_delay_feeds_cluster_config():
    """ROADMAP "live-backend depth": the remesh provisioning cost is measured
    on the real JAX path and wired into ClusterConfig.provision_delay_s."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    devs = jax.devices()
    dt, mesh, params2 = measure_provision_delay(
        model, params, devices=devs[:1], model_parallel=1)
    assert dt > 0.0
    assert mesh.devices.size == 1
    # re-placed params still serve a forward on the new mesh
    assert jax.tree.all(jax.tree.map(
        lambda a, b: a.shape == b.shape, params, params2))
    base = ClusterConfig()
    ccfg = provisioned_cluster_config(base, dt)
    assert ccfg.provision_delay_s == pytest.approx(max(dt, 1.0))
    assert ccfg.replica == base.replica          # only the delay changed
    # the measured config drives a real cluster run
    reqs = _requests(300)
    res = ElasticCluster(ccfg, ThresholdPolicy(0.7), reqs).run()
    assert res.n_done == len(reqs)
