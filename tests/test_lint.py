"""Tests for the replint static-analysis engine (src/repro/lint).

Covers: the rule corpus (every rule fires on its fixture and stays silent
on the clean twin), suppression handling (reasoned, reasonless, unused,
ALL), call-graph jit-reachability (direct jax.jit, via functools.partial,
via self.method, via lax bodies, via the `# replint: traced` marker), the
staticness classifier's judgment calls, and the CLI/JSON surface that
scripts/check.sh and CI rely on."""

import ast
import json
import textwrap
from pathlib import Path

import pytest

from repro.lint import lint_paths
from repro.lint.callgraph import build_graph, build_imports
from repro.lint.engine import build_context, parse_comments
from repro.lint.rules import ALL_RULES, get_rule
from repro.lint.selftest import SELFTEST_IDS, check_rule

REPO = Path(__file__).resolve().parent.parent


def _lint_src(tmp_path, source, name="mod.py", **kw):
    f = tmp_path / name
    f.write_text(textwrap.dedent(source))
    kw.setdefault("respect_scope", False)
    return lint_paths([str(f)], root=tmp_path, **kw)


def _rules_of(report):
    return [f.rule for f in report.findings]


# ---------------------------------------------------------------------------------
# rule corpus
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("rule_id", SELFTEST_IDS)
def test_rule_corpus(rule_id):
    """Every rule fires on its *_fire.py fixture and is silent on the
    *_clean.py twin."""
    assert check_rule(rule_id, REPO) == []


def test_every_rule_has_an_id_and_description():
    ids = [r.id for r in ALL_RULES]
    assert len(ids) == len(set(ids))
    for r in ALL_RULES:
        assert r.description and r.name
    assert get_rule("TRC101") is get_rule("host-sync")


# ---------------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------------

def test_reasoned_suppression_silences_finding(tmp_path):
    report = _lint_src(tmp_path, """
        def f(plan):
            plan._x = 1  # replint: disable=CPL303 -- test: exercising the API
        """)
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["CPL303"]
    assert report.suppressed[0].reason == "test: exercising the API"


def test_reasonless_suppression_is_itself_a_finding(tmp_path):
    report = _lint_src(tmp_path, """
        def f(plan):
            plan._x = 1  # replint: disable=CPL303
        """)
    assert _rules_of(report) == ["REP001"]          # CPL303 still suppressed
    assert [f.rule for f in report.suppressed] == ["CPL303"]


def test_unused_suppression_is_flagged(tmp_path):
    report = _lint_src(tmp_path, """
        def f():
            return 1  # replint: disable=TRC101 -- nothing syncs here
        """)
    assert _rules_of(report) == ["REP002"]


def test_own_line_suppression_covers_next_line(tmp_path):
    report = _lint_src(tmp_path, """
        def f(plan):
            # replint: disable=CPL303 -- test: next-line form
            plan._x = 1
        """)
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["CPL303"]


def test_suppression_matches_by_name_and_all(tmp_path):
    by_name = _lint_src(tmp_path, """
        def f(plan):
            plan._x = 1  # replint: disable=private-mutation -- test: by name
        """)
    assert by_name.findings == []
    by_all = _lint_src(tmp_path, """
        def f(plan):
            plan._x = 1  # replint: disable=ALL -- test: blanket
        """, name="all.py")
    assert by_all.findings == []


def test_suppression_does_not_leak_to_other_lines(tmp_path):
    report = _lint_src(tmp_path, """
        def f(plan):
            plan._x = 1  # replint: disable=CPL303 -- test: this line only
            plan._y = 2
        """)
    assert _rules_of(report) == ["CPL303"]
    assert report.findings[0].line == 4


# ---------------------------------------------------------------------------------
# call-graph jit-reachability
# ---------------------------------------------------------------------------------

def _graph_of(source):
    tree = ast.parse(textwrap.dedent(source))
    return build_graph(tree, build_imports(tree))


def _reachable(source):
    g = _graph_of(source)
    return {f.qualname for f in g.jit_reachable_functions()}


def test_reachability_direct_jit():
    names = _reachable("""
        import jax

        def helper(x):
            return x + 1

        @jax.jit
        def hot(x):
            return helper(x)

        def cold(x):
            return x
        """)
    assert names == {"hot", "helper"}


def test_reachability_via_functools_partial():
    names = _reachable("""
        import functools
        import jax

        def body(step, x):
            return x * step

        def run(x):
            fn = jax.jit(functools.partial(body, 2))
            return fn(x)
        """)
    assert "body" in names


def test_reachability_via_method():
    names = _reachable("""
        import jax

        class Engine:
            def _step(self, x):
                return self._inner(x)

            def _inner(self, x):
                return x + 1

            def __init__(self):
                self.fn = jax.jit(self._step)
        """)
    assert {"Engine._step", "Engine._inner"} <= names


def test_reachability_via_lax_bodies_and_alias():
    names = _reachable("""
        from jax import lax

        def cond(c):
            return c[0] < 10

        def body(c):
            return c

        def run(x):
            step = body
            return lax.while_loop(cond, step, (x,))
        """)
    assert {"cond", "body"} <= names


def test_reachability_via_traced_marker():
    src = textwrap.dedent("""
        # replint: traced -- jitted by a caller in another module
        def entry(x):
            return helper(x)

        def helper(x):
            return x + 1
        """)
    tree = ast.parse(src)
    _, traced = parse_comments(src)
    g = build_graph(tree, build_imports(tree), traced)
    names = {f.qualname for f in g.jit_reachable_functions()}
    assert names == {"entry", "helper"}


def test_kernel_reachability_from_pallas_call():
    g = _graph_of("""
        import jax
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def launch(x):
            return pl.pallas_call(
                _kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
        """)
    kernels = {f.qualname for f in g.kernel_functions()}
    assert kernels == {"_kernel"}
    assert len(g.pallas_sites) == 1
    outer, inner, kernel, _scope = g.pallas_sites[0]
    assert kernel.qualname == "_kernel"
    assert outer is not None and inner is not None


# ---------------------------------------------------------------------------------
# staticness judgment calls (regression-pins for the real tree)
# ---------------------------------------------------------------------------------

def test_shape_coercion_is_not_a_host_sync(tmp_path):
    report = _lint_src(tmp_path, """
        import jax

        @jax.jit
        def hot(x):
            n = int(x.shape[0])
            return x * n
        """)
    assert report.findings == []


def test_config_branches_are_static(tmp_path):
    report = _lint_src(tmp_path, """
        import jax

        @jax.jit
        def hot(x, cfg: ModelConfig, n_layers: int = 4, extra=None):
            if cfg.moe:
                x = x + 1
            for _ in range(n_layers):
                x = x * 2
            if extra is None:
                return x
            return x + extra
        """)
    assert report.findings == []


def test_kernel_kwonly_params_are_static(tmp_path):
    report = _lint_src(tmp_path, """
        import functools
        import jax
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref, *, block_k):
            if block_k > 8:
                o_ref[...] = x_ref[...]

        def launch(x):
            return pl.pallas_call(
                functools.partial(_kernel, block_k=16),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
        """)
    assert report.findings == []


def test_traced_branch_detected_through_assignment(tmp_path):
    report = _lint_src(tmp_path, """
        import jax

        @jax.jit
        def hot(x):
            y = x + 1
            if y > 0:
                return y
            return -y
        """)
    assert _rules_of(report) == ["TRC102"]


# ---------------------------------------------------------------------------------
# engine surface: discovery, JSON, exit codes, CLI
# ---------------------------------------------------------------------------------

def test_fixture_corpus_is_excluded_by_default():
    report = lint_paths(["tests"], root=REPO)
    assert not any("lint_fixtures" in f.path for f in report.findings)


def test_json_report_roundtrip(tmp_path):
    report = _lint_src(tmp_path, """
        def f(plan):
            plan._x = 1
        """)
    out = tmp_path / "report.json"
    report.write_json(out)
    data = json.loads(out.read_text())
    assert data["tool"] == "replint"
    assert data["n_findings"] == 1
    assert data["counts"] == {"CPL303": 1}
    assert data["findings"][0]["rule"] == "CPL303"
    assert report.exit_code == 1


def test_cli_exit_codes(tmp_path, capsys):
    from repro.lint.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text("def f(plan):\n    plan._x = 1\n")
    good = tmp_path / "good.py"
    good.write_text("def f():\n    return 1\n")
    assert main([str(bad), "--root", str(tmp_path), "--no-scope"]) == 1
    assert main([str(good), "--root", str(tmp_path), "--no-scope"]) == 0
    out = capsys.readouterr().out
    assert "CPL303" in out and "replint:" in out


def test_select_limits_rules_and_skips_meta(tmp_path):
    report = _lint_src(tmp_path, """
        import time

        def decide():
            return time.time()  # wall clock

        def other():
            return 1  # replint: disable=TRC102 -- unrelated, must not REP002
        """, select=("CPL301",))
    assert _rules_of(report) == ["CPL301"]


def test_real_tree_is_clean():
    """The acceptance gate: the repo lints clean (suppressions allowed)."""
    report = lint_paths(["src", "tests", "benchmarks"], root=REPO)
    assert report.findings == [], "\n".join(
        f"{f.location()} {f.rule}: {f.message}" for f in report.findings)
    for f in report.suppressed:
        assert f.reason, f"reasonless suppression at {f.location()}"


def test_context_parses_syntax_error_file(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def broken(:\n")
    report = lint_paths([str(f)], root=tmp_path, respect_scope=False)
    assert _rules_of(report) == ["REP000"]
    assert build_context(f, "broken.py") is None
