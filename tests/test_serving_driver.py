"""Serve launcher end-to-end + straggler eviction path."""
import os
import subprocess
import sys

import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_serve_driver_end_to_end():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "smollm-135m",
         "--smoke", "--requests", "15", "--horizon", "20", "--batch", "4"],
        capture_output=True, text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "completed 1" in p.stdout and "violations" in p.stdout


def test_straggler_eviction_requeues():
    """A slot that stops making progress is evicted and its request completes
    after re-dispatch."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, ServeConfig(max_batch=2, max_len=64))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    # simulate a stuck slot: freeze request 0's output by fault injection
    eng.step(now=0.0)
    victim_slot, victim = next(iter(eng.active.items()))
    # evict (what launch/serve.py does after stall detection)
    eng.active.pop(victim_slot)
    victim.output.clear()
    eng.submit(victim)
    eng.run_until_drained()
    assert len(eng.completed) == 3
    assert all(len(r.output) == r.max_new_tokens for r in eng.completed)
