"""Serving correctness: prefill+decode == full forward per arch; continuous
batching is greedy-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init_params(jax.random.key(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0, cfg.vocab)
    if cfg.family in ("audio", "encdec"):
        enc = jax.random.normal(jax.random.key(3), (B, cfg.enc_len, cfg.d_model))
        full = {"enc_embeds": enc, "tokens": toks}
        pre = {"enc_embeds": enc, "tokens": toks[:, :S]}
        dec_tok = toks[:, S:S + 1]
    elif cfg.input_mode == "embeddings":
        emb = jax.random.normal(jax.random.key(3), (B, S + 1, cfg.d_model))
        full = {"embeds": emb}
        pre = {"embeds": emb[:, :S]}
        dec_tok = emb[:, S:S + 1]
    else:
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :S]}
        dec_tok = toks[:, S:S + 1]

    logits_full, _ = jax.jit(m.forward)(params, full)
    lg_pre, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 8))(params, pre)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]),
                               np.asarray(logits_full[:, S - 1]), atol=0.1)
    lg_dec, _ = jax.jit(m.decode_step)(params, cache, dec_tok, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(logits_full[:, S]), atol=0.1)


def test_continuous_batching_greedy_exact():
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=int(rng.integers(3, 7))))
        eng.submit(reqs[-1])
    eng.run_until_drained()
    assert len(eng.completed) == 8
    # every request decodes exactly what sequential greedy decoding produces
    for r in eng.completed[:3]:
        toks = list(r.prompt)
        ref = []
        for _ in range(r.max_new_tokens):
            logits, _ = m.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
            t = int(jnp.argmax(logits[0, -1]))
            ref.append(t)
            toks.append(t)
        assert r.output == ref


def test_max_new_tokens_one_emits_exactly_one_token():
    """Regression: a max_new_tokens=1 request used to emit 2 tokens (prefill
    argmax + one forced decode); it must finish at fill time instead."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=32))
    rng = np.random.default_rng(1)
    one = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                  max_new_tokens=1)
    two = Request(rid=1, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                  max_new_tokens=2)
    eng.submit(one)
    eng.submit(two)
    eng.run_until_drained()
    assert len(eng.completed) == 2
    assert len(one.output) == 1 and one.done_s is not None
    assert len(two.output) == 2
    # the single token is the greedy prefill argmax
    logits, _ = jax.jit(m.forward)(params, {"tokens": jnp.asarray(one.prompt)[None]})
    assert one.output == [int(jnp.argmax(logits[0, -1]))]
    # a fill-time finish must not leave the slot occupied
    assert not eng.active and not eng.queue
    # fill-time finishes still respect the slot cap and count as served work:
    # 4 one-token requests through max_batch=2 take 2 steps, not 1
    eng2 = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=32))
    for i in range(4):
        eng2.submit(Request(rid=i,
                            prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                            max_new_tokens=1))
    assert eng2.step(now=0.0) == 2
    assert len(eng2.completed) == 2 and len(eng2.queue) == 2
    assert eng2.step(now=1.0) == 2
    assert len(eng2.completed) == 4
    assert eng2.step_count == 2                    # fill-only steps still count
    # a zero-budget request completes with no output, no prefill timestamp
    zero = Request(rid=9, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                   max_new_tokens=0)
    eng2.submit(zero)
    eng2.run_until_drained()
    assert zero.done_s is not None and zero.output == []
    assert zero.first_token_s is None


def test_vector_pos_decode_matches_scalar():
    cfg = get_smoke_config("qwen2.5-3b")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    B, S = 3, 12
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
    _, cache = m.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 4)
    lg_s, _ = m.decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S))
    lg_v, _ = m.decode_step(params, cache, toks[:, S:S + 1],
                            jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v), atol=1e-3)
