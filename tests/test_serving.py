"""Serving correctness: prefill+decode == full forward per arch; continuous
batching is greedy-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init_params(jax.random.key(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0, cfg.vocab)
    if cfg.family in ("audio", "encdec"):
        enc = jax.random.normal(jax.random.key(3), (B, cfg.enc_len, cfg.d_model))
        full = {"enc_embeds": enc, "tokens": toks}
        pre = {"enc_embeds": enc, "tokens": toks[:, :S]}
        dec_tok = toks[:, S:S + 1]
    elif cfg.input_mode == "embeddings":
        emb = jax.random.normal(jax.random.key(3), (B, S + 1, cfg.d_model))
        full = {"embeds": emb}
        pre = {"embeds": emb[:, :S]}
        dec_tok = emb[:, S:S + 1]
    else:
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :S]}
        dec_tok = toks[:, S:S + 1]

    logits_full, _ = jax.jit(m.forward)(params, full)
    lg_pre, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 8))(params, pre)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]),
                               np.asarray(logits_full[:, S - 1]), atol=0.1)
    lg_dec, _ = jax.jit(m.decode_step)(params, cache, dec_tok, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(logits_full[:, S]), atol=0.1)


def test_continuous_batching_greedy_exact():
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=int(rng.integers(3, 7))))
        eng.submit(reqs[-1])
    eng.run_until_drained()
    assert len(eng.completed) == 8
    # every request decodes exactly what sequential greedy decoding produces
    for r in eng.completed[:3]:
        toks = list(r.prompt)
        ref = []
        for _ in range(r.max_new_tokens):
            logits, _ = m.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
            t = int(jnp.argmax(logits[0, -1]))
            ref.append(t)
            toks.append(t)
        assert r.output == ref


def test_max_new_tokens_one_emits_exactly_one_token():
    """Regression: a max_new_tokens=1 request used to emit 2 tokens (prefill
    argmax + one forced decode); it must finish at fill time instead."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=32))
    rng = np.random.default_rng(1)
    one = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                  max_new_tokens=1)
    two = Request(rid=1, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                  max_new_tokens=2)
    eng.submit(one)
    eng.submit(two)
    eng.run_until_drained()
    assert len(eng.completed) == 2
    assert len(one.output) == 1 and one.done_s is not None
    assert len(two.output) == 2
    # the single token is the greedy prefill argmax
    logits, _ = jax.jit(m.forward)(params, {"tokens": jnp.asarray(one.prompt)[None]})
    assert one.output == [int(jnp.argmax(logits[0, -1]))]
    # a fill-time finish must not leave the slot occupied
    assert not eng.active and not eng.queue
    # fill-time finishes still respect the slot cap and count as served work:
    # 4 one-token requests through max_batch=2 take 2 steps, not 1
    eng2 = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=32))
    for i in range(4):
        eng2.submit(Request(rid=i,
                            prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                            max_new_tokens=1))
    assert eng2.step(now=0.0) == 2
    assert len(eng2.completed) == 2 and len(eng2.queue) == 2
    assert eng2.step(now=1.0) == 2
    assert len(eng2.completed) == 4
    assert eng2.step_count == 2                    # fill-only steps still count
    # a zero-budget request completes with no output, no prefill timestamp
    zero = Request(rid=9, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                   max_new_tokens=0)
    eng2.submit(zero)
    eng2.run_until_drained()
    assert zero.done_s is not None and zero.output == []
    assert zero.first_token_s is None


def _request_set(cfg, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 30))).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 7)))
            for i in range(n)]


def test_paged_matches_dense_tokens():
    """Acceptance: the paged-cache engine emits bit-for-bit the same tokens
    as the dense-cache engine for the same prompts."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    outs = {}
    for paged in (True, False):
        eng = ServingEngine(m, params,
                            ServeConfig(max_batch=4, max_len=64, paged=paged))
        assert eng.paged == paged
        for r in _request_set(cfg):
            eng.submit(r)
        eng.run_until_drained()
        outs[paged] = {r.rid: list(r.output) for r in eng.completed}
        if paged:
            eng.kv.check_invariants()
            assert eng.kv.n_free == eng.kv.num_pages - 1   # all pages freed
    assert outs[True] == outs[False]


def test_prefill_trace_count_bounded_by_buckets():
    """Acceptance: prefill jit retraces are bounded by the number of distinct
    request_class prefill buckets, not the number of distinct prompt lengths;
    decode retraces are bounded by the power-of-two active-batch sizes."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64))
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                    max_new_tokens=3)
            # 8 distinct prompt lengths spanning exactly two 2^k buckets
            for i, plen in enumerate([3, 5, 7, 9, 12, 16, 17, 21, 25, 31])]
    buckets = {min(r.request_class[0], 64) for r in reqs}
    assert len(buckets) == 2
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert len(eng.completed) == len(reqs)
    assert eng.prefill_trace_count <= len(buckets)
    assert eng.decode_trace_count <= int(np.ceil(np.log2(4))) + 1


def test_eos_early_stop_frees_slot_and_pages():
    """A request whose decode emits eos_token finishes early, its slot
    empties, its pages return to the pool, and pos/remaining reset."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    # discover what greedy decoding emits, then replay with eos = 2nd token
    probe = Request(rid=0, prompt=prompt.copy(), max_new_tokens=6)
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=64))
    eng.submit(probe)
    eng.run_until_drained()
    assert len(probe.output) == 6
    eos = probe.output[1]
    eng2 = ServingEngine(m, params,
                         ServeConfig(max_batch=2, max_len=64, eos_token=eos))
    replay = Request(rid=1, prompt=prompt.copy(), max_new_tokens=6)
    eng2.submit(replay)
    eng2.run_until_drained()
    assert replay.output == probe.output[:2]       # stopped at the eos token
    assert replay.done_s is not None
    assert not eng2.active and not eng2.queue
    assert eng2.pos[0] == 0 and eng2.remaining[0] == 0   # slot state reset
    assert eng2.kv.n_free == eng2.kv.num_pages - 1       # pages freed
    eng2.kv.check_invariants()


def test_engine_scores_are_mean_decode_logprobs():
    """Request.score is the engine-computed running mean logprob of the
    emitted tokens (the application-output signal the driver records)."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    rng = np.random.default_rng(4)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                  max_new_tokens=4)
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=64))
    eng.submit(req)
    eng.run_until_drained()
    # reference: sequential greedy logprobs from the full forward
    toks = list(req.prompt)
    lps = []
    for t in req.output:
        logits, _ = m.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        lp = jax.nn.log_softmax(logits[0, -1])
        assert t == int(jnp.argmax(lp))
        lps.append(float(lp[t]))
        toks.append(t)
    assert req.score < 0.0
    np.testing.assert_allclose(req.score, np.mean(lps), atol=2e-2)


def test_submit_rejects_oversized_request():
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=32))
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0,
                           prompt=rng.integers(0, cfg.vocab, 30).astype(np.int32),
                           max_new_tokens=8))


def test_page_pressure_defers_admission_then_drains():
    """With a pool too small for all requests at once, admission defers until
    completions free pages -- and every request still completes."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    # 3 usable pages of 16 tokens: only one 17+-token request fits at a time
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=4, max_len=64, num_pages=4))
    rng = np.random.default_rng(6)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32),
                    max_new_tokens=5) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step(now=0.0)
    assert len(eng.active) == 1          # pool pressure: only one admitted
    eng.run_until_drained()
    assert len(eng.completed) == 3
    assert all(len(r.output) == 5 for r in reqs)
    eng.kv.check_invariants()
    assert eng.kv.n_free == eng.kv.num_pages - 1


def test_page_size_larger_than_bucket_floor():
    """Regression: page_size=32 with a short prompt (16-bucket) used to
    produce zero page chunks and crash the prefill scatter; the bucket is
    now clamped up to the page size."""
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=2, max_len=128, page_size=32))
    rng = np.random.default_rng(7)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                  max_new_tokens=4)
    eng.submit(req)
    eng.run_until_drained()
    assert len(req.output) == 4
    eng.kv.check_invariants()
    assert eng.kv.n_free == eng.kv.num_pages - 1


def test_vector_pos_decode_matches_scalar():
    cfg = get_smoke_config("qwen2.5-3b")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    B, S = 3, 12
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
    _, cache = m.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 4)
    lg_s, _ = m.decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S))
    lg_v, _ = m.decode_step(params, cache, toks[:, S:S + 1],
                            jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v), atol=1e-3)
