"""Per-arch smoke: reduced config, one forward + one train step on CPU,
asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.training import make_train_step


def _batch(cfg, B=2, S=16, seed=0):
    rng = jax.random.key(seed)
    if cfg.family in ("audio", "encdec"):
        return {
            "enc_embeds": jax.random.normal(rng, (B, cfg.enc_len, cfg.d_model)),
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
            "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        }
    if cfg.input_mode == "embeddings":
        return {
            "embeds": jax.random.normal(rng, (B, S, cfg.d_model)),
            "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        }
    t = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    return {"tokens": t, "targets": t}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch(cfg)
    B, S = 2, 16

    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10)))
    p2, o2, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         params, p2)
    assert max(jax.tree.leaves(delta)) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_parameter_count(arch):
    """Full configs: analytic param count matches the abstract init exactly."""
    cfg = get_config(arch)
    model = build_model(cfg)
    abstract = model.abstract_params()
    n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(abstract))
    expected = {
        "zamba2-2.7b": 2.7e9, "smollm-360m": 360e6, "smollm-135m": 135e6,
        "gemma3-4b": 4e9, "qwen2.5-3b": 3e9, "olmoe-1b-7b": 7e9,
        "mixtral-8x22b": 140e9, "whisper-small": 240e6, "mamba2-1.3b": 1.3e9,
        "pixtral-12b": 12e9,
    }[arch]
    assert n == pytest.approx(expected, rel=0.45), f"{arch}: {n / 1e9:.2f}B"
