"""PLK204 clean twin: blocks tile the literal out_shape exactly."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch(x):
    block = 32
    n = 128
    return pl.pallas_call(
        _kernel,
        grid=(n // block, 1),
        in_specs=[pl.BlockSpec((block, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, 128), jnp.float32),
    )(x)
