"""CPL301 fire fixture: wall-clock and ambient RNG in decision code."""
import time

import numpy as np


def decide(observation):
    now = time.monotonic()           # wall-clock read
    jitter = np.random.random()      # global (unseeded) RNG
    rng = np.random.default_rng()    # constructor without a seed
    return now + jitter + rng.random()
