"""PLK203 clean twin: distinct operands (repeated literals are fine)."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def launch(x, y):
    out = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(_kernel, out_shape=out)(x, y)
