"""REP001 fire fixture: a suppression without a reason string."""


def hijack(plan):
    plan._pending = []  # replint: disable=CPL303
