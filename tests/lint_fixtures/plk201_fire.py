"""PLK201 fire fixture: kernel closes over a traced array."""
import jax
from jax.experimental import pallas as pl


def launch(x, bias):
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] + bias     # captured tracer, not a ref

    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
