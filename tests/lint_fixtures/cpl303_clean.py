"""CPL303 clean twin: classes mutate their own privates; outsiders use the
public API (reads of privates are not mutations)."""


class Plan:
    def __init__(self):
        self._pending = []
        self._count = 0

    def push(self, item):
        self._pending.append(item)
        self._count += 1


def use(plan):
    plan.push(3)
    plan.public_field = 7
    return len(plan._pending)        # read access is fine
