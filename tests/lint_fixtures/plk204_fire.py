"""PLK204 fire fixture: literal out_shape not divisible by the block."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch(x):
    block = 48
    return pl.pallas_call(
        _kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((block, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((100, 128), jnp.float32),   # 100 % 48
    )(x)
