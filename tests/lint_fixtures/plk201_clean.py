"""PLK201 clean twin: arrays enter via refs, constants via partial-bound
keyword-only args, and module-level kernels only see static globals."""
import functools

import jax
from jax.experimental import pallas as pl

_EPS = 1e-6   # module constant: fine to close over


def _kernel(x_ref, b_ref, o_ref, *, scale):
    o_ref[...] = x_ref[...] * scale + b_ref[...] + _EPS


def launch(x, bias, scale: int = 2):
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x, bias)
