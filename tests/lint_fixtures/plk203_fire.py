"""PLK203 fire fixture: same array passed twice to one pallas_call."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def launch(x):
    out = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(_kernel, out_shape=out)(x, x)   # aliased operands
