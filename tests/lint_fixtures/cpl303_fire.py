"""CPL303 fire fixture: private state mutated from outside the class."""


def hijack(plan):
    plan._pending = []               # direct assignment
    plan._meters["od"] = 1           # write through a subscript
    plan._queue.append(3)            # mutating method call
