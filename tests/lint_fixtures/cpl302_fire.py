"""CPL302 fire fixture: additive arithmetic across unit families."""


def budget(window_s, horizon_steps, price_unit_hours):
    total_s = window_s + horizon_steps        # seconds + steps
    if window_s > price_unit_hours:           # seconds vs hours compare
        total_s = total_s - horizon_steps     # seconds - steps
    return total_s
