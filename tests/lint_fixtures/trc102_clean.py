"""TRC102 clean twin: static branches and device-side selects."""
import jax
import jax.numpy as jnp


@jax.jit
def hot(x, scale: float = 2.0, y=None):
    if scale > 1.0:                 # config knob: trace-time Python
        x = x * scale
    if x.shape[0] > 1:              # shapes are static
        x = x + 1
    if y is None:                   # identity test never syncs
        y = jnp.zeros_like(x)
    return jnp.where(x > 0, x, -x) + y
