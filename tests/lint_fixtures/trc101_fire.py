"""TRC101 fire fixture: host syncs on traced values in a jitted function."""
import jax
import numpy as np


@jax.jit
def hot(x):
    n = int(x)                 # coercion concretizes the tracer
    a = np.asarray(x)          # numpy materializes the device array
    return x.item() + n + a.sum()
