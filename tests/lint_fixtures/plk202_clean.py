"""PLK202 clean twin: the legal ref-index grammar (constants, slices,
pl.ds, program_id-derived scalars, scalar arithmetic)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, x_ref, o_ref, acc_scr, *, block_k):
    b = pl.program_id(0)
    length = s_ref[b]
    acc_scr[...] = x_ref[pl.ds(0, block_k), :] * 1.0
    o_ref[0, :] = acc_scr[length - 1, :]
    o_ref[1:, :] = x_ref[: block_k - 1, :]


def launch(lengths, x):
    return pl.pallas_call(
        functools.partial(_kernel, block_k=8),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32))(lengths, x)
