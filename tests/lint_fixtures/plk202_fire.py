"""PLK202 fire fixture: data-dependent ref index."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, idx_ref, o_ref):
    o_ref[...] = x_ref[jnp.argmax(idx_ref[...])]   # jnp expression as index


def launch(x, idx):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x, idx)
