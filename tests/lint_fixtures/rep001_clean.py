"""REP001 clean twin: the suppression carries its reason."""


def hijack(plan):
    plan._pending = []  # replint: disable=CPL303 -- fixture: reasoned suppression
