"""TRC102 fire fixture: Python branch on a traced operand in a scan body."""
import jax
import jax.numpy as jnp


def step(carry, tok):
    if tok > 0:                # Python `if` concretizes the tracer
        carry = carry + tok
    return carry, carry


def run(tokens):
    return jax.lax.scan(step, jnp.zeros(()), tokens)
