"""REP002 fire fixture: a suppression that matches no finding."""


def fine():
    return 1  # replint: disable=TRC101 -- nothing here actually syncs
