"""CPL301 clean twin: 'now' is a parameter, RNG is explicitly seeded."""
import numpy as np


def decide(observation, now: float, seed: int):
    rng = np.random.default_rng(seed)
    return now + rng.random()
