"""CPL302 clean twin: convert with multiply/divide before combining."""


def budget(window_s, step_s, cost_rate):
    horizon_steps = round(window_s / step_s)   # divide converts s -> steps
    covered_s = horizon_steps * step_s         # multiply converts back
    cost = window_s / 3600.0 * cost_rate       # s -> hours via divide
    return horizon_steps, covered_s + step_s, cost
