"""TRC101 clean twin: metadata coercions and host-side syncs are fine."""
import jax
import jax.numpy as jnp


@jax.jit
def hot(x):
    n = int(x.shape[0])        # shapes are trace-time Python
    y = jnp.asarray(x)         # device-side cast, no sync
    return y * n


def host(x):
    return float(x)            # not jit-reachable: host code may sync
