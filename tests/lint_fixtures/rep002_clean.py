"""REP002 clean twin: the suppression is actually used."""


def hijack(plan):
    plan._pending = []  # replint: disable=CPL303 -- fixture: suppression is used
