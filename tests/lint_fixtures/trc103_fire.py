"""TRC103 fire fixture: printing / formatting tracers inside jit."""
import jax


@jax.jit
def hot(x):
    print(x)                   # prints the abstract tracer, not data
    msg = f"value={x}"         # f-string interpolates the tracer
    return x, msg
