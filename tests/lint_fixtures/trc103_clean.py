"""TRC103 clean twin: jax.debug.print and static f-strings."""
import jax


@jax.jit
def hot(x, label: str = "x"):
    jax.debug.print("value={v}", v=x)          # staged, prints real data
    note = f"tensor {label} rank {x.ndim}"     # static metadata only
    return x, note


def host(x):
    print(x)                                   # host code prints freely
    return x
