"""Replica fleet: single-replica behavioral equivalence (pinned), drain
migration conservation, measured provisioning delay, SLA-aware routing, and
convergence-plane healing of killed replicas (see repro.serving.fleet)."""
import numpy as np
import pytest

from repro.core.autoscaler.base import Decision, Policy  # noqa: E402
from repro.core.scaling import CapacityPlan, Sla, UnitPool
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.fleet import (
    FLEET_POOL,
    FleetBackend,
    FleetExecutor,
    FleetRouter,
    ReplicaPool,
)

@pytest.fixture(scope="module")
def fleet_env(tmp_path_factory):
    """One model + checkpoint shared by every spawn in this module."""
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    ckpt_dir = tmp_path_factory.mktemp("fleet-ckpt")
    mgr = CheckpointManager(str(ckpt_dir), keep=2, async_save=False)
    mgr.save(params, step=1)
    return cfg, model, mgr


def _make_pool(fleet_env, n_replicas, **cfg_kw):
    cfg, model, mgr = fleet_env
    serve_cfg = ServeConfig(max_batch=cfg_kw.pop("max_batch", 4),
                            max_len=cfg_kw.pop("max_len", 128),
                            decode_steps=4, **cfg_kw)
    pool = ReplicaPool(model, mgr, serve_cfg)
    for _ in range(n_replicas):
        rep, _ = pool.spawn()
        pool.serving.append(rep)
    return cfg, pool


def _requests(cfg, rng, n, *, arrival=lambda i: 0.0, decode=lambda i: 6):
    return [Request(rid=i, arrival_s=arrival(i),
                    prompt=rng.integers(0, cfg.vocab,
                                        8 + (i % 3) * 8).astype(np.int32),
                    max_new_tokens=decode(i)) for i in range(n)]


class _Hold(Policy):
    """Votes zero delta forever: the desired state is whatever the fleet
    started at, so the only scaling activity left is fault healing."""

    name = "hold"

    def reset(self):
        pass

    def decide(self, obs):
        return Decision(0, "hold")

    def describe(self):
        return "hold"


def test_single_replica_fleet_matches_bare_engine(fleet_env):
    """Pinned equivalence: the router + one replica admits and emits exactly
    what the bare engine does under the same virtual-time stepping -- fleet
    mode at size 1 is today's engine, not a different scheduler."""
    cfg, pool = _make_pool(fleet_env, 1)
    bare = ServingEngine(pool.model, pool.serving[0].eng.params,
                         pool.serve_cfg)
    rng = np.random.default_rng(7)
    reqs_fleet = _requests(cfg, rng, 10, arrival=lambda i: float(i // 3),
                           decode=lambda i: 4 + i % 5)
    rng = np.random.default_rng(7)
    reqs_bare = _requests(cfg, rng, 10, arrival=lambda i: float(i // 3),
                          decode=lambda i: 4 + i % 5)

    router = FleetRouter(pool)
    replica = pool.serving[0]
    heads = [0, 0]
    for t in range(200):
        while heads[0] < len(reqs_fleet) and \
                reqs_fleet[heads[0]].arrival_s <= t:
            router.submit(reqs_fleet[heads[0]])
            heads[0] += 1
        router.dispatch(float(t))
        replica.step(float(t), decode_steps=2)
        while heads[1] < len(reqs_bare) and reqs_bare[heads[1]].arrival_s <= t:
            bare.submit(reqs_bare[heads[1]])
            heads[1] += 1
        bare.step(now=float(t), decode_steps=2)
        if not router.backlog and not replica.eng.n_in_system \
                and not bare.n_in_system:
            break
    else:
        raise AssertionError("fleet or bare engine failed to drain")

    fleet_done = {r.rid: (list(r.output), r.done_s)
                  for r in replica.eng.completed}
    bare_done = {r.rid: (list(r.output), r.done_s) for r in bare.completed}
    assert fleet_done == bare_done
    replica.eng.kv.check_invariants()


def test_drain_migration_bit_identical_and_conserves_pages(fleet_env):
    """Mid-decode drain: every in-flight request resumes on the survivor
    with bit-identical tokens, and page free-lists conserve on BOTH sides."""
    cfg, pool = _make_pool(fleet_env, 2)
    rng = np.random.default_rng(3)
    reqs = _requests(cfg, rng, 8, decode=lambda i: 6 + i % 4)
    rng = np.random.default_rng(3)
    ref_reqs = _requests(cfg, rng, 8, decode=lambda i: 6 + i % 4)

    # reference: same params, no migration
    ref = ServingEngine(pool.model, pool.serving[0].eng.params,
                        pool.serve_cfg)
    for r in ref_reqs:
        ref.submit(r)
    ref.run_until_drained()
    reference = {r.rid: list(r.output) for r in ref.completed}

    router = FleetRouter(pool)
    for r in reqs:
        router.submit(r)
    for t in range(3):
        router.dispatch(float(t))
        for rep in pool.serving:
            rep.step(float(t), decode_steps=2)
    victim = pool.serving[-1]
    assert victim.eng.active, "nothing mid-decode: the drill is vacuous"
    free_before = int(victim.eng.kv.n_free)
    held_before = int(victim.eng.kv.held.sum())
    pool.drain(victim)
    # drained side: every held page is back on the free list
    assert int(victim.eng.kv.held.sum()) == 0
    assert int(victim.eng.kv.worst.sum()) == 0
    assert victim.eng.kv.n_free == free_before + held_before
    victim.eng.kv.check_invariants()

    for t in range(3, 300):
        router.dispatch(float(t))
        for rep in pool.serving:
            rep.step(float(t), decode_steps=2)
        if not router.backlog and not any(r.eng.n_in_system
                                          for r in pool.serving):
            break
    survivor = pool.serving[0]
    survivor.eng.kv.check_invariants()   # survivor side conserves too
    done = {r.rid: list(r.output)
            for rep in pool.serving + pool.retired
            for r in rep.eng.completed}
    assert done == reference


def test_measured_delay_lands_in_run_report(fleet_env):
    """The RunReport's provisioning delay is measured at spawn, not the
    configured guess."""
    cfg, pool = _make_pool(fleet_env, 0)
    rng = np.random.default_rng(5)
    reqs = _requests(cfg, rng, 8, arrival=lambda i: float(i // 4),
                     decode=lambda i: 4)
    be = FleetBackend(pool, reqs, sla_s=30.0, horizon_s=10.0,
                      starting_replicas=1, max_replicas=2,
                      provision_delay_s=123.0, adapt_period_s=2.0,
                      app_window_s=4.0, decode_steps=2)
    rep = be.run()
    assert rep.n_done == len(reqs)
    measured = rep.pool_provision_delay_s.get(FLEET_POOL)
    assert measured is not None and 0.0 < measured < 123.0
    assert rep.summary()["measured_delay_s.replica"] == measured


def test_router_sheds_cheapest_class_first(fleet_env):
    """Under pressure the queue serves strictest absolute deadline first, so
    the cheapest class (longest deadline) is the one that waits."""
    cfg, pool = _make_pool(fleet_env, 1, max_batch=2)
    sla = Sla(default_s=100.0, per_class={"p32d16": 5.0})
    router = FleetRouter(pool, sla=sla)
    rng = np.random.default_rng(9)
    # two blockers fill both slots: one finishes quickly, one runs long
    blockers = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=2),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=40),
    ]
    for b in blockers:
        router.submit(b)
    router.dispatch(0.0)
    pool.serving[0].step(0.0, decode_steps=1)
    assert len(pool.serving[0].eng.active) == 2
    # cheap (p16 -> 100 s deadline) arrives BEFORE premium (p32 -> 5 s):
    # FIFO would admit cheap first; deadline order must not
    cheap = Request(rid=2, arrival_s=1.0,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=4)
    premium = Request(rid=3, arrival_s=1.0,
                      prompt=rng.integers(0, cfg.vocab, 24).astype(np.int32),
                      max_new_tokens=4)
    router.submit(cheap)
    router.submit(premium)
    router.dispatch(1.0)
    assert [r.rid for r in router.queue] == [3, 2], \
        "queue is not deadline-ordered"
    for t in range(2, 20):     # rid 0 finishes, freeing exactly one slot
        pool.serving[0].step(float(t), decode_steps=2)
        if 0 in {r.rid for r in pool.serving[0].eng.completed}:
            break
    router.dispatch(float(t))
    pool.serving[0].step(float(t), decode_steps=1)
    active_rids = {r.rid for r in pool.serving[0].eng.active.values()}
    assert 3 in active_rids, "premium class did not preempt the cheap one"
    assert [r.rid for r in router.queue] == [2], "cheap class should shed"


def test_kill_requeues_at_original_deadline(fleet_env):
    """A killed replica's restarted requests re-enter the deadline queue at
    their ORIGINAL deadline (arrival survives the kill) -- re-admission must
    not jump a premium request that arrived later with a tighter absolute
    deadline.  Regression: the migrated backlog used to bypass the queue via
    direct placement, so a crash laundered cheap work past premium."""
    cfg, pool = _make_pool(fleet_env, 2, max_batch=1)
    sla = Sla(default_s=100.0, per_class={"p32d16": 5.0})
    router = FleetRouter(pool, sla=sla)
    rng = np.random.default_rng(13)
    # one blocker per replica: rid 0 runs long on A, cheap rid 1 sits on B
    blocker = Request(rid=0, prompt=rng.integers(0, cfg.vocab,
                                                 8).astype(np.int32),
                      max_new_tokens=40)
    cheap = Request(rid=1, prompt=rng.integers(0, cfg.vocab,
                                               8).astype(np.int32),
                    max_new_tokens=16)           # p16d16 -> 100 s deadline
    router.submit(blocker)
    router.submit(cheap)
    router.dispatch(0.0)
    for rep in pool.serving:
        rep.step(0.0, decode_steps=1)
    victim = next(r for r in pool.serving
                  if 1 in {q.rid for q in r.eng.active.values()})
    pool.kill(victim)                            # cheap restarts from scratch
    assert pool.migrated and pool.migrated[0].req.rid == 1
    # premium arrives AFTER the kill with a tighter absolute deadline
    premium = Request(rid=2, arrival_s=1.0,
                      prompt=rng.integers(0, cfg.vocab, 24).astype(np.int32),
                      max_new_tokens=16)         # p32d16 -> deadline 6 s
    router.submit(premium)
    router.dispatch(1.0)
    # the restarted cheap request folded into the queue BEHIND premium
    assert not pool.migrated
    assert [r.rid for r in router.queue] == [2, 1]
    for t in range(2, 60):                       # blocker frees the only slot
        pool.serving[0].step(float(t), decode_steps=2)
        if 0 in {r.rid for r in pool.serving[0].eng.completed}:
            break
    router.dispatch(float(t))
    pool.serving[0].step(float(t), decode_steps=1)
    active_rids = {r.rid for r in pool.serving[0].eng.active.values()}
    assert 2 in active_rids, "crash restart outranked the premium class"
    assert [r.rid for r in router.queue] == [1]


def test_converger_heals_killed_replica(fleet_env):
    """Abrupt replica loss mid-run: the plan records a measured unit loss
    and the converger heals it with a REAL respawn; every request (including
    the killed replica's restarted in-flights) still completes."""
    cfg, pool = _make_pool(fleet_env, 0)
    rng = np.random.default_rng(11)
    reqs = _requests(cfg, rng, 14, arrival=lambda i: float(i // 2),
                     decode=lambda i: 5 + i % 4)
    killed = []

    def kill_once(be, t):
        if t == 3.0 and not killed:
            victim = be.pool.serving[-1]
            killed.append(victim.rix)
            be.kill_replica(victim, t)

    be = FleetBackend(pool, reqs, sla_s=60.0, horizon_s=10.0,
                      policy=_Hold(), starting_replicas=2, max_replicas=3,
                      adapt_period_s=2.0, app_window_s=4.0, decode_steps=2,
                      on_step=kill_once)
    rep = be.run()
    assert killed, "the drill never fired"
    assert rep.n_done == len(reqs)
    assert len(pool.serving) == 2, "fleet did not heal back to desired size"
    assert pool._next_rix >= 3, "healing never spawned a replacement"
    # the loss is on the books as a measured fault, not silent
    meters = be.controller.plan.meters()[FLEET_POOL]
    assert meters.lost == 1
    for r in pool.serving:
        r.eng.kv.check_invariants()


def test_executor_books_stuck_spawn_and_cancels_it_first(fleet_env):
    """A spawn that raises becomes a measured stuck build; cancel takes the
    stuck book entry before discarding healthy provisioning replicas."""
    cfg, pool = _make_pool(fleet_env, 0)
    outcomes = iter([True, False])      # first spawn fails, second succeeds
    pool.spawn_fault = lambda: next(outcomes, False)
    plan = CapacityPlan((UnitPool(FLEET_POOL, provision_delay_s=5.0,
                                  max_units=4),), starting_units=0)
    ex = FleetExecutor(pool, plan)
    applied = ex.launch(FLEET_POOL, 2, now=0.0)
    assert applied == 2
    assert ex._stuck == 1 and len(pool.provisioning) == 1
    # measured delay was calibrated from the successful spawn
    assert plan.report_kwargs()["pool_provision_delay_s"][FLEET_POOL] > 0.0
    # cancel one: the stuck build goes first, the real replica survives
    assert ex.cancel_pending(FLEET_POOL, 1, now=1.0) == 1
    assert ex._stuck == 0 and len(pool.provisioning) == 1
    # cancel the other: now the provisioning replica is discarded
    assert ex.cancel_pending(FLEET_POOL, 1, now=2.0) == 1
    assert not pool.provisioning and len(pool.retired) == 1


def test_chaos_drill_kill_under_load_is_observationally_equivalent(
        fleet_env, tmp_path):
    """End-to-end ChaosDrill over REAL engines: a replica killed under
    burst load heals, and the whole invariant battery -- exactly-once,
    bit-identical outputs vs the fault-free reference, KV page
    conservation, sealed audit replay -- comes back green."""
    from repro.core.chaos import ChaosAction, ChaosDrill, ChaosScript

    def make_backend(*, on_step, audit_path):
        cfg, pool = _make_pool(fleet_env, 0)
        rng = np.random.default_rng(21)
        reqs = _requests(cfg, rng, 10, arrival=lambda i: float(i // 2),
                         decode=lambda i: 4 + i % 3)
        return FleetBackend(pool, reqs, sla_s=60.0, horizon_s=8.0,
                            policy=_Hold(), starting_replicas=2,
                            max_replicas=3, adapt_period_s=2.0,
                            app_window_s=4.0, decode_steps=2,
                            calibrate=False, on_step=on_step,
                            audit_path=audit_path)

    script = ChaosScript([ChaosAction(3.0, "kill", count=1)], seed=5)
    drill = ChaosDrill("kill-under-load", make_backend, script,
                       audit_path=str(tmp_path / "drill.jsonl"))
    report = drill.run()
    assert report.fired and report.fired[0]["kind"] == "kill"
    assert report.n_completed == 10 == report.n_reference
    assert report.ok, report.summary()
