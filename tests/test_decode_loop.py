"""Device-resident decode loop: fused epilogue exactness, K-step scan ==
K single steps (incl. mid-scan eos), batched bucketed prefill, empty-active
guards, and span page pre-allocation across a K-burst."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def smol():
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    return cfg, m, params


def test_fused_epilogue_matches_log_softmax_oracle():
    """The fused argmax + chosen-token logprob (max - logsumexp) is
    token-exact and logprob-close vs materializing log_softmax, including
    on exact ties (first maximal index wins, like jnp.argmax)."""
    from repro.kernels.sampling.ops import greedy_epilogue
    from repro.kernels.sampling.ref import greedy_epilogue_ref
    logits = jax.random.normal(jax.random.key(3), (8, 977)) * 6.0
    # plant exact ties on two rows
    logits = logits.at[0, 11].set(50.0).at[0, 503].set(50.0)
    logits = logits.at[1, 900].set(-1.0 + logits[1].max() + 1.0)
    tok, lp = greedy_epilogue(logits)
    tok_ref, lp_ref = greedy_epilogue_ref(logits)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_ref))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_ref), atol=1e-5)
    assert int(tok[0]) == 11                       # first of the tied maxima


def test_fused_epilogue_kernel_matches_oracle():
    """The Pallas streaming kernel (interpret mode on CPU) == the oracle,
    across block sizes incl. non-dividing ones (single-block fallback)."""
    from repro.kernels.sampling.kernel import greedy_epilogue_fwd
    from repro.kernels.sampling.ref import greedy_epilogue_ref
    logits = jax.random.normal(jax.random.key(4), (3, 1000)) * 4.0
    tok_ref, lp_ref = greedy_epilogue_ref(logits)
    for bv in (1000, 250, 128, 4096):
        tok, lp = greedy_epilogue_fwd(logits, block_v=bv, interpret=True)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_ref))
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_ref),
                                   atol=1e-5)


def _mixed_requests(cfg, n=10, seed=11):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 30))).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 12)))
            for i in range(n)]


def test_kstep_loop_equals_single_steps(smol):
    """Acceptance: draining at K=8 sync cadence emits exactly the tokens
    (and the same scores) as stepping one token at a time."""
    cfg, m, params = smol
    outs = {}
    for k in (1, 8):
        eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64,
                                                   decode_steps=8))
        for r in _mixed_requests(cfg):
            eng.submit(r)
        while eng.queue or eng.active:
            eng.step(decode_steps=k)
        assert len(eng.completed) == 10
        eng.kv.check_invariants()
        assert eng.kv.n_free == eng.kv.num_pages - 1
        outs[k] = {r.rid: (list(r.output), r.score) for r in eng.completed}
    assert {r: o for r, (o, _) in outs[1].items()} == \
           {r: o for r, (o, _) in outs[8].items()}
    for rid in outs[1]:
        np.testing.assert_allclose(outs[1][rid][1], outs[8][rid][1],
                                   atol=1e-4)


def test_kstep_midscan_eos_finish(smol):
    """A row that emits eos in the middle of a K-burst parks on device:
    later loop iterations emit nothing for it, its pre-allocated pages come
    back on release, and its output stops at the eos token."""
    cfg, m, params = smol
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    probe = Request(rid=0, prompt=prompt.copy(), max_new_tokens=7)
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=64))
    eng.submit(probe)
    eng.run_until_drained()
    assert len(probe.output) == 7
    eos = probe.output[2]                          # fires mid-burst (K=8)
    eng2 = ServingEngine(m, params,
                         ServeConfig(max_batch=2, max_len=64, eos_token=eos,
                                     decode_steps=8))
    replay = Request(rid=1, prompt=prompt.copy(), max_new_tokens=7)
    eng2.submit(replay)
    eng2.run_until_drained()
    assert replay.output == probe.output[:3]       # stopped at the eos token
    assert replay.done_s is not None
    assert not eng2.active and not eng2.queue
    assert eng2.kv.n_free == eng2.kv.num_pages - 1
    eng2.kv.check_invariants()


def test_kburst_crosses_page_boundaries(smol):
    """One K-burst writing across page boundaries relies on span
    pre-allocation -- the device loop must never need a host-side append."""
    cfg, m, params = smol
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=2, max_len=64, page_size=16,
                                    decode_steps=8))
    rng = np.random.default_rng(6)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 14).astype(np.int32),
                  max_new_tokens=12)               # writes cross pos 16 and 24
    eng.submit(req)
    eng.run_until_drained()
    assert len(req.output) == 12
    eng.kv.check_invariants()
    assert eng.kv.n_free == eng.kv.num_pages - 1


def test_empty_active_decode_guard(smol):
    """Regression: decoding with an empty active set used to hit
    np.log2(0); both paths must return (0 served, 0 iters) untouched."""
    cfg, m, params = smol
    eng = ServingEngine(m, params, ServeConfig(max_batch=2, max_len=32))
    assert eng._decode_active_paged(now=0.0) == (0, 0)
    dense = ServingEngine(m, params,
                          ServeConfig(max_batch=2, max_len=32, paged=False))
    assert dense._decode_all_dense(now=0.0) == (0, 0)
    assert eng.step(now=0.0) == 0                  # no queue, no active: noop
    assert eng.step_count == 0
    with pytest.raises(ValueError):
        eng.step(now=0.0, decode_steps=eng.decode_steps + 1)  # buffer bound


def test_batched_prefill_coalesces_same_bucket(smol):
    """Four same-bucket prompts arrive together: ONE batched prefill call
    fills all four slots (one jit trace, full occupancy)."""
    cfg, m, params = smol
    eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64,
                                               chunked_prefill=False))
    rng = np.random.default_rng(7)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                           max_new_tokens=3))
    eng.step(now=0.0)
    assert len(eng.active) == 4
    assert eng.prefill_trace_count == 1
    assert eng._prefill_width == 4                 # one width-4 dispatch
    assert eng.prefill_occupancy == 1.0
    eng.run_until_drained()
    assert len(eng.completed) == 4
    # partial refill: occupancy drops below 1 but work still lands
    eng.submit(Request(rid=9,
                       prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                       max_new_tokens=2))
    eng.run_until_drained()
    assert len(eng.completed) == 5
    assert eng.prefill_trace_count == 1            # same bucket, same trace
    assert 0.0 < eng.prefill_occupancy < 1.0


def test_batched_prefill_mixed_buckets_split_groups(smol):
    """A bucket change at the queue head closes the group: two buckets ->
    two prefill calls, two traces, everything still greedy-exact."""
    cfg, m, params = smol
    eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64,
                                               chunked_prefill=False,
                                               bucket_max_wait=0))
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (8, 12, 20, 28)]           # buckets 16, 16, 32, 32
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.step(now=0.0)
    assert len(eng.active) == 4
    assert eng.prefill_trace_count == 2
    eng.run_until_drained()
    for i, p in enumerate(prompts):
        req = next(r for r in eng.completed if r.rid == i)
        toks = list(p)
        ref = []
        for _ in range(4):
            logits, _ = m.forward(params,
                                  {"tokens": jnp.asarray(toks, jnp.int32)[None]})
            t = int(jnp.argmax(logits[0, -1]))
            ref.append(t)
            toks.append(t)
        assert req.output == ref
