"""Checkpoint roundtrip (incl. bf16), rotation, and deterministic data resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import DataConfig, TokenStream


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"w": jnp.ones((5,), jnp.bfloat16) * 1.5,
              "step": jnp.int32(7)},
    }


def test_roundtrip_bf16(tmp_path):
    t = _tree()
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, t, step=3)
    loaded, meta = load_checkpoint(p, t)
    assert meta["step"] == 3
    for k, (x, y) in enumerate(zip(jax.tree.leaves(t), jax.tree.leaves(loaded))):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_manager_rotation_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    for step in (5, 10, 15, 20):
        mgr.save(t, step=step)
    files = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert len(files) == 2                       # rotation keeps 2
    loaded, meta = mgr.restore_latest(t)
    assert meta["step"] == 20


def test_corrupt_save_never_clobbers(tmp_path):
    """Atomic save: the previous checkpoint survives a failed write."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    mgr.save(t, step=1)
    before = mgr.latest()
    class Boom:
        def __array__(self, dtype=None, copy=None):
            raise RuntimeError("disk full")
    with pytest.raises(Exception):
        mgr.save({"a": Boom()}, step=2)
    assert mgr.latest() == before
    loaded, meta = mgr.restore_latest(t)
    assert meta["step"] == 1


def test_latest_skips_unmarked_partial_checkpoint(tmp_path):
    """A ckpt file without its terminal marker (interrupted save, torn copy)
    must never be picked as latest; restore falls back to the newest
    complete one."""
    from repro.checkpoint import OK_SUFFIX
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    t = _tree()
    mgr.save(t, step=1)
    good = mgr.latest()
    assert good is not None and os.path.exists(good + OK_SUFFIX)
    # a newer-looking but unmarked file: simulated crash after the rename
    # but before the terminal marker
    torn = tmp_path / "ckpt_00000099.npz"
    torn.write_bytes(b"not an npz")
    assert mgr.latest() == good
    loaded, meta = mgr.restore_latest(t)
    assert meta["step"] == 1
    # a stray marker without its npz must not resurrect anything either
    os.remove(torn)
    (tmp_path / ("ckpt_00000099.npz" + OK_SUFFIX)).write_text("ok\n")
    assert mgr.latest() == good


def test_data_determinism_and_host_sharding():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=9)
    a = TokenStream(cfg).batch(17)
    b = TokenStream(cfg).batch(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts partition the same global batch
    h0 = TokenStream(DataConfig(vocab=1000, seq_len=32, global_batch=8,
                                seed=9, n_hosts=2, host_id=0)).batch(17)
    h1 = TokenStream(DataConfig(vocab=1000, seq_len=32, global_batch=8,
                                seed=9, n_hosts=2, host_id=1)).batch(17)
    both = np.concatenate([h0["tokens"], h1["tokens"]])
    np.testing.assert_array_equal(both, a["tokens"])


def test_tokens_in_vocab_range():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=4)
    b = TokenStream(cfg).batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512
