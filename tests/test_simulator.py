"""Simulator invariants: exact water-filling (vs the paper's per-tweet loop),
conservation, Little's-law calibration, controller mechanics."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.autoscaler import LoadPolicy, ThresholdPolicy
from repro.core.autoscaler.base import Decision, Observation, Policy
from repro.core.simulator import (
    SimConfig, generate_trace, repeat_until_ci, run_scenario,
)
from repro.core.simulator.distributions import (
    CYCLES_PER_DELAY_SECOND, TESTBED_FREQ_HZ, TESTBED_IN_FLIGHT,
    TESTBED_INPUT_RATE, TESTBED_MEAN_DELAY_S, TESTBED_UTILIZATION, ServiceModel,
)
from repro.core.scaling.service import water_level
from repro.core.simulator.engine import _water_level


def paper_algorithm1(rem, capacity):
    """The paper's Algorithm 1, literally (per-tweet loop with redistribution)."""
    rem = sorted(rem)
    n = len(rem)
    to_process = n
    per = capacity / n
    consumed = {}
    for i, r in enumerate(rem):
        if r < per:
            excess = per - r
            to_process -= 1
            if to_process:
                per += excess / to_process
            consumed[i] = r
        else:
            consumed[i] = per
    return consumed


@given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=60),
       st.floats(0.01, 500.0))
@settings(max_examples=200, deadline=None)
def test_water_level_matches_paper_loop(rems, capacity):
    rem = np.sort(np.asarray(rems, dtype=np.float64))
    tau, k = _water_level(rem, capacity)
    ref = paper_algorithm1(list(rem), capacity)
    # same per-tweet consumption
    for i in range(rem.shape[0]):
        mine = min(rem[i], tau) if np.isfinite(tau) else rem[i]
        assert mine == pytest.approx(ref[i], rel=1e-9, abs=1e-9)
    # conservation: total consumed == min(capacity, total demand)
    total = sum(min(r, tau) if np.isfinite(tau) else r for r in rem)
    assert total == pytest.approx(min(capacity, float(rem.sum())), rel=1e-9)
    # k = number fully finished
    assert k == int(np.sum(rem <= (tau if np.isfinite(tau) else np.inf)))


@given(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=40))
@settings(max_examples=100, deadline=None)
def test_water_level_monotone(rems):
    """More capacity => higher tau, never fewer completions."""
    rem = np.sort(np.asarray(rems))
    t1, k1 = _water_level(rem, 5.0)
    t2, k2 = _water_level(rem, 10.0)
    assert k2 >= k1
    if np.isfinite(t1) and np.isfinite(t2):
        assert t2 >= t1


def test_water_level_legacy_alias():
    """The engine's `_water_level` is the shared core's `water_level`."""
    assert _water_level is water_level


def test_repeat_until_ci_returns_results_and_reps():
    """Regression: the docstring promises (results, reps) but only the
    results list was returned."""
    out = repeat_until_ci(lambda: ThresholdPolicy(0.9), "england",
                          min_reps=2, max_reps=2)
    results, reps = out
    assert reps == len(results) == 2
    assert all(hasattr(r, "violation_rate") for r in results)


def test_littles_law_calibration():
    sm = ServiceModel()
    lam = TESTBED_FREQ_HZ * TESTBED_UTILIZATION / sm.mean_cycles()
    assert lam == pytest.approx(TESTBED_INPUT_RATE, rel=1e-3)
    assert TESTBED_IN_FLIGHT / lam == pytest.approx(TESTBED_MEAN_DELAY_S, rel=1e-3)


def test_engine_conserves_tweets_and_drains():
    tr = generate_trace("england", seed=0)
    res = run_scenario(tr, ThresholdPolicy(0.9), SimConfig())
    assert res.delays.shape[0] == tr.n_tweets          # every tweet completed
    assert np.all(res.delays > 0.0)
    assert res.units_t.min() >= 1                      # floor respected


def test_quantile_pessimism_ordering():
    sm = ServiceModel()
    qs = [0.9, 0.99, 0.999, 0.9999, 0.99999]
    vals = [sm.quantile_cycles(q) for q in qs]
    assert all(a < b for a, b in zip(vals, vals[1:]))
    assert vals[0] > sm.mean_cycles()


class _Null(Policy):
    name = "null"
    def decide(self, obs):
        return Decision()


def test_provisioning_delay_and_single_release():
    """Upscales land after alloc_delay; downscale is one unit per tick."""
    class Upper(Policy):
        name = "u"
        def __init__(self):
            self.calls = 0
        def decide(self, obs):
            self.calls += 1
            if self.calls == 1:
                return Decision(+5, "up")
            return Decision(-3, "down")   # engine must cap at -1

    tr = generate_trace("england", seed=1)
    res = run_scenario(tr, Upper(), SimConfig())
    u = res.units_t
    # at t=60 decision +5 -> available at t=120; the t=120 tick's -3 cancels
    # one still-pending unit (pending-cancel downscale fix; the pre-fix
    # controller refused to act because *live* units sat at the floor and then
    # let all 5 pending land anyway), so 4 of the 5 arrive
    assert u[115] == 1 and u[125] == 5
    # afterwards releases at most 1 per 60 s
    diffs = np.diff(u[125:1000].astype(int))
    assert diffs.min() >= -1


def test_load_policy_multiplicative_upscale():
    sm = ServiceModel()
    pol = LoadPolicy(sm, quantile=0.99999, sla_s=300.0)
    obs = Observation(time=0, n_units=2, n_pending=0, utilization=1.0,
                      n_in_system=200_000, input_rate=100.0,
                      app_window_mean=0, app_prev_window_mean=0, app_window_count=0)
    d = pol.decide(obs)
    assert d.delta > 5   # jumps by many units at once, not +1
