"""Distribution-layer tests on 8 forced host devices (subprocess: jax fixes the
device count at first init, so these run in children)."""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str) -> str:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": os.path.join(ROOT, "src")}
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    return p.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.optim import AdamWConfig, adamw_init
        from repro.training import make_train_step, train_state_shardings
        from repro.distributed.sharding import batch_sharding, param_sharding

        cfg = get_smoke_config('qwen2.5-3b')
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        opt = adamw_init(params)
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)
        batch = {'tokens': toks, 'targets': toks}
        step = make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10))

        # single device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        with mesh:
            p_sh, o_sh, b_sh = train_state_shardings(model, mesh,
                jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch))
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            p2, o2, m2 = fn(jax.device_put(params, p_sh),
                            jax.device_put(opt, o_sh),
                            jax.device_put(batch, b_sh))
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-2, (m1['loss'], m2['loss'])
        d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                                    b.astype(jnp.float32)).max()), p1, p2)
        md = max(jax.tree.leaves(d))
        assert md < 0.05, md
        print('SHARDED_OK', float(m1['loss']), md)
    """)
    assert "SHARDED_OK" in out


def test_elastic_remesh_preserves_values():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.core.elastic.remesh import scale_replicas

        cfg = get_smoke_config('smollm-360m')
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        ref = jax.tree.map(lambda a: np.asarray(a, np.float32), params)
        devs = jax.devices()
        for n, tp in [(4, 2), (8, 2), (4, 4), (2, 2)]:
            mesh, params = scale_replicas(params, devices=devs[:n], model_parallel=tp)
            cur = jax.tree.map(lambda a: np.asarray(a, np.float32), params)
            for r, c in zip(jax.tree.leaves(ref), jax.tree.leaves(cur)):
                np.testing.assert_array_equal(r, c)
        print('REMESH_OK')
    """)
    assert "REMESH_OK" in out


def test_checkpoint_restore_resharded():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.checkpoint import save_checkpoint, restore_resharded
        from repro.distributed.sharding import param_sharding

        cfg = get_smoke_config('smollm-135m')
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        d = tempfile.mkdtemp()
        p = os.path.join(d, 'ck.npz')
        save_checkpoint(p, params, step=1)
        # restore onto a DIFFERENT mesh shape than the save-time layout
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        sh = param_sharding(abstract, mesh)
        restored, meta = restore_resharded(p, params, sh)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        print('RESHARD_OK')
    """)
    assert "RESHARD_OK" in out


def test_int8_pod_gradient_compression():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (
            compress_allreduce_pod, init_error_state)

        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        grads = {'w': jnp.linspace(-1, 1, 64).reshape(8, 8)}
        err = init_error_state(grads)

        @partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                 out_specs=(P(), P()), check_rep=False,
                 auto=frozenset({'data', 'model'}))
        def f(g, e):
            return compress_allreduce_pod(g, e)

        with mesh:                      # partial-auto shard_map needs the mesh context
            red, new_err = jax.jit(f)(grads, err)
        # identical replicas => reduction == original up to int8 error
        q_err = float(jnp.abs(red['w'] - grads['w']).max())
        assert q_err < 2.0 / 127.0, q_err
        # error feedback: residual matches quantization error exactly
        assert float(jnp.abs(new_err['w'] + red['w'] - grads['w'] - err['w']).max()) < 1e-6
        print('COMPRESS_OK', q_err)
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_cell_small_mesh():
    """A miniature dry-run cell: lower+compile on an in-test 8-device mesh."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.optim import AdamWConfig, adamw_init
        from repro.training import make_train_step, train_state_shardings

        cfg = get_smoke_config('olmoe-1b-7b')     # MoE: exercises EP sharding
        model = build_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        with mesh:
            p_abs = model.abstract_params()
            specs = {'tokens': jax.ShapeDtypeStruct((8, 32), jnp.int32),
                     'targets': jax.ShapeDtypeStruct((8, 32), jnp.int32)}
            step = make_train_step(model, AdamWConfig())
            p_sh, o_sh, b_sh = train_state_shardings(model, mesh, specs)
            o_abs = jax.eval_shape(adamw_init, p_abs)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            compiled = fn.lower(p_abs, o_abs, specs).compile()
            assert compiled.cost_analysis() is not None
        print('MINIDRYRUN_OK')
    """)
    assert "MINIDRYRUN_OK" in out
