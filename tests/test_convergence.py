"""Convergence-plane tests: desired-state derivation, the pure planner
(including idempotence on a converged fleet), the converger's healing /
retry / backoff / give-up discipline under injected faults, fault-free
golden parity with the imperative controller (simulator goldens bit-for-bit),
audit-log replay, and scaling-group config validation with scheduled and
webhook desired-state changes."""
import json

import pytest

from repro.core.autoscaler import (
    AppDataPolicy,
    CompositePolicy,
    Decision,
    LoadPolicy,
    Policy,
    ThresholdPolicy,
    WebhookPolicy,
)
from repro.core.autoscaler.base import Observation
from repro.core.convergence import (
    AuditIntegrityError,
    AuditLog,
    CancelPending,
    Converger,
    ConvergerConfig,
    DesiredGroup,
    DrainUnit,
    FaultInjector,
    FaultSpec,
    LaunchUnit,
    PoolTarget,
    ReplaceUnhealthy,
    ScalingGroup,
    ScriptedFault,
    ScriptedFaults,
    StepExecutor,
    derive_desired,
    observed_group,
    plan_steps,
    replay,
    validate_group_config,
    verify_plan_replay,
)
from repro.core.scaling import (
    CapacityPlan,
    ControllerConfig,
    PoolStats,
    ScalingController,
    SignalBus,
    UnitPool,
)


# ---------------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------------

class _Script(Policy):
    name = "script"

    def __init__(self, deltas):
        self.deltas = list(deltas)
        self.i = 0

    def reset(self):
        self.i = 0

    def decide(self, obs):
        d = self.deltas[self.i] if self.i < len(self.deltas) else 0
        self.i += 1
        if isinstance(d, dict):
            return Decision(0, "scripted", pools=d)
        return Decision(d, "scripted")


def _drive(ctrl, n_steps, *, step_s=1.0):
    units = []
    for k in range(n_steps):
        units.append(ctrl.on_step_start(k * step_s))
        ctrl.note_step(0.5, 0)
        ctrl.maybe_adapt(time=(k + 1) * step_s, n_in_system=0)
    return units


def _ctrl(policy, *, convergence, starting=1, pools=None, faults=None,
          converge=None, max_units=8, adapt=10.0, delay=20.0, audit_path=None):
    cfg = ControllerConfig(adapt_period_s=adapt, provision_delay_s=delay,
                           min_units=1, max_units=max_units, step_s=1.0,
                           app_window_s=adapt, pools=pools,
                           convergence=convergence, faults=faults,
                           converge=converge, audit_path=audit_path)
    return ScalingController(policy, cfg, SignalBus(("app",), bin_s=1.0),
                             starting_units=starting)


def _stats(**pools):
    """PoolStats shorthand: name=(units, pending, cost, min, max[, unhealthy])."""
    out = {}
    for name, spec in pools.items():
        units, pending, cost, mn, mx = spec[:5]
        unhealthy = spec[5] if len(spec) > 5 else 0
        out[name] = PoolStats(units=units, pending=pending, cost_rate=cost,
                              min_units=mn, max_units=mx, unhealthy=unhealthy)
    return out


def _final_state(plan):
    return {name: {"live": s.units, "pending": s.pending}
            for name, s in plan.stats().items()}


# ---------------------------------------------------------------------------------
# desired-state derivation (the policy -> target adapter)
# ---------------------------------------------------------------------------------

def test_derive_from_observed_and_positive_delta_clamps_to_ceiling():
    stats = _stats(od=(2, 1, 3.0, 1, 4))
    d = derive_desired(None, stats, {"od": 5})
    assert d.target_of("od") == 4                # 2+1 +5 clamped to max_units
    assert d.targets["od"].min_units == 1
    # no deltas: desired ratifies observed
    assert derive_desired(None, stats, {}).target_of("od") == 3
    assert observed_group(stats).target_of("od") == 3


def test_derive_persists_previous_targets():
    stats = _stats(od=(2, 0, 3.0, 1, 8))
    prev = DesiredGroup({"od": PoolTarget(target=5, min_units=1, max_units=8)})
    # observed dropped to 2 (faults) but desired stays 5 without a new vote
    assert derive_desired(prev, stats, {}).target_of("od") == 5
    assert derive_desired(prev, stats, {"od": 1}).target_of("od") == 6


def test_derive_downscale_cap_and_expensive_first_distribution():
    stats = _stats(od=(3, 0, 3.0, 1, 8), spot=(2, 2, 1.0, 0, 8))
    # net down-vote of 3 capped at 1 per tick; expensive od has no pending,
    # so pass 1 cancels nothing there... but od is the pricier pool and has
    # live above floor only after spot's pending is considered.  Pass 1
    # (cancellable pending) runs expensive-first over ALL pools: od none,
    # spot 2 -> the single capped unit comes off spot's pending.
    d = derive_desired(None, stats, {"od": -3})
    assert d.target_of("od") == 3 and d.target_of("spot") == 3
    # cap raised: after spot's pending, live sheds expensive-first to floors
    d = derive_desired(None, stats, {"od": -9}, downscale_cap=6)
    assert d.target_of("od") == 1                 # od live -> floor (pass 2)
    assert d.target_of("spot") == 0               # pending + live both taken
    # floor binds: nothing left to take
    d = derive_desired(None, stats, {"od": -20}, downscale_cap=20)
    assert d.target_of("od") == 1 and d.target_of("spot") == 0


def test_derive_unknown_pool_fails_loudly():
    with pytest.raises(ValueError, match="unknown pool"):
        derive_desired(None, _stats(od=(1, 0, 1.0, 0, 4)), {"Spot": 1})


# ---------------------------------------------------------------------------------
# the pure planner
# ---------------------------------------------------------------------------------

def test_planner_idempotent_on_converged_state():
    """Satellite: re-planning a converged fleet emits zero steps."""
    stats = _stats(od=(3, 1, 3.0, 1, 8), spot=(2, 0, 1.0, 0, 8))
    desired = observed_group(stats)
    assert plan_steps(desired, stats) == []
    # and planning the same diff twice yields the same steps (pure function)
    desired2 = DesiredGroup({"od": PoolTarget(6, 1, 8),
                             "spot": PoolTarget(0, 0, 8)})
    assert plan_steps(desired2, stats) == plan_steps(desired2, stats)


def test_planner_launch_cancel_drain_split():
    stats = _stats(od=(3, 2, 3.0, 1, 8), spot=(1, 0, 1.0, 0, 8))
    desired = DesiredGroup({"od": PoolTarget(2, 1, 8),
                            "spot": PoolTarget(4, 0, 8)})
    steps = plan_steps(desired, stats)
    # od surplus 3: cancel both pending first, then drain 1 live (floor 1
    # allows 2, surplus only needs 1); spot deficit 3: launch
    assert CancelPending("od", 2) in steps
    assert DrainUnit("od", 1) in steps
    assert steps[-1] == LaunchUnit("spot", 3)
    # downs come before ups so freed headroom is usable in the same tick
    assert [type(s) for s in steps] == [CancelPending, DrainUnit, LaunchUnit]


def test_planner_drain_respects_floor():
    stats = _stats(od=(2, 0, 3.0, 2, 8))
    steps = plan_steps(DesiredGroup({"od": PoolTarget(0, 2, 8)}), stats)
    assert steps == []                            # live at floor: nothing to do


def test_planner_stuck_cancel_and_blocked_launch():
    stats = _stats(od=(1, 3, 3.0, 1, 8))
    desired = DesiredGroup({"od": PoolTarget(4, 1, 8)})
    steps = plan_steps(desired, stats, overdue={"od": 3})
    # the 3 stuck builds are cancelled and relaunched in the same plan
    assert steps == [CancelPending("od", 3, reason="stuck"),
                     LaunchUnit("od", 3)]
    # a pool in retry backoff cancels but does not relaunch
    steps = plan_steps(desired, stats, overdue={"od": 3},
                       launch_blocked={"od"})
    assert steps == [CancelPending("od", 3, reason="stuck")]


def test_planner_replace_unhealthy_and_flap_damping():
    stats = _stats(od=(4, 0, 3.0, 1, 8, 2))
    desired = observed_group(stats)
    assert plan_steps(desired, stats) == [ReplaceUnhealthy("od", 2)]
    assert plan_steps(desired, stats, replace_blocked={"od"}) == []


# ---------------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------------

def test_fault_spec_validation_and_windowing():
    with pytest.raises(ValueError, match="loss_rate"):
        FaultSpec(loss_rate=-1.0)
    with pytest.raises(ValueError, match="stuck_p"):
        FaultSpec(stuck_p=1.5)
    with pytest.raises(ValueError, match="end_s"):
        FaultSpec(start_s=10.0, end_s=5.0)
    with pytest.raises(ValueError, match="brownout_factor"):
        FaultSpec(brownout_factor=0.5)
    with pytest.raises(ValueError, match="corr_loss_p"):
        FaultSpec(corr_loss_p=-0.1)
    with pytest.raises(ValueError, match="corr_loss_frac"):
        FaultSpec(corr_loss_frac=0.0)
    spec = FaultSpec(pool="od", loss_rate=0.1, start_s=10.0, end_s=20.0)
    assert spec.active("od", 10.0) and not spec.active("od", 20.0)
    assert not spec.active("spot", 15.0)
    assert FaultSpec(loss_rate=0.1).active("anything", 1e9)


def test_fault_injector_is_seeded_and_deterministic():
    mk = lambda: FaultInjector((FaultSpec(loss_rate=0.05, stuck_p=0.3,
                                          seed=11),))
    a, b = mk(), mk()
    draws_a = [a.step_draws("p", 10, 0, float(t), 1.0) for t in range(100)]
    draws_b = [b.step_draws("p", 10, 0, float(t), 1.0) for t in range(100)]
    assert draws_a == draws_b
    assert any(lost for lost, _, _ in draws_a)
    sa = [a.stuck_builds("p", 5, 0.0) for _ in range(50)]
    sb = [b.stuck_builds("p", 5, 0.0) for _ in range(50)]
    assert sa == sb and 0 < sum(sa) < 250
    a.reset()
    assert [a.step_draws("p", 10, 0, float(t), 1.0)
            for t in range(100)] == draws_a


def test_plan_threads_stuck_builds_through_pending():
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=10.0, max_units=8),),
        starting_units=1,
        faults=FaultInjector((FaultSpec(stuck_p=1.0, seed=0),)))
    assert plan.request("od", 3, now=0.0) == 3    # queued, but all stuck
    assert plan.pending_of("od") == 3             # observably pending
    plan.land(100.0)
    assert plan.live_of("od") == 1                # they never land
    assert plan.overdue_pending("od", 100.0, 30.0) == 3
    assert plan.cancel_pending("od", 3) == 3
    assert plan.pending_of("od") == 0
    assert plan.meters()["od"].cancelled == 3


def test_brownout_build_lands_late_but_lands():
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=10.0, max_units=8),),
        starting_units=1,
        faults=FaultInjector((FaultSpec(brownout_factor=4.0, seed=1),)))
    assert plan.request("od", 2, now=0.0) == 2
    assert plan.fault_events[-1].kind == "brownout"
    assert plan.pending_of("od") == 2             # observably pending
    plan.land(10.0)                               # the PROMISED landing time
    assert plan.live_of("od") == 1                # ...nothing arrives
    # overdue keys off the promise, so the converger can SEE the brownout
    # long before the real landing at 10 s * factor 4
    assert plan.overdue_pending("od", 25.0, 10.0) == 2
    plan.land(40.0)
    assert plan.live_of("od") == 3 and plan.pending_of("od") == 0
    assert plan.meters()["od"].landed == 2


def test_cancel_order_stuck_then_brownout_then_healthy():
    inj = FaultInjector((
        FaultSpec(stuck_p=1.0, start_s=0.0, end_s=1.0, seed=2),
        FaultSpec(brownout_factor=4.0, start_s=10.0, end_s=11.0, seed=2),
    ))
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=10.0, max_units=8),),
        starting_units=1, faults=inj)
    plan.request("od", 1, now=0.0)      # sticks forever
    plan.request("od", 1, now=10.0)     # browned out: would land at 50 s
    plan.request("od", 1, now=20.0)     # healthy: lands at 30 s
    assert plan.pending_of("od") == 3
    assert [e.kind for e in plan.fault_events] == ["stuck_build", "brownout"]
    # worthless capacity goes first: the stuck build, then the build that
    # lands LATEST (browned out), and only then healthy pending
    assert plan.cancel_pending("od", 2) == 2
    plan.land(30.0)
    assert plan.live_of("od") == 2      # the healthy build survived
    plan.land(60.0)
    assert plan.live_of("od") == 2 and plan.pending_of("od") == 0
    m = plan.meters()["od"]
    assert m.cancelled == 2 and m.landed == 1


def test_corr_loss_shares_one_draw_across_pools_and_is_deterministic():
    spec = FaultSpec(corr_loss_p=0.25, corr_loss_frac=0.5, seed=9)
    inj = FaultInjector((spec,))
    # the event fires once per (spec, step): every pool the spec covers is
    # hit in the SAME step -- that shared draw is the correlation
    a = [inj.corr_loss("a", 4, float(t), 1.0) for t in range(200)]
    b = [inj.corr_loss("b", 4, float(t), 1.0) for t in range(200)]
    assert a == b and set(a) == {0, 2}            # ceil(0.5 * 4) on events
    assert 0 < sum(1 for x in a if x) < 200
    inj.reset()
    assert [inj.corr_loss("a", 4, float(t), 1.0) for t in range(200)] == a
    fresh = FaultInjector((spec,))
    assert [fresh.corr_loss("a", 4, float(t), 1.0) for t in range(200)] == a

    # through the plan: one AZ-scale event takes half of BOTH pools at once
    plan = CapacityPlan(
        (UnitPool("a", provision_delay_s=1.0, max_units=8),
         UnitPool("b", provision_delay_s=1.0, max_units=8)),
        starting_units=4,
        faults=FaultInjector((FaultSpec(corr_loss_p=1.0, corr_loss_frac=0.5,
                                        start_s=5.0, end_s=6.0, seed=9),)))
    plan.request("b", 4, now=0.0)
    plan.land(1.0)
    assert plan.live_of("a") == 4 and plan.live_of("b") == 4
    plan.land(5.0)                                # window: the event fires
    assert plan.live_of("a") == 2 and plan.live_of("b") == 2
    hits = [e for e in plan.fault_events if e.kind == "corr_loss"]
    assert {(e.pool, e.time, e.count) for e in hits} == \
        {("a", 5.0, 2), ("b", 5.0, 2)}
    assert plan.meters()["a"].lost == 2 and plan.meters()["b"].lost == 2


# ---------------------------------------------------------------------------------
# converger: healing, retries, backoff, give-up
# ---------------------------------------------------------------------------------

class _Hold(Policy):
    name = "hold"

    def decide(self, obs):
        return Decision(0, "hold")


def test_converger_heals_unit_loss_imperative_stays_degraded():
    faults = (FaultSpec(loss_rate=1 / 50.0, start_s=100.0, end_s=200.0,
                        seed=7),)
    imp = _ctrl(_Hold(), convergence=False, starting=5, faults=faults,
                delay=10.0)
    conv = _ctrl(_Hold(), convergence=True, starting=5, faults=faults,
                 delay=10.0,
                 converge=ConvergerConfig(build_timeout_s=15.0))
    ui = _drive(imp, 600)
    uc = _drive(conv, 600)
    assert ui[-1] < 5                   # losses are never healed
    assert uc[-1] == 5                  # converger relaunched every loss
    assert sum(uc) > sum(ui)
    # the audit log accounts for every lost unit
    lost = sum(r.get("lost", 0) for r in conv.audit.records
               if r["kind"] == "events")
    assert lost == conv.plan.meters()["on-demand"].lost > 0
    assert replay(conv.audit.records) == _final_state(conv.plan)


def test_converger_cancels_stuck_builds_and_retries():
    faults = (FaultSpec(stuck_p=0.9, start_s=100.0, end_s=160.0, seed=3),)
    script = [0] * 11 + [4]             # upscale lands inside the fault window

    def run(convergence):
        ctrl = _ctrl(_Script(script), convergence=convergence, starting=1,
                     faults=faults, delay=10.0,
                     converge=ConvergerConfig(build_timeout_s=12.0,
                                              backoff_base_s=4.0,
                                              max_retries=8))
        units = _drive(ctrl, 600)
        return units, ctrl

    ui, imp = run(False)
    uc, conv = run(True)
    assert ui[-1] < 5 and imp.plan.total_pending > 0   # clogged forever
    assert uc[-1] == 5 and conv.plan.total_pending == 0
    # the retry discipline left its trace: backoff records, then success
    kinds = [r["kind"] for r in conv.audit.records]
    assert "backoff" in kinds
    assert any(r["kind"] == "step" and r["step"] == "CancelPending"
               and r.get("reason") == "stuck" for r in conv.audit.records)
    assert replay(conv.audit.records) == _final_state(conv.plan)


def test_converger_gives_up_after_max_retries_and_desired_change_resets():
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=5.0, max_units=8),),
        starting_units=1,
        faults=FaultInjector((FaultSpec(stuck_p=1.0, seed=0),)))
    conv = Converger(plan, ConvergerConfig(build_timeout_s=5.0,
                                           backoff_base_s=2.0,
                                           backoff_max_s=16.0, max_retries=2),
                     audit=AuditLog())
    conv.set_desired(DesiredGroup({"od": PoolTarget(3, 1, 8)}), 0.0)
    t = 0.0
    for _ in range(200):
        plan.land(t)
        conv.converge(t)
        t += 1.0
    # every build sticks: after max_retries the pool is parked
    assert any(r["kind"] == "gave_up" for r in conv.audit.records)
    assert plan.pending_of("od") == 0            # last stuck batch cancelled
    launches_before = sum(r["applied"] for r in conv.audit.records
                          if r["kind"] == "step" and r["step"] == "LaunchUnit")
    conv.converge(t)
    assert sum(r["applied"] for r in conv.audit.records
               if r["kind"] == "step" and r["step"] == "LaunchUnit") == \
        launches_before                          # parked: no new launches
    # a new desired target un-parks the pool
    conv.set_desired(DesiredGroup({"od": PoolTarget(4, 1, 8)}), t)
    out = conv.converge(t)
    assert any(isinstance(o.step, LaunchUnit) and o.applied > 0 for o in out)


def test_converger_replaces_flapping_units_with_damping():
    faults = (FaultSpec(flap_rate=1 / 10.0, heal_rate=0.0, start_s=50.0,
                        end_s=80.0, seed=1),)
    conv = _ctrl(_Hold(), convergence=True, starting=4, faults=faults,
                 delay=5.0,
                 converge=ConvergerConfig(build_timeout_s=15.0,
                                          replace_backoff_s=30.0))
    _drive(conv, 300)
    replaces = [r for r in conv.audit.records
                if r["kind"] == "step" and r["step"] == "ReplaceUnhealthy"]
    assert replaces                               # flapped units were replaced
    # damping: consecutive replacements in one pool are >= replace_backoff_s apart
    times = [r["t"] for r in replaces]
    assert all(b - a >= 30.0 for a, b in zip(times, times[1:]))
    assert conv.plan.stats()["on-demand"].unhealthy == 0
    assert conv.units == 4
    assert replay(conv.audit.records) == _final_state(conv.plan)


class _RecordingExecutor:
    """StepExecutor that records every actuation before delegating to the
    plan -- the seam repro.serving.fleet.FleetExecutor plugs into."""

    def __init__(self, plan):
        self.plan = plan
        self.calls = []

    def launch(self, pool, count, now):
        self.calls.append(("launch", pool, count, now))
        return self.plan.request(pool, count, now)

    def cancel_pending(self, pool, count, now):
        self.calls.append(("cancel_pending", pool, count, now))
        return self.plan.cancel_pending(pool, count)

    def drain(self, pool, count, now):
        self.calls.append(("drain", pool, count, now))
        return self.plan.drain(pool, count)

    def replace_unhealthy(self, pool, count, now):
        self.calls.append(("replace_unhealthy", pool, count, now))
        return self.plan.replace_unhealthy(pool, count, now)


def test_controller_routes_convergence_steps_through_custom_executor():
    """executor_factory is the engine-actuation seam: every convergence step
    flows through the bound executor, and reset() rebinds it to the rebuilt
    plan (a stale binding would actuate a dead plan object)."""
    made = []

    def factory(plan):
        made.append(_RecordingExecutor(plan))
        return made[-1]

    cfg = ControllerConfig(adapt_period_s=5.0, provision_delay_s=2.0,
                           min_units=1, max_units=8, step_s=1.0,
                           app_window_s=5.0, convergence=True)
    ctrl = ScalingController(_Script([2]), cfg, SignalBus(("app",), bin_s=1.0),
                             starting_units=1, executor_factory=factory)
    assert isinstance(made[-1], StepExecutor)     # satisfies the protocol
    assert made[-1].plan is ctrl.plan
    _drive(ctrl, 12)
    launches = [c for c in made[-1].calls if c[0] == "launch"]
    assert launches and sum(c[2] for c in launches) == 2
    assert ctrl.units == 3                        # the launches really landed
    ctrl.reset()
    assert len(made) == 2 and made[-1].plan is ctrl.plan
    assert made[-1].plan is not made[-2].plan


# ---------------------------------------------------------------------------------
# fault-free parity with the imperative controller
# ---------------------------------------------------------------------------------

def test_scripted_parity_scalar_and_multipool():
    """Same scripts, same configs: convergence mode must actuate identically
    (units trajectory, counters, decision records) with no faults injected."""
    script = [5, 0, -3, 0, 0, 2, -1, -1, 0, 8, 0, -2] * 3

    def fingerprint(ctrl, units):
        return (units, ctrl.n_up, ctrl.n_down,
                [(r.applied, r.units, r.pending, r.pool_deltas)
                 for r in ctrl.decision_log])

    for pools, scr in (
        (None, script),
        ((UnitPool("od", provision_delay_s=20.0, cost_rate=3.0, min_units=1,
                   max_units=4),
          UnitPool("spot", provision_delay_s=5.0, cost_rate=1.0, max_units=3)),
         [{"spot": 3}, 0, {"od": 2, "spot": -1}, 0, -2, 0, {"spot": 5}, -1,
          0, 0] * 3),
    ):
        imp = _ctrl(_Script(scr), convergence=False, pools=pools, max_units=6)
        conv = _ctrl(_Script(scr), convergence=True, pools=pools, max_units=6)
        fi = fingerprint(imp, _drive(imp, 400))
        fc = fingerprint(conv, _drive(conv, 400))
        assert fi == fc
        assert replay(conv.audit.records) == _final_state(conv.plan)


def test_simulator_golden_parity_in_convergence_mode():
    """Acceptance: convergence mode, no faults, single on-demand pool ->
    the simulator goldens are bit-for-bit the imperative controller's."""
    from test_scaling import GOLDEN_ENGLAND
    from repro.core.simulator import SimConfig, generate_trace, run_scenario
    from repro.core.simulator.distributions import ServiceModel

    def fingerprint(r):
        return (r.violation_rate, r.cpu_seconds, r.n_decisions_up,
                r.n_decisions_down, float(r.delays.sum()),
                int(r.units_t.sum()), int(r.units_t.max()))

    sm = ServiceModel()
    tr = generate_trace("england", seed=0)
    cfg = SimConfig(convergence=True)
    assert fingerprint(run_scenario(tr, ThresholdPolicy(0.9), cfg)) == \
        GOLDEN_ENGLAND["threshold"]
    pol = CompositePolicy([LoadPolicy(sm, quantile=0.99999),
                           AppDataPolicy(extra_units=5)])
    assert fingerprint(run_scenario(tr, pol, cfg)) == \
        GOLDEN_ENGLAND["load+appdata"]


# ---------------------------------------------------------------------------------
# audit log
# ---------------------------------------------------------------------------------

def test_audit_jsonl_roundtrip_and_replay(tmp_path):
    path = str(tmp_path / "audit.jsonl")
    conv = _ctrl(_Script([3, 0, -1, 0, 2]), convergence=True, starting=2,
                 delay=5.0, audit_path=path)
    _drive(conv, 80)
    conv.audit.close()
    loaded = AuditLog.load(path)
    assert loaded == conv.audit.records
    assert all(set(r) >= {"t", "kind"} for r in loaded)
    # the file is genuine JSONL: one object per line
    with open(path) as fh:
        assert all(isinstance(json.loads(line), dict) for line in fh)
    assert replay(loaded) == _final_state(conv.plan)
    kinds = {r["kind"] for r in loaded}
    assert {"init", "desired", "plan", "step", "events"} <= kinds


# ---------------------------------------------------------------------------------
# scaling groups: schema validation, scheduled + webhook desired changes
# ---------------------------------------------------------------------------------

_GROUP_CFG = {
    "name": "web",
    "pools": [
        {"name": "od", "provision_delay_s": 10.0, "cost_rate": 3.0,
         "min_units": 1, "max_units": 8},
        {"name": "spot", "provision_delay_s": 5.0, "cost_rate": 1.0,
         "max_units": 4},
    ],
    "schedule": [
        {"at_s": 100.0, "end_s": 200.0, "targets": {"od": 4}},
    ],
    "webhooks": [
        {"name": "breaking-news", "hold_s": 60.0, "targets": {"od": 6}},
    ],
}


def test_group_config_validation_errors_name_their_path():
    validate_group_config(_GROUP_CFG)             # the happy path
    bad = {**_GROUP_CFG, "pools": [{"name": "od", "cost_rate": "cheap"}]}
    with pytest.raises(ValueError, match=r"pools\[0\]\.cost_rate.*number"):
        validate_group_config(bad)
    with pytest.raises(ValueError, match="required key missing"):
        validate_group_config({"name": "g"})
    with pytest.raises(ValueError, match=r"unknown key.*typo"):
        validate_group_config({**_GROUP_CFG, "typo": 1})
    bad = {**_GROUP_CFG,
           "schedule": [{"at_s": 5.0, "end_s": 1.0, "targets": {"od": 1}}]}
    with pytest.raises(ValueError, match=r"schedule\[0\]\.end_s"):
        validate_group_config(bad)
    bad = {**_GROUP_CFG,
           "webhooks": [{"name": "x", "hold_s": 1.0,
                         "targets": {"nope": 2}}]}
    with pytest.raises(ValueError, match=r"webhooks\[0\]\.targets.*'nope'"):
        validate_group_config(bad)
    bad = {**_GROUP_CFG,
           "schedule": [{"at_s": 0.0, "end_s": 1.0, "targets": {"od": True}}]}
    with pytest.raises(ValueError, match="expected int"):
        validate_group_config(bad)


def test_group_scheduled_and_webhook_floors_overlay_desired():
    grp = ScalingGroup.from_config(_GROUP_CFG)
    desired = DesiredGroup({"od": PoolTarget(2, 1, 8),
                            "spot": PoolTarget(1, 0, 4)})
    assert grp.overlay(desired, 50.0).target_of("od") == 2    # outside window
    assert grp.overlay(desired, 150.0).target_of("od") == 4   # scheduled floor
    assert grp.overlay(desired, 150.0).target_of("spot") == 1
    grp.fire("breaking-news", 150.0)
    assert grp.overlay(desired, 150.0).target_of("od") == 6   # webhook wins
    assert grp.overlay(desired, 211.0).target_of("od") == 2   # both expired
    with pytest.raises(ValueError, match="unknown webhook"):
        grp.fire("nope", 0.0)
    grp.reset()
    assert grp.overlay(desired, 150.0).target_of("od") == 4


def test_group_drives_convergence_controller_end_to_end():
    grp = ScalingGroup.from_config(_GROUP_CFG)
    cfg = ControllerConfig(adapt_period_s=10.0, step_s=1.0,
                           app_window_s=10.0, group=grp, convergence=True)
    ctrl = ScalingController(_Hold(), cfg, SignalBus(("app",), bin_s=1.0),
                             starting_units=1)
    hist = _drive(ctrl, 90)
    assert hist[50] == 1                          # before the window: baseline
    ctrl.fire_webhook("breaking-news", 90.0)
    hist += _drive_from(ctrl, 90, 160)
    # scheduled floor (4) took effect after t=100+delay; webhook raised to 6
    assert ctrl.plan.live_of("od") == 6
    assert any(r["kind"] == "webhook" for r in ctrl.audit.records)
    assert replay(ctrl.audit.records) == _final_state(ctrl.plan)


def _drive_from(ctrl, t0, t1):
    units = []
    for k in range(int(t0), int(t1)):
        units.append(ctrl.on_step_start(float(k)))
        ctrl.note_step(0.5, 0)
        ctrl.maybe_adapt(time=float(k + 1), n_in_system=0)
    return units


def test_webhook_policy_imperative_mode():
    pol = WebhookPolicy({"spike": (5, 30.0)},
                        schedule=((100.0, 200.0, 3),))
    obs = lambda t, n: Observation(time=t, n_units=n, n_pending=0,
                                   utilization=0.5, n_in_system=0,
                                   input_rate=0.0)
    assert pol.decide(obs(0.0, 1)).delta == 0     # nothing active
    pol.fire("spike", 10.0)
    assert pol.decide(obs(10.0, 1)).delta == 4    # floor 5 - have 1
    assert pol.decide(obs(45.0, 1)).delta == 0    # hold expired
    assert pol.decide(obs(150.0, 1)).delta == 2   # scheduled window floor 3
    with pytest.raises(ValueError, match="unknown webhook"):
        pol.fire("nope", 0.0)
    pol.reset()
    assert pol.decide(obs(10.0, 1)).delta == 0
    # the group's imperative fallback wires both paths together
    grp = ScalingGroup.from_config(_GROUP_CFG)
    gp = grp.as_policy()
    assert gp.decide(obs(150.0, 1)).delta == 3    # scheduled total floor 4
    gp.fire("breaking-news", 150.0)
    assert gp.decide(obs(150.0, 1)).delta == 5    # webhook total floor 6


# ---------------------------------------------------------------------------------
# incident hardening: scripted faults, generation/supersede, sealed audit logs
# ---------------------------------------------------------------------------------

def test_scripted_faults_fire_exactly_on_schedule():
    """ScriptedFaults is the deterministic injector behind chaos drills:
    point events land in the step containing their timestamp (exactly once),
    windows cover [at_s, until_s), and corr_lose hits every matching pool in
    the SAME step -- the correlation is the shared timeline, not a draw."""
    sf = ScriptedFaults((
        ScriptedFault(5.0, "lose", pool="od", count=2),
        ScriptedFault(8.0, "corr_lose", frac=0.5),
        ScriptedFault(10.0, "stick", pool="od", until_s=20.0),
        ScriptedFault(10.0, "brownout", pool="od", until_s=30.0, factor=3.0),
    ))
    assert sf.step_draws("od", 4, 0, 5.0, 1.0) == (2, 0, 0)
    assert sf.step_draws("od", 4, 0, 6.0, 1.0) == (0, 0, 0)
    assert sf.step_draws("spot", 4, 0, 5.0, 1.0) == (0, 0, 0)   # pool-scoped
    assert sf.corr_loss("od", 4, 8.0, 1.0) == 2
    assert sf.corr_loss("spot", 3, 8.0, 1.0) == 2               # same step
    assert sf.corr_loss("od", 4, 9.0, 1.0) == 0
    assert sf.stuck_builds("od", 3, 9.0) == 0
    assert sf.stuck_builds("od", 3, 10.0) == 3
    assert sf.stuck_builds("od", 3, 20.0) == 0                  # half-open
    assert sf.delay_factor("od", 15.0) == 3.0
    assert sf.delay_factor("od", 30.0) == 1.0
    sf.reset()                       # stateless: reset replays identically
    assert sf.step_draws("od", 4, 0, 5.0, 1.0) == (2, 0, 0)
    with pytest.raises(ValueError, match="kind"):
        ScriptedFault(0.0, "explode")
    with pytest.raises(ValueError, match="until_s"):
        ScriptedFault(5.0, "stick", until_s=5.0)
    with pytest.raises(TypeError):
        ScriptedFaults((FaultSpec(),))


def test_floor_raise_mid_backoff_discards_retry_state():
    """A desired-state change landing MID-BACKOFF supersedes the retry: the
    backoff gate and attempt budget are DISCARDED (not resumed), the
    generation bumps, and the converger launches immediately -- far inside
    what would have been the stale backoff window.  The operator's floor
    wins over the stale retry."""
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=5.0, max_units=8),),
        starting_units=1,
        faults=ScriptedFaults((ScriptedFault(0.0, "brownout", pool="od",
                                             until_s=40.0, factor=12.0),)))
    conv = Converger(plan, ConvergerConfig(build_timeout_s=5.0,
                                           backoff_base_s=100.0,
                                           backoff_max_s=400.0,
                                           max_retries=5),
                     audit=AuditLog())
    conv.set_desired(DesiredGroup({"od": PoolTarget(3, 1, 8)}), 0.0)
    gen0 = conv.desired.generation
    t = 0.0
    while t < 60.0 and not any(r["kind"] == "backoff"
                               for r in conv.audit.records):
        plan.land(t)
        conv.converge(t)
        t += 1.0
    gate = next(r for r in conv.audit.records if r["kind"] == "backoff")
    assert gate["until"] >= t + 90.0   # a LONG backoff is armed mid-incident
    # operator floor raise lands mid-retry
    conv.set_desired(DesiredGroup({"od": PoolTarget(5, 3, 8)}), t,
                     reason="webhook:floor")
    assert conv.desired.generation == gen0 + 1
    assert any(r["kind"] == "superseded" and r["pool"] == "od"
               for r in conv.audit.records)
    out = conv.converge(t)
    launched = [o for o in out
                if isinstance(o.step, LaunchUnit) and o.applied > 0]
    assert launched, "supersede did not un-gate the launch"
    assert t < gate["until"], "the launch happened inside the stale window"
    # every step after the supersede carries the new generation
    last_launch = [r for r in conv.audit.records
                   if r["kind"] == "step" and r["step"] == "LaunchUnit"][-1]
    assert last_launch["gen"] == gen0 + 1


def test_refresh_unparks_same_target_and_replays(tmp_path):
    """A webhook re-asserting an UNCHANGED numeric target still supersedes
    (refresh names the pool): the parked/backing-off pool un-parks, and the
    sealed audit log replays the planner's decisions byte-for-byte."""
    path = str(tmp_path / "audit.jsonl")
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=5.0, max_units=8),),
        starting_units=1,
        faults=ScriptedFaults((ScriptedFault(0.0, "stick", pool="od",
                                             until_s=25.0),)))
    conv = Converger(plan, ConvergerConfig(build_timeout_s=4.0,
                                           backoff_base_s=60.0,
                                           backoff_max_s=240.0,
                                           max_retries=5),
                     audit=AuditLog(path))
    conv.set_desired(DesiredGroup({"od": PoolTarget(3, 1, 8)}), 0.0)
    t = 0.0
    while t < 40.0 and not any(r["kind"] == "backoff"
                               for r in conv.audit.records):
        plan.land(t)
        conv.converge(t)
        t += 1.0
    gen_before = conv.desired.generation
    # same target, but the operator re-asserts it: refresh supersedes
    conv.set_desired(DesiredGroup({"od": PoolTarget(3, 1, 8)}), t,
                     reason="webhook:reassert", refresh=("od",))
    assert conv.desired.generation == gen_before + 1
    out = conv.converge(t)
    assert any(isinstance(o.step, LaunchUnit) and o.applied > 0 for o in out)
    conv.audit.seal(t)
    conv.audit.close()
    records = AuditLog.load(path, verify=True)
    checked, mismatches = verify_plan_replay(records)
    assert checked > 0 and mismatches == []


def test_audit_seal_verify_detects_truncation_and_tampering(tmp_path):
    """load(verify=True) mirrors the checkpoint store's .ok semantics: a
    clean sealed log round-trips; a missing seal, a torn JSON tail, a
    dropped record, or an in-place edit each raise AuditIntegrityError
    naming the failure."""
    path = str(tmp_path / "audit.jsonl")
    log = AuditLog(path)
    for k in range(5):
        log.append(float(k), "plan", gen=1, steps=[])
    log.seal(5.0)
    log.close()
    records = AuditLog.load(path, verify=True)
    assert records[-1]["kind"] == "seal" and records[-1]["n"] == 5
    with open(path) as fh:
        lines = fh.read().splitlines()

    def write(name, content_lines):
        p = str(tmp_path / name)
        with open(p, "w") as fh:
            fh.write("\n".join(content_lines) + "\n")
        return p

    # unsealed tail: the run was cut off mid-incident
    with pytest.raises(AuditIntegrityError, match="no terminal seal"):
        AuditLog.load(write("trunc.jsonl", lines[:-1]), verify=True)
    # torn write: half a record then EOF
    with pytest.raises(AuditIntegrityError, match="corrupt record"):
        AuditLog.load(write("torn.jsonl", lines[:-1] + ['{"t": 4.0, "ki']),
                      verify=True)
    # a dropped record: seal count no longer matches
    with pytest.raises(AuditIntegrityError, match="seal claims"):
        AuditLog.load(write("dropped.jsonl", lines[:2] + lines[3:]),
                      verify=True)
    # an in-place edit: CRC mismatch
    doctored = list(lines)
    doctored[1] = doctored[1].replace('"gen": 1', '"gen": 9')
    with pytest.raises(AuditIntegrityError, match="CRC mismatch"):
        AuditLog.load(write("edited.jsonl", doctored), verify=True)
    # unverified load still reads the unsealed file (forensics mode)
    assert len(AuditLog.load(str(tmp_path / "trunc.jsonl"))) == 5


def test_plan_replay_reproduces_faulted_run_decisions(tmp_path):
    """Full-fidelity replay of a FAULTED convergence run: re-running the
    pure planner over every plan record's logged inputs reproduces the
    converger's decisions exactly, and a doctored step is caught."""
    path = str(tmp_path / "audit.jsonl")
    faults = (FaultSpec(loss_rate=1 / 40.0, start_s=20.0, end_s=60.0,
                        seed=5),)
    ctrl = _ctrl(_Script([3, 0, -2, 0, 1]), convergence=True, starting=2,
                 delay=5.0, faults=faults, audit_path=path)
    _drive(ctrl, 120)
    ctrl.audit.seal(120.0)
    ctrl.audit.close()
    records = AuditLog.load(path, verify=True)
    checked, mismatches = verify_plan_replay(records)
    assert checked > 0 and mismatches == []
    assert replay(records) == _final_state(ctrl.plan)
    # a doctored step count is a steps mismatch
    doctored = [json.loads(json.dumps(r)) for r in records]
    plan_rec = next(r for r in doctored if r["kind"] == "plan" and r["steps"])
    plan_rec["steps"][0]["count"] += 1
    _, caught = verify_plan_replay(doctored)
    assert caught and caught[0]["kind"] == "steps"
    # a stale-generation plan is a generation mismatch
    doctored2 = [json.loads(json.dumps(r)) for r in records]
    plan_rec2 = next(r for r in doctored2 if r["kind"] == "plan")
    plan_rec2["gen"] = plan_rec2.get("gen", 0) + 7
    _, caught2 = verify_plan_replay(doctored2)
    assert any(m["kind"] == "generation" for m in caught2)
