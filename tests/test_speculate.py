"""Overlapped chunked prefill + speculative decode: acceptance-rule units,
fused lm-head epilogue exactness, mixed-span attention kernel oracle, KV
rollback page accounting, eos-mid-chunk, and the pinned token-exactness of
greedy speculative decode against the single-step oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.kvcache import TRASH_PAGE, PagedKVCache, _span_mask
from repro.serving.speculate import NGramProposer, RepeatProposer, prefix_len


@pytest.fixture(scope="module")
def smol():
    cfg = get_smoke_config("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    return cfg, m, params


# ---------------------------------------------------------------------------------
# acceptance rule + proposers
# ---------------------------------------------------------------------------------

def test_prefix_len_is_leading_run():
    m = jnp.array([[True, True, False, True],
                   [False, True, True, True],
                   [True, True, True, True]])
    assert prefix_len(m).tolist() == [2, 0, 4]


def test_ngram_proposer_prompt_lookup():
    hist = jnp.array([[1, 2, 3, 4, 1, 2, 0, 0],
                      [7, 7, 7, 7, 7, 0, 0, 0],
                      [5, 9, 9, 9, 9, 9, 9, 0]], jnp.int32)
    ell = jnp.array([6, 5, 7], jnp.int32)
    p = NGramProposer(draft_len=3, ngram=2)(hist, ell)
    # row 0: trailing bigram (1,2) matched at [1,2] -> copy hist[2:5]
    assert p[0].tolist() == [3, 4, 1]
    # row 1: all-same history -> latest match, continuation then repeat-last
    assert p[1].tolist() == [7, 7, 7]
    assert p[2].tolist() == [9, 9, 9]


def test_ngram_proposer_no_match_falls_back_to_repeat():
    hist = jnp.array([[3, 1, 4, 1, 5, 0]], jnp.int32)   # trailing (1,5) unique
    ell = jnp.array([5], jnp.int32)
    p = NGramProposer(draft_len=2, ngram=2)(hist, ell)
    assert p[0].tolist() == [5, 5]                       # repeat last token
    r = RepeatProposer(draft_len=2)(hist, ell)
    assert r[0].tolist() == [5, 5]


def test_ngram_proposer_short_history():
    hist = jnp.zeros((2, 8), jnp.int32).at[0, 0].set(4).at[1, 0].set(6)
    ell = jnp.array([1, 1], jnp.int32)                  # one token: no bigram
    p = NGramProposer(draft_len=2, ngram=2)(hist, ell)
    assert p.tolist() == [[4, 4], [6, 6]]


# ---------------------------------------------------------------------------------
# fused lm-head epilogue
# ---------------------------------------------------------------------------------

def test_fused_lmhead_matches_materialized_oracle():
    """All three routes (single fused matmul, streaming jnp blocks, Pallas
    kernel) are token-exact and logprob-close vs computing the (N, V)
    logits and log_softmax -- including non-dividing vocab blocks."""
    from repro.kernels.sampling.ops import fused_lmhead_greedy
    from repro.kernels.sampling.ref import lmhead_greedy_ref
    h = jax.random.normal(jax.random.key(5), (6, 32)) * 2.0
    w = jax.random.normal(jax.random.key(6), (32, 999))
    tok_ref, lp_ref = lmhead_greedy_ref(h, w)
    for kw in ({}, {"block_v": 250}, {"block_v": 64},
               {"use_kernel": True, "block_v": 256},
               {"use_kernel": True, "block_v": 4096}):
        tok, lp = fused_lmhead_greedy(h, w, **kw)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_ref)), kw
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_ref),
                                   atol=1e-5)


def test_fused_lmhead_verify_shape():
    """The d-token verify case (B, T, d) flattens through the same path."""
    from repro.kernels.sampling.ops import fused_lmhead_greedy
    from repro.kernels.sampling.ref import lmhead_greedy_ref
    h = jax.random.normal(jax.random.key(7), (3, 4, 16))
    w = jax.random.normal(jax.random.key(8), (16, 101))
    tok_ref, lp_ref = lmhead_greedy_ref(h, w)
    tok, lp = fused_lmhead_greedy(h, w, block_v=33)
    assert tok.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_ref))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_ref), atol=1e-5)


# ---------------------------------------------------------------------------------
# mixed-span paged attention
# ---------------------------------------------------------------------------------

def test_mixed_kernel_matches_gather_sdpa():
    """The T>1 block-table kernel == gather + span-masked SDPA, with and
    without a sliding window, at heterogeneous span starts."""
    from repro.kernels.decode_attention.ops import decode_attention_mixed
    from repro.models.attention import sdpa
    from repro.serving.kvcache import paged_gather
    B, T, Hq, Hkv, D, ps, n = 3, 4, 4, 2, 8, 4, 6
    ks = jax.random.split(jax.random.key(9), 3)
    kp = jax.random.normal(ks[0], (B * n + 1, ps, Hkv, D))
    vp = jax.random.normal(ks[1], (B * n + 1, ps, Hkv, D))
    q = jax.random.normal(ks[2], (B, T, Hq, D))
    tbl = jnp.arange(1, B * n + 1, dtype=jnp.int32).reshape(B, n)
    starts = jnp.array([0, 5, 13], jnp.int32)
    kd, vd = paged_gather(kp, tbl), paged_gather(vp, tbl)
    for win in (None, 3):
        out_k = decode_attention_mixed(q, kp, vp, tbl, starts, window=win)
        mask = _span_mask(n * ps, starts, T, jnp.int32(-1 if win is None else win))
        out_r = sdpa(q, kd, vd, mask)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-5)


def test_mixed_kernel_t1_equals_decode_kernel():
    """The T=1 slice of the mixed kernel is the plain paged decode kernel."""
    from repro.kernels.decode_attention.ops import (decode_attention_mixed,
                                                    decode_attention_paged)
    B, Hq, Hkv, D, ps, n = 2, 4, 2, 8, 4, 4
    ks = jax.random.split(jax.random.key(10), 3)
    kp = jax.random.normal(ks[0], (B * n + 1, ps, Hkv, D))
    vp = jax.random.normal(ks[1], (B * n + 1, ps, Hkv, D))
    q = jax.random.normal(ks[2], (B, 1, Hq, D))
    tbl = jnp.arange(1, B * n + 1, dtype=jnp.int32).reshape(B, n)
    pos = jnp.array([3, 11], jnp.int32)
    out_m = decode_attention_mixed(q, kp, vp, tbl, pos)
    out_d = decode_attention_paged(q[:, 0][:, None], kp, vp, tbl, pos + 1)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_d), atol=1e-5)


# ---------------------------------------------------------------------------------
# KV rollback / page accounting
# ---------------------------------------------------------------------------------

def _pool(max_batch=2, max_len=64, page_size=16):
    def init_cache(batch, seq):
        return {"k": jnp.zeros((1, batch, seq, 1, 4))}
    return PagedKVCache(init_cache, max_batch=max_batch, max_len=max_len,
                        page_size=page_size)


def test_shrink_to_returns_speculative_pages():
    """Worst-case span pre-allocation followed by rejection: shrink_to hands
    the over-held pages back, resets their table entries to TRASH, and the
    free-list conservation invariant holds throughout."""
    kv = _pool()
    kv.reserve(0, 40)                       # chunked admission: no pages yet
    assert kv.held[0] == 0 and kv.worst[0] == 3
    kv.ensure_writable_span(0, 0, 34)       # worst-case span: 3 pages
    assert kv.held[0] == 3
    kv.check_invariants()
    freed = kv.shrink_to(0, 17)             # only 17 tokens committed
    assert freed == 1
    assert kv.held[0] == 2
    assert kv.block_table[0, 2] == TRASH_PAGE
    kv.check_invariants()
    # rejected-within-page tokens shrink nothing: page still holds pos < 17
    assert kv.shrink_to(0, 20) == 0
    kv.release(0)
    assert kv.n_free == kv.num_pages - 1
    kv.check_invariants()


def test_shrink_then_regrow_across_page_boundary():
    """A page appended for a draft crossing a page boundary, rejected, then
    re-accepted: shrink returns it, ensure_writable_span re-appends (possibly
    a different physical page), conservation holds."""
    kv = _pool()
    kv.reserve(0, 33)
    kv.ensure_writable_span(0, 0, 17)       # crosses into page 2
    p2 = int(kv.block_table[0, 1])
    assert kv.shrink_to(0, 16) == 1         # page-boundary rejection
    assert p2 in kv._free
    kv.ensure_writable_span(0, 16, 4)       # accept-heavy retry re-appends
    assert kv.held[0] == 2
    kv.check_invariants()
    kv.release(0)
    assert kv.n_free == kv.num_pages - 1


def test_reserve_rebooks_outstanding():
    kv = _pool()
    kv.reserve(0, 16)
    assert kv._outstanding == 1
    kv.reserve(0, 48)                       # re-book a bigger worst case
    assert kv._outstanding == 3
    kv.check_invariants()
    kv.release(0)
    assert kv._outstanding == 0
    kv.check_invariants()


def test_engine_page_conservation_through_speculation(smol):
    """A speculative drain (drafts accepted AND rejected along the way)
    ends with every page back on the free list and invariants intact."""
    cfg, m, params = smol
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=4, max_len=64, page_size=8,
                                    chunk_size=8, draft_len=4))
    rng = np.random.default_rng(12)
    for i in range(6):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab,
                                int(rng.integers(4, 20))).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 14))))
    seen_mid = False
    while eng.queue or eng.active:
        eng.step(decode_steps=eng.decode_steps)
        eng.kv.check_invariants()           # conservation holds mid-flight
        seen_mid = seen_mid or bool(eng.active)
    assert seen_mid and len(eng.completed) == 6
    assert eng.kv.n_free == eng.kv.num_pages - 1
    eng.kv.check_invariants()


# ---------------------------------------------------------------------------------
# mixed-step semantics
# ---------------------------------------------------------------------------------

def _oracle(m, params, prompt, n, eos=None):
    toks = list(prompt)
    out = []
    for _ in range(n):
        logits, _ = m.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        t = int(jnp.argmax(logits[0, -1]))
        out.append(t)
        toks.append(t)
        if eos is not None and t == eos:
            break
    return out


def test_speculative_greedy_token_exact_vs_oracle(smol):
    """PINNED acceptance gate: greedy speculative decode (chunked prefill +
    n-gram drafts + fused verify) emits bit-identical tokens to sequential
    single-step greedy decoding, for every request in a mixed batch."""
    cfg, m, params = smol
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=4, max_len=64,
                                    chunk_size=8, draft_len=3))
    rng = np.random.default_rng(13)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(3, 24))).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 10)))
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert len(eng.completed) == 8
    for r in reqs:
        assert r.output == _oracle(m, params, r.prompt, r.max_new_tokens), r.rid


def test_eos_in_prompt_does_not_truncate(smol):
    """eos tokens inside the prompt are known positions, not candidates:
    chunked prefill must stream them through without finishing the row."""
    cfg, m, params = smol
    rng = np.random.default_rng(14)
    prompt = rng.integers(0, cfg.vocab, 20).astype(np.int32)
    eos = int(prompt[9])                    # an eos token mid-prompt
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=2, max_len=64, eos_token=eos,
                                    chunk_size=4, draft_len=2))
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    eng.run_until_drained()
    assert req.output == _oracle(m, params, prompt, 6, eos=eos)
    assert eng.kv.n_free == eng.kv.num_pages - 1


def test_emitted_eos_mid_chunk_stops_row(smol):
    """A row whose eos fires in the same mixed invocation that commits its
    final prefill chunk stops exactly at the eos, pages released."""
    cfg, m, params = smol
    rng = np.random.default_rng(15)
    prompt = rng.integers(0, cfg.vocab, 11).astype(np.int32)
    first = _oracle(m, params, prompt, 1)[0]
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=2, max_len=64, eos_token=first,
                                    chunk_size=16, draft_len=3))
    req = Request(rid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    eng.run_until_drained()
    assert req.output == [first]            # eos was the very first emission
    assert eng.kv.n_free == eng.kv.num_pages - 1
    eng.kv.check_invariants()


def test_chunked_matches_bucketed_path(smol):
    """The chunked mixed loop and the legacy bucketed-prefill path produce
    identical greedy outputs and matching scores."""
    cfg, m, params = smol
    outs = {}
    for chunked in (False, True):
        eng = ServingEngine(m, params,
                            ServeConfig(max_batch=4, max_len=64,
                                        chunked_prefill=chunked,
                                        chunk_size=8, draft_len=3))
        rng = np.random.default_rng(16)
        for i in range(6):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    int(rng.integers(4, 28))).astype(np.int32),
                max_new_tokens=int(rng.integers(2, 9))))
        eng.run_until_drained()
        outs[chunked] = {r.rid: (list(r.output), r.score)
                         for r in eng.completed}
    assert {r: o for r, (o, _) in outs[False].items()} == \
           {r: o for r, (o, _) in outs[True].items()}
    for rid in outs[False]:
        np.testing.assert_allclose(outs[False][rid][1], outs[True][rid][1],
                                   atol=2e-2)


def test_mixed_loop_single_trace(smol):
    """The mixed loop runs at fixed max_batch width: every slot-population
    mix and every sync cadence shares ONE compiled variant, and no prefill
    graph is ever traced."""
    cfg, m, params = smol
    eng = ServingEngine(m, params, ServeConfig(max_batch=4, max_len=64,
                                               chunk_size=8, draft_len=3))
    rng = np.random.default_rng(17)
    for i in range(7):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab,
                                int(rng.integers(3, 30))).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 8))))
    eng.step(now=0.0)                       # population 4
    eng.step(now=0.0, decode_steps=eng.decode_steps)
    eng.run_until_drained()                 # tail populations 3..1
    assert len(eng.completed) == 7
    assert eng.mixed_trace_count == 1
    assert eng.prefill_trace_count == 0


# ---------------------------------------------------------------------------------
# bucketed-path starvation control
# ---------------------------------------------------------------------------------

def test_bucket_max_wait_flushes_partial_group(smol):
    """A lone cold-bucket request behind a busy decode batch waits for
    bucket-mates at most ``bucket_max_wait`` steps, then flushes."""
    cfg, m, params = smol
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=4, max_len=64,
                                    chunked_prefill=False, bucket_max_wait=3))
    rng = np.random.default_rng(18)
    # a long-running batch keeps the engine busy
    for i in range(2):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                           max_new_tokens=30))
    eng.step(now=0.0)
    assert len(eng.active) == 2
    # a lone request in a different (cold) bucket: deferred, not prefilled
    lone = Request(rid=9, prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32),
                   max_new_tokens=2)
    eng.submit(lone)
    eng.step(now=0.0)
    assert 9 not in {r.rid for r in eng.active.values()}   # waiting for mates
    eng.step(now=0.0)
    eng.step(now=0.0)
    eng.step(now=0.0)                       # max-wait reached: flushed
    assert (9 in {r.rid for r in eng.active.values()}
            or any(r.rid == 9 for r in eng.completed))
    eng.run_until_drained()
    assert len(eng.completed) == 3


def test_bucket_wait_coalesces_late_mate(smol):
    """A bucket-mate arriving during the wait window joins the deferred
    group: one prefill dispatch, occupancy 0.5 instead of 0.25 twice."""
    cfg, m, params = smol
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=4, max_len=64,
                                    chunked_prefill=False, bucket_max_wait=4))
    rng = np.random.default_rng(19)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 9).astype(np.int32),
                       max_new_tokens=20))
    eng.step(now=0.0)                       # idle engine: flushes immediately
    assert len(eng.active) == 1
    eng.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32),
                       max_new_tokens=4))
    eng.step(now=0.0)                       # deferred (busy, partial, cold)
    eng.submit(Request(rid=2, prompt=rng.integers(0, cfg.vocab, 24).astype(np.int32),
                       max_new_tokens=4))
    width_before = eng._prefill_width
    eng.step(now=0.0)
    eng.step(now=0.0)
    eng.step(now=0.0)
    eng.step(now=0.0)
    rids = {r.rid for r in eng.active.values()} | {r.rid for r in eng.completed}
    assert {1, 2} <= rids
    # both rode one width-4 dispatch (bucket 32): occupancy 2/4 for it
    assert eng._prefill_width == width_before + 4
    assert eng.bucket_occupancy[32] == 0.5
    eng.run_until_drained()
    assert len(eng.completed) == 3


def test_bucket_max_wait_zero_restores_immediate_flush(smol):
    cfg, m, params = smol
    eng = ServingEngine(m, params,
                        ServeConfig(max_batch=4, max_len=64,
                                    chunked_prefill=False, bucket_max_wait=0))
    rng = np.random.default_rng(20)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 9).astype(np.int32),
                       max_new_tokens=10))
    eng.step(now=0.0)
    eng.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32),
                       max_new_tokens=2))
    eng.step(now=0.0)                       # no waiting: prefilled at once
    assert 1 in ({r.rid for r in eng.active.values()}
                 | {r.rid for r in eng.completed})
    eng.run_until_drained()
    assert len(eng.completed) == 2
