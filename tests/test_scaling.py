"""Scaling control-plane tests: SignalBus window math, ScalingController
Table III mechanics, multi-channel signals, the RunReport schema, and a
bit-for-bit parity check against the pre-refactor simulator results."""
import numpy as np
import pytest

from repro.core.autoscaler import (
    AppDataPolicy,
    CompositePolicy,
    Decision,
    LoadPolicy,
    Observation,
    Policy,
    ScheduledPolicy,
    TargetTrackingPolicy,
    ThresholdPolicy,
)
from repro.core.scaling import (
    ControllerConfig,
    RunReport,
    ScalableBackend,
    ScalingController,
    ServiceProcess,
    SignalBus,
    WindowStats,
    available_policies,
    make_policy,
)
from repro.core.simulator import SimConfig, generate_trace, run_scenario
from repro.core.simulator.distributions import ServiceModel


# ---------------------------------------------------------------------------------
# Parity: the refactored Engine (SignalBus + ScalingController) must reproduce the
# seed simulator bit-for-bit.  Golden values captured from the pre-refactor engine
# at commit 09bf04d on generate_trace("england", seed=0) / ("mexico", seed=1).
# ---------------------------------------------------------------------------------

GOLDEN_ENGLAND = {
    # policy -> (violation_rate, cpu_seconds, n_up, n_down, delays_sum,
    #            units_t_sum, units_t_max)
    "threshold": (0.0, 12072.0, 10, 10, 334050.6924178286, 12072, 4),
    "load": (5.411226129728735e-06, 10332.0, 5, 5, 3432095.6924178284, 10332, 4),
    "load+appdata": (2.7056130648643674e-06, 12552.0, 6, 15,
                     3094931.6924178284, 12552, 8),
}
GOLDEN_MEXICO_CAPPED = (0.00010689349682639686, 15512.0, 10, 16,
                        10585666.145966608, 15512, 4)


def _fingerprint(r):
    return (r.violation_rate, r.cpu_seconds, r.n_decisions_up, r.n_decisions_down,
            float(r.delays.sum()), int(r.units_t.sum()), int(r.units_t.max()))


def test_engine_parity_with_seed_simulator():
    sm = ServiceModel()
    tr = generate_trace("england", seed=0)
    cfg = SimConfig()
    policies = {
        "threshold": lambda: ThresholdPolicy(0.9),
        "load": lambda: LoadPolicy(sm, quantile=0.99999),
        "load+appdata": lambda: CompositePolicy(
            [LoadPolicy(sm, quantile=0.99999), AppDataPolicy(extra_units=5)]),
    }
    for name, golden in GOLDEN_ENGLAND.items():
        r = run_scenario(tr, policies[name](), cfg)
        assert _fingerprint(r) == golden, name


def test_engine_parity_with_input_rate_cap():
    """The capped-admission path (ingest queue) must also match the seed."""
    sm = ServiceModel()
    tr = generate_trace("mexico", seed=1)
    pol = CompositePolicy([LoadPolicy(sm, quantile=0.999),
                           AppDataPolicy(extra_units=3)])
    r = run_scenario(tr, pol, SimConfig(max_input_rate=600.0))
    assert _fingerprint(r) == GOLDEN_MEXICO_CAPPED


def test_elastic_backend_golden_regression():
    """Pin the elastic backend's behavior on a fixed workload (regenerated
    after the Algorithm-1 unification onto the shared water-filling service
    core, see DESIGN.md: the old equal-share loop dropped a finished request's
    excess capacity, so the water-filling fleet completes the same stream with
    lower latency and fewer replica-hours).

    replica_hours regenerated once more (0.10111 -> 0.105) for the
    pending-cancel downscale fix: one downscale tick (t=164) now cancels the
    still-provisioning replica queued at t=134 instead of releasing a live one
    while that pending replica lands 15 s later anyway -- the fleet holds 3
    live replicas through [164, 179) instead of dipping to 2 and bouncing
    back.  Everything else (latencies, decision counts, peaks) is unchanged;
    the simulator goldens, where the adaptation period equals the
    provisioning delay (Table III), are bit-for-bit unaffected."""
    from repro.core.elastic import ClusterConfig, ElasticCluster, ServeRequest
    rng = np.random.default_rng(0)
    reqs = []
    for sec in range(300):
        for _ in range(rng.poisson(3.0 if 100 < sec < 160 else 1.0)):
            hot = 80 < sec < 160
            reqs.append(ServeRequest(
                rid=len(reqs), arrival_s=sec + rng.random(),
                prefill_len=int(rng.exponential(2000)) + 128,
                decode_len=int(rng.exponential(64)) + 8,
                score=float(np.clip((0.9 if hot else 0.3)
                                    + rng.normal(0, .05), 0, 1))))
    pol = CompositePolicy([ThresholdPolicy(0.7), AppDataPolicy(extra_units=2)])
    res = ElasticCluster(ClusterConfig(), pol, reqs).run()
    assert res["n_done"] == 406
    assert res["violation_rate"] == 0.0
    assert res["mean_latency_s"] == pytest.approx(1.6547317567942001)
    assert res["replica_hours"] == pytest.approx(0.105)
    assert res["max_replicas"] == 3
    assert (res["n_scale_ups"], res["n_scale_downs"]) == (2, 3)


# ---------------------------------------------------------------------------------
# Shared water-filling service core (ServiceProcess)
# ---------------------------------------------------------------------------------

def test_service_process_waterfills_and_conserves():
    proc = ServiceProcess({"idx": np.int64})
    empty = proc.step(5.0)
    assert empty.consumed == 0.0 and empty.busy == 0.0 and empty.n_finished == 0
    proc.admit(np.array([3.0, 1.0, 2.0]), idx=np.array([0, 1, 2]))
    assert len(proc) == 3 and proc.demand == pytest.approx(6.0)
    # capacity 4 over [1, 2, 3]: tau = 1.5, only the smallest item finishes
    r = proc.step(4.0)
    assert r.tau == pytest.approx(1.5)
    assert list(r.finished["idx"]) == [1]
    assert r.consumed == pytest.approx(4.0) and r.busy == 1.0
    # survivors hold [0.5, 1.5]; surplus capacity drains them, busy < 1
    r = proc.step(10.0)
    assert np.isinf(r.tau) and r.n_finished == 2
    assert list(r.finished["idx"]) == [2, 0]       # ascending remaining work
    assert r.consumed == pytest.approx(2.0) and r.busy == pytest.approx(0.2)
    assert len(proc) == 0


def test_service_process_zero_work_and_payload_columns():
    proc = ServiceProcess(("val",))
    instant = proc.admit(np.array([0.0, 2.0]), val=np.array([7.0, 8.0]))
    assert list(instant["val"]) == [7.0]           # zero-demand: instant finish
    assert len(proc) == 1
    assert proc.admit(np.array([1.0]), val=np.array([9.0])) is None
    r = proc.step(100.0)
    assert list(r.finished["val"]) == [9.0, 8.0]   # columns follow the sort
    # undeclared payload columns are rejected loudly, not silently dropped
    with pytest.raises(ValueError, match="payload columns"):
        proc.admit(np.array([1.0]), val=np.array([1.0]), prio=np.array([2.0]))
    with pytest.raises(ValueError, match="payload columns"):
        proc.admit(np.array([1.0]))


def test_elastic_consumed_work_conservation():
    """Acceptance: per-step consumed work == min(demand, capacity) -- the
    elastic fleet never wastes a replica-second while requests are hungry --
    and every priced replica-second of work is served exactly once."""
    from repro.core.elastic import ClusterConfig, ElasticCluster
    clu = ElasticCluster(ClusterConfig(), ThresholdPolicy(0.7),
                         _cluster_requests(1500))
    res = clu.run()
    assert np.allclose(res.consumed_t,
                       np.minimum(res.demand_t, res.capacity_t))
    assert res.consumed_t.sum() == pytest.approx(clu._work.sum())
    # busy fraction is defined from consumed work, not pre-step demand
    assert np.allclose(res.util_t, res.consumed_t / res.capacity_t, atol=1e-6)


# ---------------------------------------------------------------------------------
# SignalBus window math
# ---------------------------------------------------------------------------------

def test_signalbus_window_means():
    bus = SignalBus(("s",), bin_s=1.0)
    # previous window [0, 10): mean 0.2; current window [10, 20): mean 0.8
    bus.record("s", np.arange(0.0, 10.0), np.full(10, 0.2))
    bus.record("s", np.arange(10.0, 20.0), np.full(10, 0.8))
    st = bus.window_stats("s", hi_bin=20, window_bins=10)
    assert st.mean == pytest.approx(0.8)
    assert st.prev_mean == pytest.approx(0.2)
    assert st.count == 10 and st.prev_count == 10
    assert st.rise == pytest.approx(0.6)
    assert st.relative_rise == pytest.approx(3.0)


def test_signalbus_empty_windows_and_clamping():
    bus = SignalBus(("s",), bin_s=1.0)
    assert bus.window_stats("s", hi_bin=5, window_bins=10) == WindowStats()
    bus.record("s", np.array([2.0]), np.array([1.0]))
    # window reaching below t=0 clamps instead of wrapping
    st = bus.window_stats("s", hi_bin=3, window_bins=10)
    assert st.count == 1 and st.mean == pytest.approx(1.0)
    assert st.prev_count == 0 and st.prev_mean == 0.0


def test_signalbus_grows_on_demand_and_respects_horizon():
    bus = SignalBus(("s",), bin_s=1.0)
    bus.record("s", np.array([10_000.0]), np.array([0.5]))   # force growth
    assert bus.window_stats("s", 10_001, 1).count == 1
    capped = SignalBus(("s",), bin_s=1.0, horizon_bins=100)
    capped.record("s", np.array([500.0]), np.array([1.0]))   # clamps into last bin
    st = capped.window_stats("s", hi_bin=10_000, window_bins=10)  # hi clamps to 100
    assert st.count == 1


def test_signalbus_window_beyond_allocated_bins_is_empty():
    """An unbounded bus must not slide the window back onto stale data when
    queried past the last-grown bin (regression: hi was clamped to array len)."""
    bus = SignalBus(("s",), bin_s=1.0)
    bus.record("s", np.arange(200.0, 256.0), np.full(56, 0.9))
    st = bus.window_stats("s", hi_bin=400, window_bins=60)   # window [340, 400)
    assert st.count == 0 and st.mean == 0.0
    assert st.prev_count == 0 and st.prev_mean == 0.0
    # partially-past window still sees only what falls inside it
    st = bus.window_stats("s", hi_bin=300, window_bins=60)   # [240, 300)
    assert st.count == 16


def test_relative_rise_on_negative_baseline():
    """Paper polarity lives in [-1, 1]: a negative baseline must still report
    a rise (regression: the `prev_mean > 1e-6` guard silently yielded 0, so
    AppDataPolicy in relative mode could never fire)."""
    st = WindowStats(mean=-0.2, count=30, prev_mean=-0.5, prev_count=30)
    assert st.rise == pytest.approx(0.3)
    assert st.relative_rise == pytest.approx(0.6)
    # positive baselines are unchanged
    up = WindowStats(mean=0.9, count=30, prev_mean=0.6, prev_count=30)
    assert up.relative_rise == pytest.approx(0.5)
    # no-baseline edge still reads 0
    assert WindowStats(mean=0.4, count=30).relative_rise == 0.0
    # and the appdata detector actually fires on the negative-baseline rise
    pol = AppDataPolicy(extra_units=2, jump=0.5, relative=True, channel="s")
    obs = Observation(time=0.0, n_units=1, n_pending=0, utilization=0.5,
                      n_in_system=0, input_rate=0.0, signals={"s": st})
    assert pol.decide(obs).delta == 2


def test_signalbus_multi_channel_isolation():
    bus = SignalBus(("a",), bin_s=1.0)
    bus.record("a", np.array([1.0]), np.array([1.0]))
    bus.record("b", np.array([1.0]), np.array([3.0]))        # auto-registered
    snap = bus.snapshot(hi_bin=2, window_bins=2)
    assert set(snap) == {"a", "b"}
    assert snap["a"].mean == pytest.approx(1.0)
    assert snap["b"].mean == pytest.approx(3.0)


def test_signalbus_cumulative_matches_slices():
    rng = np.random.default_rng(0)
    bus = SignalBus(("s",), bin_s=1.0)
    times = rng.uniform(0, 50, size=200)
    vals = rng.random(200)
    bus.record("s", times, vals)
    csum, ccnt = bus.cumulative("s")
    for lo, hi in [(0, 10), (5, 30), (20, 50)]:
        st = bus.window_stats("s", hi_bin=hi, window_bins=hi - lo)
        n = ccnt[hi] - ccnt[lo]
        assert st.count == n
        if n:
            assert st.mean == pytest.approx((csum[hi] - csum[lo]) / n)


# ---------------------------------------------------------------------------------
# ScalingController mechanics (Table III)
# ---------------------------------------------------------------------------------

class _Script(Policy):
    """Replays a scripted sequence of deltas, one per adaptation tick."""
    name = "script"

    def __init__(self, deltas):
        self.deltas = list(deltas)
        self.i = 0

    def reset(self):
        self.i = 0

    def decide(self, obs):
        d = self.deltas[self.i] if self.i < len(self.deltas) else 0
        self.i += 1
        return Decision(d, f"scripted {d}")


def _drive(ctrl, n_steps, *, step_s=1.0, busy=0.5, arrivals=0, n_in_system=0):
    units = []
    for k in range(n_steps):
        u = ctrl.on_step_start(k * step_s)
        units.append(u)
        ctrl.note_step(busy, arrivals)
        ctrl.maybe_adapt(time=(k + 1) * step_s, n_in_system=n_in_system)
    return units


def test_provisioning_delay_queue():
    cfg = ControllerConfig(adapt_period_s=10.0, provision_delay_s=30.0)
    ctrl = ScalingController(_Script([5]), cfg)
    units = _drive(ctrl, 60)
    # decision at t=10 -> available at t=40: first step that sees 6 is t=40
    assert units[39] == 1 and units[40] == 6
    assert ctrl.n_up == 1
    rec = ctrl.decision_log[0]
    assert rec.requested == 5 and rec.applied == 5 and rec.pending == 5


def test_downscale_cap_and_floor():
    cfg = ControllerConfig(adapt_period_s=10.0, provision_delay_s=0.0)
    ctrl = ScalingController(_Script([4, -3, -3, -3, -3, -3]), cfg)
    units = _drive(ctrl, 70)
    arr = np.asarray(units)
    assert arr.max() == 5
    assert np.diff(arr).min() >= -1          # one unit at a time, ever
    assert arr[-1] == 1 and ctrl.units == 1  # floor respected
    # the -3 request against units=2 applies only -1
    applied = [r.applied for r in ctrl.decision_log]
    assert all(a >= -1 for a in applied)


def test_max_units_ceiling():
    cfg = ControllerConfig(adapt_period_s=5.0, provision_delay_s=5.0, max_units=3)
    ctrl = ScalingController(_Script([10]), cfg)
    units = _drive(ctrl, 30)
    assert max(units) == 3


def test_observation_window_accounting():
    cfg = ControllerConfig(adapt_period_s=4.0, app_window_s=4.0, signal_channel="s")
    ctrl = ScalingController(_Script([0] * 10), cfg,
                             SignalBus(("s",), bin_s=1.0))
    for k in range(8):
        ctrl.on_step_start(float(k))
        ctrl.bus.record("s", np.array([float(k)]), np.array([1.0 if k >= 4 else 0.5]))
        ctrl.note_step(busy_fraction=0.25 * (k % 4), new_arrivals=2)
        ctrl.maybe_adapt(time=k + 1.0, n_in_system=7)
    obs = ctrl.observe(time=8.0, n_in_system=7)
    # windows over [4, 8) vs [0, 4)
    assert obs.app_window_mean == pytest.approx(1.0)
    assert obs.app_prev_window_mean == pytest.approx(0.5)
    assert obs.signal("s").prev_count == 4
    assert obs.input_rate == pytest.approx(0.0)   # reset at the adapt tick
    assert obs.n_in_system == 7


def test_legacy_observation_shim():
    """Policies reading obs.signal(None) see the legacy app_* fields."""
    obs = Observation(time=0, n_units=1, n_pending=0, utilization=0.5,
                      n_in_system=3, input_rate=1.0,
                      app_window_mean=0.9, app_prev_window_mean=0.4,
                      app_window_count=50)
    st = obs.signal()
    assert st.mean == 0.9 and st.prev_mean == 0.4 and st.count == 50
    assert obs.signal("missing") == WindowStats()


# ---------------------------------------------------------------------------------
# Multi-channel signal path through a real backend
# ---------------------------------------------------------------------------------

def _cluster_requests(n=1500, horizon=300.0, burst_at=150.0, seed=0):
    from repro.core.elastic import ServeRequest
    rng = np.random.default_rng(seed)
    out = []
    for sec in range(int(horizon)):
        lam = 1.0 + 4.0 * np.exp(-((sec - burst_at) ** 2) / (2 * 20.0 ** 2))
        for _ in range(rng.poisson(lam * n / (horizon * 2.0))):
            hot = burst_at - 70 <= sec <= burst_at + 40
            out.append(ServeRequest(
                rid=len(out), arrival_s=sec + rng.random(),
                prefill_len=int(rng.exponential(2000)) + 128,
                decode_len=int(rng.exponential(64)) + 8,
                score=0.5,
                signals={"breaking_news": 1.0 if (hot and rng.random() < 0.9)
                         else 0.0}))
    return out


def test_cluster_multi_channel_appdata():
    """An AppDataPolicy watching a secondary channel (not the primary
    output_score, which stays flat here) pre-provisions on its rise."""
    from repro.core.elastic import ClusterConfig, ElasticCluster
    cfg = ClusterConfig()
    reqs = _cluster_requests()
    base = ElasticCluster(cfg, ThresholdPolicy(0.7), _cluster_requests()).run()
    pol = CompositePolicy([
        ThresholdPolicy(0.7),
        AppDataPolicy(extra_units=4, jump=0.5, relative=False,
                      channel="breaking_news"),
    ])
    res = ElasticCluster(cfg, pol, reqs).run()
    assert res.max_units > base.max_units          # the channel actually fired
    assert any("breaking_news" in r.reason for r in res.decisions)
    # flat primary channel alone would never have fired (jump 0.6 also clears
    # the cold-start edge where an empty previous window reads as prev_mean=0)
    flat = AppDataPolicy(extra_units=4, jump=0.6, relative=False)
    only = ElasticCluster(cfg, CompositePolicy([ThresholdPolicy(0.7), flat]),
                          _cluster_requests()).run()
    assert not any("signal" in r.reason for r in only.decisions)


# ---------------------------------------------------------------------------------
# RunReport schema + backend protocol
# ---------------------------------------------------------------------------------

def test_runreport_schema_and_mapping_shim():
    rep = RunReport(backend="x", workload="w", policy="p", sla_s=10.0,
                    latencies=np.array([1.0, 5.0, 20.0]), unit_seconds=3600.0,
                    units_t=np.array([1, 2, 3]), unit_name="replica",
                    extra={"chip_hours": 16.0})
    assert rep.violation_rate == pytest.approx(1 / 3)
    assert rep.unit_hours == pytest.approx(1.0)
    assert rep["replica_hours"] == pytest.approx(1.0)     # unit-named alias
    assert rep["max_replicas"] == 3 and rep.max_units == 3
    assert rep["chip_hours"] == 16.0                      # extra rows pass through
    assert rep["n_done"] == 3
    assert "violation_rate" in rep


def test_backends_satisfy_protocol_and_share_schema():
    from repro.core.elastic import ClusterConfig, ElasticCluster
    from repro.core.simulator.engine import Engine
    sim = Engine(generate_trace("england", seed=0), ThresholdPolicy(0.9))
    clu = ElasticCluster(ClusterConfig(), ThresholdPolicy(0.7),
                         _cluster_requests(300))
    assert isinstance(sim, ScalableBackend)
    assert isinstance(clu, ScalableBackend)
    rep = clu.run()
    assert isinstance(rep, RunReport)
    assert {"backend", "policy", "violation_rate", "n_scale_ups"} <= set(rep.keys())


# ---------------------------------------------------------------------------------
# New policies + registry
# ---------------------------------------------------------------------------------

def _obs(**kw):
    base = dict(time=0.0, n_units=2, n_pending=0, utilization=0.5,
                n_in_system=0, input_rate=0.0)
    base.update(kw)
    return Observation(**base)


def test_target_tracking_scales_proportionally():
    pol = TargetTrackingPolicy(target=0.5)
    assert pol.decide(_obs(utilization=1.0)).delta == 2   # 2 * 1.0/0.5 = 4 desired
    assert pol.decide(_obs(utilization=0.5)).delta == 0   # on target
    assert pol.decide(_obs(utilization=0.1)).delta == -1  # scale-in, one at a time
    # dead band suppresses flapping near the target
    assert pol.decide(_obs(utilization=0.52)).delta == 0
    # utilization comes from live units only: 2 saturated units imply a load of
    # 2 unit-equivalents -> desired 4, already covered by the 2 pending units
    assert pol.decide(_obs(utilization=1.0, n_pending=2)).delta == 0
    assert pol.decide(_obs(utilization=1.0, n_pending=1)).delta == 1
    # excess pending (e.g. queued by a co-composed policy) must not trigger a
    # scale-in while the live units still run above target
    assert pol.decide(_obs(utilization=1.0, n_pending=4)).delta == 0


def test_target_tracking_on_signal_channel():
    pol = TargetTrackingPolicy(target=0.5, metric="signal", channel="load_score")
    obs = _obs(signals={"load_score": WindowStats(mean=1.0, count=10)})
    assert pol.decide(obs).delta == 2


def test_scheduled_policy_preprovisions_with_lead():
    pol = ScheduledPolicy([(100.0, 200.0, 6)], lead_s=60.0)
    assert pol.decide(_obs(time=30.0)).delta == 0         # too early
    assert pol.decide(_obs(time=40.0)).delta == 4         # 100 - 60 lead
    assert pol.decide(_obs(time=150.0, n_units=6)).delta == 0
    assert pol.decide(_obs(time=250.0)).delta == 0        # window over


def test_policy_registry():
    names = available_policies()
    assert {"threshold", "load", "appdata", "target", "scheduled"} <= set(names)
    assert make_policy("threshold", upper=0.8).describe() == "threshold(80%)"
    assert make_policy("load").describe().startswith("load(")
    assert make_policy("target", target=0.6).describe() == "target(utilization=0.6)"
    assert make_policy("scheduled",
                       schedule=[(0.0, 60.0, 2)]).describe() == "scheduled(1 windows)"
    with pytest.raises(ValueError, match="schedule"):
        make_policy("scheduled")          # helpful error, not a bare TypeError


def test_policy_registry_error_paths():
    from repro.core.scaling import register_policy
    # unknown name: a KeyError that *names* the known policies
    with pytest.raises(KeyError, match="unknown policy 'nope'"):
        make_policy("nope")
    # duplicate registration is refused loudly (silent override would let a
    # plugin shadow the built-ins)
    with pytest.raises(ValueError, match="already registered"):
        register_policy("threshold", ThresholdPolicy)
    # ... and the decorator form refuses identically
    with pytest.raises(ValueError, match="already registered"):
        @register_policy("target")
        class Shadow(Policy):
            pass
    # the failed registrations must not have clobbered the originals
    assert make_policy("threshold", upper=0.8).describe() == "threshold(80%)"
    assert make_policy("target").name == "target"


class _Const(Policy):
    """Always votes the same delta (CompositePolicy interaction tests)."""
    name = "const"

    def __init__(self, delta, reason=""):
        self._d = Decision(delta, reason)

    def decide(self, obs):
        return self._d


def test_composite_up_vote_vetoes_down():
    obs = _obs()
    # up + down -> the up vote wins outright, in either arrival order
    assert CompositePolicy([_Const(+2), _Const(-1)]).decide(obs).total == 2
    assert CompositePolicy([_Const(-1), _Const(+2)]).decide(obs).total == 2
    # the veto zeroes the release; it does not net it against the allocation
    assert CompositePolicy([_Const(-1), _Const(+1)]).decide(obs).total == 1
    # several up votes accumulate; a lone down vote among them still loses
    assert CompositePolicy(
        [_Const(+1), _Const(-1), _Const(+3)]).decide(obs).total == 4
    # all-down composes to a release (the controller caps it at -1 later)
    assert CompositePolicy([_Const(-1), _Const(-1)]).decide(obs).total == -2
    # reasons survive composition
    d = CompositePolicy([_Const(+1, "burst"), _Const(0, "")]).decide(obs)
    assert "burst" in d.reason
