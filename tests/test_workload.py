"""Calibrated trace generator vs the paper's published statistics."""
import numpy as np
import pytest

from repro.core.signals import burst_lead_report, lag_correlation_table
from repro.core.simulator import MATCHES, generate_trace


@pytest.mark.parametrize("match", list(MATCHES))
def test_table2_totals(match):
    tr = generate_trace(match, seed=0)
    spec = MATCHES[match]
    assert tr.n_tweets == pytest.approx(spec.total_tweets, rel=0.01)
    assert tr.duration == int(round(spec.length_hours * 3600))
    assert np.all(np.diff(tr.post_time) >= 0)          # sorted
    assert tr.sentiment.min() >= 0.0 and tr.sentiment.max() <= 1.0


def test_sentiment_volume_correlation_positive():
    tr = generate_trace("spain", seed=0)
    rows = lag_correlation_table(tr)
    # the reconstructed trace reproduces the correlation STRUCTURE; absolute
    # levels are trace-dependent (paper: 0.79 -> 0.70).  See EXPERIMENTS.md.
    assert rows[0][1] > 0.35
    assert rows[10][1] > 0.0


def test_burst_early_warning():
    det = tot = 0
    for seed in range(3):
        tr = generate_trace("spain", seed=seed)
        rep = burst_lead_report(tr)
        det += rep["n_detected"]
        tot += rep["n_bursts"]
    assert det / tot > 0.6             # most bursts detected (paper has FNs too)


def test_zero_cycle_class_exists():
    tr = generate_trace("england", seed=0)
    assert (tr.cycles == 0.0).mean() == pytest.approx(0.10, abs=0.02)  # PE(1) path


def test_seed_determinism():
    a = generate_trace("france", seed=3)
    b = generate_trace("france", seed=3)
    assert a.n_tweets == b.n_tweets
    assert np.array_equal(a.post_time, b.post_time)
    assert np.array_equal(a.sentiment, b.sentiment)
