"""Degrade gracefully when `hypothesis` is not installed: the property tests
individually skip while the rest of their module still runs (a module-level
importorskip would silently drop every non-property test with them).

Usage:  from _hypothesis_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn

    class _NullStrategies:
        """Accepts any strategy construction; the test is skipped anyway."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()
