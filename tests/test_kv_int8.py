"""int8 KV cache: decode matches the bf16-cache path within quantization noise
and halves cache storage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma3-4b", "smollm-135m"])
def test_int8_cache_decode_close(arch):
    cfg = get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    params = m.init_params(jax.random.key(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, S + 4), 0, cfg.vocab)
    pre = {"tokens": toks[:, :S]}
    lg0, c0 = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 8))(params, pre)
    lg8, c8 = jax.jit(lambda p, b: m8.prefill(p, b, max_len=S + 8))(params, pre)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg8), atol=0.15)
    for i in range(3):
        t = toks[:, S + i:S + i + 1]
        lg0, c0 = jax.jit(m.decode_step)(params, c0, t, jnp.int32(S + i))
        lg8, c8 = jax.jit(m8.decode_step)(params, c8, t, jnp.int32(S + i))
        np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg8), atol=0.2)
    b0 = sum(a.nbytes for a in jax.tree.leaves(c0))
    b8 = sum(a.nbytes for a in jax.tree.leaves(c8))
    assert b8 < 0.75 * b0            # >= 25% smaller even at tiny head dims


def test_int8_quantize_roundtrip():
    from repro.models.lm import _kv_dequantize, _kv_quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 2, 64)) * 3.0
    q, sc = _kv_quantize(x)
    y = _kv_dequantize(q, sc, jnp.float32)
    rel = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02                 # 1/127 symmetric quantization error
