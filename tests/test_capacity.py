"""Capacity-plane tests: typed unit pools, the CapacityPlan actuation
mechanics (per-pool delays, ceilings, expensive-first release with
pending-cancel, seeded spot revocation), per-pool Decisions, priced
RunReports and per-class SLAs, and the single-pool <-> legacy-scalar
equivalence that underwrites the golden parity tests."""

import numpy as np
import pytest

from repro.core.autoscaler import (
    CheapestFirstRouter,
    Decision,
    Observation,
    Policy,
    ThresholdPolicy,
)
from repro.core.scaling import (
    CapacityPlan,
    ControllerConfig,
    PoolStats,
    RunReport,
    ScalingController,
    Sla,
    UnitPool,
)


# ---------------------------------------------------------------------------------
# UnitPool / Sla specs
# ---------------------------------------------------------------------------------

def test_unit_pool_validation():
    with pytest.raises(ValueError, match="name"):
        UnitPool("")
    with pytest.raises(ValueError, match="provision_delay_s"):
        UnitPool("p", provision_delay_s=-1.0)
    with pytest.raises(ValueError, match="cost_rate"):
        UnitPool("p", cost_rate=-0.5)
    with pytest.raises(ValueError, match="min_units"):
        UnitPool("p", min_units=5, max_units=2)
    with pytest.raises(ValueError, match="preemptible"):
        UnitPool("p", revoke_rate=0.1)          # hazard without the marker


def test_sla_spec():
    sla = Sla(300.0, {"full": 120.0})
    assert sla.deadline_s("full") == 120.0
    assert sla.deadline_s("anything-else") == 300.0
    d = sla.deadlines(np.array(["full", "x", "full"]))
    assert list(d) == [120.0, 300.0, 120.0]
    with pytest.raises(ValueError, match="positive"):
        Sla(0.0)
    with pytest.raises(ValueError, match="positive"):
        Sla(10.0, {"c": -1.0})


def test_capacity_plan_rejects_bad_pool_sets():
    with pytest.raises(ValueError, match="at least one"):
        CapacityPlan(())
    with pytest.raises(ValueError, match="duplicate"):
        CapacityPlan((UnitPool("a"), UnitPool("a")))


# ---------------------------------------------------------------------------------
# CapacityPlan mechanics
# ---------------------------------------------------------------------------------

def _two_pool_plan(**spot_kw):
    return CapacityPlan((
        UnitPool("od", provision_delay_s=30.0, cost_rate=3.0, min_units=1),
        UnitPool("spot", provision_delay_s=10.0, cost_rate=1.0, max_units=4,
                 **spot_kw),
    ), starting_units=2)


def test_plan_per_pool_delays_and_metering():
    plan = _two_pool_plan()
    assert plan.total_live == 2 and plan.default_pool == "od"
    plan.request("od", 1, now=0.0)       # lands at 30
    plan.request("spot", 2, now=0.0)     # lands at 10
    assert plan.total_pending == 3
    assert plan.land(9.0) == 2
    assert plan.land(10.0) == 4          # spot pair landed first
    assert plan.live_of("spot") == 2 and plan.pending_of("od") == 1
    assert plan.land(30.0) == 5
    # unit-second meters: od held 2 for steps at t=9,10 then 3 at t=30;
    # spot held 0, 2, 2
    us = plan.unit_seconds_by_pool()
    assert us["od"] == pytest.approx(2 + 2 + 3)
    assert us["spot"] == pytest.approx(0 + 2 + 2)
    assert plan.cost() == pytest.approx((7 * 3.0 + 4 * 1.0) / 3600.0)


def test_plan_landing_clamps_to_pool_ceiling():
    plan = _two_pool_plan()
    plan.request("spot", 10, now=0.0)
    assert plan.land(10.0) == 2 + 4      # excess over max_units=4 discarded
    assert plan.pending_of("spot") == 0


def test_plan_release_cancels_pending_newest_first_then_expensive_live():
    plan = _two_pool_plan()
    plan.land(0.0)
    plan.request("spot", 1, now=0.0)
    plan.request("spot", 2, now=1.0)     # newest spot pending
    # pass 1 hits pending regardless of which pool has live capacity
    assert plan.release(1) == {"spot": 1}
    assert plan._state["spot"].pending == [(10.0, 1), (11.0, 1)]  # newest shrank
    # drain remaining pending, then live: od (3.0/h) before spot (1.0/h)
    plan.land(11.0)                      # 2 spot land; od live 2, spot live 2
    assert plan.release(2) == {"od": 1, "spot": 1}
    # od stops at its floor (min_units=1): only spot keeps releasing
    assert plan.release(5) == {"spot": 1}
    assert plan.releasable() == 0
    assert plan.release(1) == {}


def test_plan_revocation_is_seeded_and_involuntary():
    mk = lambda: CapacityPlan((
        UnitPool("spot", cost_rate=1.0, min_units=2, max_units=8,
                 preemptible=True, revoke_rate=0.05, revoke_seed=3),),
        starting_units=8)
    a, b = mk(), mk()
    traj_a = [a.land(float(t)) for t in range(200)]
    traj_b = [b.land(float(t)) for t in range(200)]
    assert traj_a == traj_b              # same seed -> same revocation draws
    assert a.n_revoked > 0
    assert sum(e.count for e in a.revocations) == a.n_revoked
    # revocation is involuntary: it takes the pool below its voluntary floor
    assert min(traj_a) < 2
    assert a.report_kwargs()["n_revocations"] == a.n_revoked


# ---------------------------------------------------------------------------------
# Decision algebra
# ---------------------------------------------------------------------------------

def test_decision_pool_algebra():
    assert Decision(3).pool_deltas("d") == {"d": 3}
    assert Decision(0).pool_deltas("d") == {}
    assert Decision(0, pools={"spot": 2, "od": -1}).total == 1
    assert Decision(0, pools={"spot": 2, None: 1}).pool_deltas("od") == \
        {"spot": 2, "od": 1}
    # scalar + pool-targeted votes merge; the scalar keeps tracking the
    # default pool through the merge
    d = Decision(2, "a") + Decision(0, "b", pools={"spot": 3})
    assert d.pool_deltas("od") == {"od": 2, "spot": 3}
    assert d.total == 5 and d.reason == "a;b"
    # merging two scalars stays scalar
    d2 = Decision(2) + Decision(-1)
    assert d2.pools is None and d2.delta == 1
    # opposite votes cancelling collapses back to a scalar zero
    d3 = Decision(0, pools={"spot": 1}) + Decision(0, pools={"spot": -1})
    assert d3.pools is None and d3.total == 0


def _obs(**kw):
    base = dict(time=0.0, n_units=2, n_pending=0, utilization=0.5,
                n_in_system=0, input_rate=0.0)
    base.update(kw)
    return Observation(**base)


def test_cheapest_first_router():
    pools = {
        "od": PoolStats(units=2, pending=0, cost_rate=3.0, max_units=4),
        "spot": PoolStats(units=1, pending=1, cost_rate=1.0, max_units=4),
    }
    pol = CheapestFirstRouter(ThresholdPolicy(0.9))
    # upscale routed to the cheapest headroom first, spilling upward
    d = pol.decide(_obs(utilization=1.0, pools=pools))
    assert d.pool_deltas("od") == {"spot": 1}
    big = CheapestFirstRouter(_Script([4]))
    d = big.decide(_obs(pools=pools))
    assert d.pool_deltas("od") == {"spot": 2, "od": 2}
    # downscale passes through untouched (controller releases expensive first)
    down = CheapestFirstRouter(ThresholdPolicy(0.9, lower=0.6))
    d = down.decide(_obs(utilization=0.1, pools=pools))
    assert d.pools is None and d.delta == -1
    # without a typed plan the router is the identity
    d = CheapestFirstRouter(_Script([4])).decide(_obs())
    assert d.pools is None and d.delta == 4
    assert big.describe() == "cheapest(script)"


# ---------------------------------------------------------------------------------
# Controller actuation over pools
# ---------------------------------------------------------------------------------

class _Script(Policy):
    name = "script"

    def __init__(self, deltas):
        self.deltas = list(deltas)
        self.i = 0

    def reset(self):
        self.i = 0

    def decide(self, obs):
        d = self.deltas[self.i] if self.i < len(self.deltas) else 0
        self.i += 1
        if isinstance(d, dict):
            return Decision(0, "scripted", pools=d)
        return Decision(d, "scripted")


def _drive(ctrl, n_steps, *, step_s=1.0):
    units = []
    for k in range(n_steps):
        units.append(ctrl.on_step_start(k * step_s))
        ctrl.note_step(0.5, 0)
        ctrl.maybe_adapt(time=(k + 1) * step_s, n_in_system=0)
    return units


def test_single_pool_config_equals_legacy_scalar_config():
    """An explicit one-on-demand-pool plan is mechanically identical to the
    scalar knobs -- the invariant behind the golden parity pins."""
    script = [5, 0, -3, -3, 2, -1, -1, -1, 0, -2]
    legacy = ScalingController(
        _Script(script),
        ControllerConfig(adapt_period_s=10.0, provision_delay_s=30.0,
                         max_units=6),
        starting_units=2)
    pooled = ScalingController(
        _Script(script),
        ControllerConfig(adapt_period_s=10.0, provision_delay_s=999.0,
                         max_units=1,    # scalar knobs ignored when pools given
                         pools=(UnitPool("on-demand", provision_delay_s=30.0,
                                         min_units=1, max_units=6),)),
        starting_units=2)
    assert _drive(legacy, 120) == _drive(pooled, 120)
    assert [r.applied for r in legacy.decision_log] == \
        [r.applied for r in pooled.decision_log]


def test_controller_downscale_cancels_pending_first():
    """Regression (pending-cancel fix): a downscale tick with units still in
    the provisioning queue cancels the newest pending allocation instead of
    releasing a live unit that the pending one would immediately replace."""
    cfg = ControllerConfig(adapt_period_s=10.0, provision_delay_s=100.0)
    ctrl = ScalingController(_Script([3, -1]), cfg, starting_units=4)
    units = _drive(ctrl, 40)
    # t=10: +3 queued (lands t=110).  t=20: -1 must cancel one pending unit...
    assert ctrl.decision_log[1].applied == -1
    assert ctrl.n_pending == 2
    # ...and leave the live fleet alone (the pre-fix controller dropped to 3
    # live here and then landed all 3 pending anyway, ending at 6 not 5)
    assert ctrl.units == 4
    assert all(u == 4 for u in units)


def test_controller_downscale_acts_at_floor_when_pending_exists():
    """The pre-fix controller refused any downscale while live units sat at
    the floor, even with a provisioning queue about to land more."""
    cfg = ControllerConfig(adapt_period_s=10.0, provision_delay_s=100.0,
                           min_units=1)
    ctrl = ScalingController(_Script([5, -2]), cfg, starting_units=1)
    _drive(ctrl, 30)
    rec = ctrl.decision_log[1]
    assert rec.applied == -1             # downscale_cap still applies
    assert ctrl.n_pending == 4 and ctrl.units == 1


def test_controller_two_pools_scalar_maps_to_default():
    pools = (UnitPool("od", provision_delay_s=10.0, cost_rate=3.0, min_units=1),
             UnitPool("spot", provision_delay_s=10.0, cost_rate=1.0,
                      max_units=8))
    ctrl = ScalingController(
        _Script([2, {"spot": 3}, 0, -1, -1]),
        ControllerConfig(adapt_period_s=10.0, pools=pools), starting_units=1)
    _drive(ctrl, 70)
    log = ctrl.decision_log
    assert log[0].pool_deltas == {"od": 2}       # scalar -> default pool
    assert log[1].pool_deltas == {"spot": 3}     # targeted delta
    # downscale releases the most expensive capacity first: od down to its
    # floor, then spot
    assert log[3].pool_deltas == {"od": -1}
    assert log[4].pool_deltas == {"od": -1}
    assert ctrl.plan.live_of("od") == 1 and ctrl.plan.live_of("spot") == 3


def test_controller_mixed_sign_decision_never_cancels_its_own_upscale():
    """{"spot": +3, "od": -1} in one tick: the release pass must run before
    the queue pass, so it cannot cancel the spot allocation queued the same
    tick (newest-first pending cancel would otherwise eat it)."""
    pools = (UnitPool("od", provision_delay_s=10.0, cost_rate=3.0),
             UnitPool("spot", provision_delay_s=10.0, cost_rate=1.0,
                      max_units=8))
    ctrl = ScalingController(
        _Script([{"spot": 3, "od": -1}]),
        ControllerConfig(adapt_period_s=10.0, pools=pools), starting_units=2)
    _drive(ctrl, 25)
    assert ctrl.decision_log[0].pool_deltas == {"od": -1, "spot": 3}
    assert ctrl.plan.live_of("od") == 1          # the release hit on-demand
    assert ctrl.plan.live_of("spot") == 3        # all three spot units landed


def test_plan_request_unknown_pool_fails_loudly():
    plan = _two_pool_plan()
    with pytest.raises(ValueError, match=r"unknown pool 'Spot'.*'od', 'spot'"):
        plan.request("Spot", 1, now=0.0)


def test_controller_config_validation():
    with pytest.raises(ValueError, match="adapt_period_s"):
        ControllerConfig(adapt_period_s=90.0, step_s=60.0)   # 1.5 steps
    with pytest.raises(ValueError, match="app_window_s"):
        ControllerConfig(app_window_s=50.0, step_s=60.0)     # < one step
    with pytest.raises(ValueError, match="step_s"):
        ControllerConfig(step_s=0.0)
    # exact multiples (incl. fractional steps) stay valid
    assert ControllerConfig(adapt_period_s=1.5, app_window_s=3.0,
                            step_s=0.5).period_steps == 3


# ---------------------------------------------------------------------------------
# Priced RunReports + per-class SLAs
# ---------------------------------------------------------------------------------

def _report(**kw):
    base = dict(backend="x", workload="w", policy="p", sla_s=10.0,
                latencies=np.array([1.0, 5.0, 20.0, 30.0]),
                unit_seconds=7200.0, units_t=np.array([1, 2]))
    base.update(kw)
    return RunReport(**base)


def test_runreport_cost_defaults_to_unit_hours():
    rep = _report()
    assert rep.cost == pytest.approx(2.0)
    assert rep["cost"] == pytest.approx(2.0)


def test_runreport_prices_pools_and_reports_revocations():
    rep = _report(pool_unit_seconds={"od": 3600.0, "spot": 7200.0},
                  pool_cost_rates={"od": 3.0, "spot": 1.0},
                  n_revocations=4)
    assert rep.cost == pytest.approx(1 * 3.0 + 2 * 1.0)
    s = rep.summary()
    assert s["unit_hours.od"] == pytest.approx(1.0)
    assert s["unit_hours.spot"] == pytest.approx(2.0)
    assert s["n_revocations"] == 4


def test_runreport_per_class_sla_breakdown():
    rep = _report(latencies=np.array([1.0, 5.0, 20.0, 30.0]),
                  classes=np.array(["batch", "inter", "inter", "batch"]),
                  sla=Sla(25.0, {"inter": 4.0}))
    # per-item deadlines: batch 25, inter 4 -> violations: 5>4, 20>4, 30>25
    assert rep.violation_rate == pytest.approx(3 / 4)
    by = rep.violation_rate_by_class()
    assert by == {"batch": pytest.approx(0.5), "inter": pytest.approx(1.0)}
    assert rep.worst_class == ("inter", pytest.approx(1.0))
    s = rep.summary()
    assert s["viol_pct.inter"] == pytest.approx(100.0)
    assert s["worst_class"] == "inter"
    # classes without an Sla spec fall back to the flat sla_s per class
    flat = _report(classes=np.array(["a", "a", "b", "b"]))
    assert flat.violation_rate_by_class() == \
        {"a": pytest.approx(0.0), "b": pytest.approx(1.0)}
    # no classes -> no breakdown keys, flat rate unchanged
    plain = _report()
    assert plain.violation_rate == pytest.approx(0.5)
    assert plain.worst_class is None
    assert "worst_class" not in plain.summary()


# ---------------------------------------------------------------------------------
# End-to-end: spot pools through a real backend
# ---------------------------------------------------------------------------------

def test_elastic_spot_pool_revocation_end_to_end():
    from repro.core.elastic import ClusterConfig, ElasticCluster, ServeRequest
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(
        rid=i, arrival_s=float(rng.uniform(0, 600)),
        prefill_len=int(rng.exponential(3000)) + 256,
        decode_len=int(rng.exponential(100)) + 16,
        request_class="interactive" if i % 3 == 0 else "batch")
        for i in range(3000)]
    cfg = ClusterConfig(
        pools=(UnitPool("od", provision_delay_s=45.0, cost_rate=3.0,
                        min_units=1),
               UnitPool("spot", provision_delay_s=45.0, cost_rate=1.0,
                        max_units=12, preemptible=True,
                        revoke_rate=1.0 / 120.0, revoke_seed=5)),
        sla=Sla(30.0, {"interactive": 15.0}))
    pol = CheapestFirstRouter(ThresholdPolicy(0.7))
    res = ElasticCluster(cfg, pol, reqs).run()
    assert res.n_done == len(reqs)
    assert res.n_revocations > 0                   # spot churned mid-run
    # per-pool meters add up to the fleet total, and the blended rate sits
    # strictly between the two pool prices
    us = res.pool_unit_seconds
    assert sum(us.values()) == pytest.approx(res.unit_seconds)
    assert us["spot"] > 0
    assert 1.0 < res.cost / res.unit_hours < 3.0
    by = res.violation_rate_by_class()
    assert set(by) == {"interactive", "batch"}
    # the tighter deadline makes interactive the harder class to serve
    assert by["interactive"] >= by["batch"]
    # decisions recorded per pool: the cheap pool was bought into
    assert any(d.pool_deltas.get("spot", 0) > 0 for d in res.decisions)


# ---------------------------------------------------------------------------------
# Meters: conservation invariants, overflow accounting, headroom clamp
# ---------------------------------------------------------------------------------

from _hypothesis_compat import given, settings, st  # noqa: E402


def test_request_clamps_to_headroom_and_reports_queued():
    plan = CapacityPlan((UnitPool("od", provision_delay_s=10.0, max_units=4),),
                        starting_units=2)
    assert plan.request("od", 10, now=0.0) == 2    # 4 - (2 live + 0 pending)
    assert plan.pending_of("od") == 2
    assert plan.request("od", 1, now=1.0) == 0     # headroom exhausted
    m = plan.meters()["od"]
    assert m.queued == 2 and m.overflow_request == 9
    st_ = plan.stats()["od"]
    assert st_.overflow == 9
    # landing frees no headroom (live+pending is conserved across land)
    plan.land(20.0)
    assert plan.live_of("od") == 4
    assert plan.request("od", 1, now=21.0) == 0
    # releasing does
    plan.release(2)
    assert plan.request("od", 2, now=23.0) == 2


def test_landing_overflow_is_metered_not_silently_dropped():
    # the request-side clamp makes landing overflow unreachable through the
    # public API; pin the belt-and-suspenders land() guard white-box, the way
    # a stale snapshot restore or future bug would hit it
    plan = CapacityPlan((UnitPool("od", provision_delay_s=10.0, max_units=3),),
                        starting_units=2)
    plan._state["od"].pending.extend([(5.0, 2)])   # bypasses the clamp
    plan.land(6.0)
    assert plan.live_of("od") == 3                 # ceiling held
    m = plan.meters()["od"]
    assert m.landed == 1 and m.overflow_landed == 1
    assert plan.stats()["od"].overflow == 1
    assert plan.pending_of("od") == 0              # overflow didn't linger


def _meters_conserve(plan, name, starting):
    """starting: per-pool live counts captured right after construction
    (initial allocation is not tracked by the meters)."""
    st_, m = plan.stats()[name], plan.meters()[name]
    return (st_.units, st_.pending) == (
        starting.get(name, 0) + m.landed - m.released - m.revoked
        - m.lost,
        m.queued - m.landed - m.cancelled - m.overflow_landed)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 6)),
                min_size=1, max_size=60),
       st.integers(0, 2 ** 31 - 1))
def test_capacity_meters_conserve_under_random_interleavings(ops, seed):
    """live == starting + landed - released - revoked - lost  and
    pending == queued - landed - cancelled - overflow_landed, whatever the
    interleaving of request/land/release/cancel/drain/replace under faults."""
    from repro.core.convergence import FaultInjector, FaultSpec
    pools = (UnitPool("od", provision_delay_s=7.0, cost_rate=3.0, min_units=1,
                      max_units=6),
             UnitPool("spot", provision_delay_s=3.0, cost_rate=1.0,
                      max_units=5, preemptible=True, revoke_rate=1 / 40.0,
                      revoke_seed=seed % 1000),)
    plan = CapacityPlan(
        pools, starting_units=3,
        faults=FaultInjector((FaultSpec(loss_rate=1 / 60.0, stuck_p=0.25,
                                        flap_rate=1 / 80.0, seed=seed),)))
    starting = {n: plan.live_of(n) for n in ("od", "spot")}
    names = ("od", "spot")
    t = 0.0
    for op, arg in ops:
        name = names[arg % 2]
        plan.land(t)
        if op == 0:
            plan.request(name, arg, now=t)
        elif op == 1:
            plan.release(arg)
        elif op == 2:
            plan.cancel_pending(name, arg)
        elif op == 3:
            plan.drain(name, arg)
        else:
            plan.replace_unhealthy(name, arg, now=t)
        for n in names:
            assert _meters_conserve(plan, n, starting), \
                (op, arg, t, plan.meters()[n])
            s = plan.stats()[n]
            assert 0 <= s.units <= plan._state[n].pool.max_units
            assert s.pending >= 0 and s.unhealthy <= s.units
        t += 1.0
    plan.land(t + 100.0)                           # drain all pending
    for n in names:
        assert _meters_conserve(plan, n, starting)


def test_capacity_meters_conserve_seeded_fuzz():
    """Deterministic companion to the hypothesis property above so the
    invariant is exercised even where hypothesis is not installed."""
    from repro.core.convergence import FaultInjector, FaultSpec
    rng = np.random.default_rng(42)
    for seed in range(20):
        pools = (UnitPool("od", provision_delay_s=7.0, cost_rate=3.0,
                          min_units=1, max_units=6),
                 UnitPool("spot", provision_delay_s=3.0, cost_rate=1.0,
                          max_units=5, preemptible=True, revoke_rate=1 / 40.0,
                          revoke_seed=seed),)
        plan = CapacityPlan(
            pools, starting_units=3,
            faults=FaultInjector((FaultSpec(loss_rate=1 / 60.0, stuck_p=0.25,
                                            flap_rate=1 / 80.0, seed=seed),)))
        starting = {n: plan.live_of(n) for n in ("od", "spot")}
        t = 0.0
        for op, arg in zip(rng.integers(0, 5, 60), rng.integers(0, 7, 60)):
            name = ("od", "spot")[int(arg) % 2]
            plan.land(t)
            if op == 0:
                plan.request(name, int(arg), now=t)
            elif op == 1:
                plan.release(int(arg))
            elif op == 2:
                plan.cancel_pending(name, int(arg))
            elif op == 3:
                plan.drain(name, int(arg))
            else:
                plan.replace_unhealthy(name, int(arg), now=t)
            for n in ("od", "spot"):
                assert _meters_conserve(plan, n, starting), (seed, op, arg, t)
            t += 1.0
        plan.land(t + 100.0)
        for n in ("od", "spot"):
            assert _meters_conserve(plan, n, starting), seed
