"""EP/TP shard_map MoE vs the reference scatter dispatch: bit-identical logits
on the same mesh (subprocess: needs 8 forced host devices)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str) -> str:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": os.path.join(ROOT, "src")}
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    return p.stdout


def test_ep_and_tp_modes_bit_identical():
    out = _run("""
        import os, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.distributed import moe_ep
        from repro.distributed.sharding import param_sharding

        for arch, mesh_shape in [('olmoe-1b-7b', (2, 4)),    # E=8 % 4 == 0: EP mode
                                 ('mixtral-8x22b', (1, 8))]: # E=4 <  8:     TP mode
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init_params(jax.random.key(0))
            toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
            batch = {'tokens': toks}
            mesh = jax.make_mesh(mesh_shape, ('data', 'model'))
            moe_ep.set_ep_mesh(mesh)
            with mesh:
                p_sh = param_sharding(model.abstract_params(), mesh)
                pp = jax.device_put(params, p_sh)
                os.environ['REPRO_MOE_EP'] = '0'
                l_ref, _ = jax.jit(model.forward, in_shardings=(p_sh, None))(pp, batch)
                os.environ['REPRO_MOE_EP'] = '1'
                l_ep, _ = jax.jit(model.forward, in_shardings=(p_sh, None))(pp, batch)
            d = float(np.abs(np.asarray(l_ref, np.float32)
                             - np.asarray(l_ep, np.float32)).max())
            assert d == 0.0, (arch, d)
            print(arch, 'BITIDENTICAL')
    """)
    assert out.count("BITIDENTICAL") == 2


def test_ep_loss_and_grads_close_to_unsharded():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.distributed import moe_ep
        from repro.distributed.sharding import param_sharding

        cfg = get_smoke_config('olmoe-1b-7b')
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)
        batch = {'tokens': toks, 'targets': toks}
        moe_ep.set_ep_mesh(None)
        l0, _ = jax.jit(model.loss_fn)(params, batch)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        moe_ep.set_ep_mesh(mesh)
        with mesh:
            p_sh = param_sharding(model.abstract_params(), mesh)
            l1, _ = jax.jit(model.loss_fn, in_shardings=(p_sh, None))(
                jax.device_put(params, p_sh), batch)
        d = abs(float(l0) - float(l1))
        assert d < 2e-3, d     # bf16 TP drift can flip borderline top-k routes
        print('LOSS_OK', d)
    """)
    assert "LOSS_OK" in out
