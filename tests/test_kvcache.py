"""Paged KV cache: pure page-ops semantics + pool free-list discipline."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kvcache import (
    TRASH_PAGE,
    PagedKVCache,
    paged_gather,
    paged_update,
    write_prefill_pages,
)


def _pool(max_batch=4, max_len=64, page_size=16, num_pages=None):
    init = lambda b, s: {"k": jnp.zeros((2, b, s, 2, 8)),
                         "v": jnp.zeros((2, b, s, 2, 8))}
    return PagedKVCache(init, max_batch=max_batch, max_len=max_len,
                        page_size=page_size, num_pages=num_pages)


def test_paged_ops_roundtrip_matches_dense():
    """Writing tokens through paged_update and reading through paged_gather
    reconstructs exactly the dense cache row, in logical order."""
    ps, P, B, n = 4, 9, 2, 2
    rest = (3, 5)
    rng = np.random.default_rng(0)
    pages = jnp.zeros((P, ps) + rest)
    tbl = jnp.asarray(np.array([[3, 1], [7, 2]], np.int32))
    dense = np.zeros((B, n * ps) + rest, np.float32)
    for pos in range(n * ps):
        new = rng.normal(size=(B, 1) + rest).astype(np.float32)
        pages = paged_update(pages, jnp.asarray(new),
                             tbl, jnp.full((B,), pos, jnp.int32))
        dense[:, pos] = new[:, 0]
    out = np.asarray(paged_gather(pages, tbl))
    np.testing.assert_array_equal(out, dense)


def test_write_prefill_pages_scatter_and_trash_overhang():
    ps, P, L = 4, 6, 2
    rest = (2, 3)
    pages = {"k": jnp.zeros((L, P, ps) + rest)}
    pb = 3 * ps                                    # bucket: 3 chunks
    cache = {"k": jnp.asarray(
        np.random.default_rng(1).normal(size=(L, 1, pb) + rest),
        jnp.float32)}
    # prompt spans 2 pages; third chunk is bucket overhang -> trash
    page_ids = jnp.asarray(np.array([4, 2, TRASH_PAGE], np.int32))
    out = write_prefill_pages(pages, cache, page_ids)["k"]
    np.testing.assert_array_equal(np.asarray(out[:, 4]),
                                  np.asarray(cache["k"][:, 0, :ps]))
    np.testing.assert_array_equal(np.asarray(out[:, 2]),
                                  np.asarray(cache["k"][:, 0, ps:2 * ps]))
    # untouched pages stay zero
    np.testing.assert_array_equal(np.asarray(out[:, 1]), 0.0)


def test_write_prefill_pages_batched_rows():
    """Batched prefill scatters each row's chunks into its own pages; all
    rows' overhang shares the trash page."""
    ps, P, L, B = 4, 8, 2, 3
    rest = (2, 3)
    pages = {"k": jnp.zeros((L, P, ps) + rest)}
    pb = 2 * ps
    cache = {"k": jnp.asarray(
        np.random.default_rng(2).normal(size=(L, B, pb) + rest), jnp.float32)}
    page_ids = jnp.asarray(np.array(
        [[5, 3], [1, TRASH_PAGE], [6, 2]], np.int32))
    out = write_prefill_pages(pages, cache, page_ids)["k"]
    for b, ids in enumerate([(5, 3), (1,), (6, 2)]):
        for c, pid in enumerate(ids):
            np.testing.assert_array_equal(
                np.asarray(out[:, pid]),
                np.asarray(cache["k"][:, b, c * ps:(c + 1) * ps]))
    np.testing.assert_array_equal(np.asarray(out[:, 7]), 0.0)  # untouched


def test_ensure_writable_span_preallocates_pages():
    """The device-resident decode loop's contract: every page the next K
    on-device writes may touch is allocated before the loop launches."""
    kv = _pool()
    kv.alloc_prefill(0, 10, 60, n_chunks=1)        # holds 1, reserves 4
    assert kv.held[0] == 1
    # K=8 burst from pos 10: writes 10..17, crossing into page 1
    kv.ensure_writable_span(0, 10, 8)
    assert kv.held[0] == 2
    kv.check_invariants()
    # K=8 burst from pos 30: crosses two boundaries at once (30..37)
    kv.ensure_writable_span(0, 30, 8)
    assert kv.held[0] == 3
    kv.check_invariants()
    # early-finished rows free their pre-allocated tail intact
    kv.release(0)
    kv.check_invariants()
    assert kv.n_free == kv.num_pages - 1
    with pytest.raises(RuntimeError):
        kv.ensure_writable_span(1, 0, 65)          # span past slot capacity


def test_pool_lifecycle_and_invariants():
    kv = _pool()
    assert kv.num_pages == 4 * 4 + 1               # all slots full + trash
    # prefill: 18 tokens -> 2 pages held, worst case 3 pages reserved
    ids = kv.alloc_prefill(0, 18, 33, n_chunks=2)
    assert kv.held[0] == 2 and kv.worst[0] == 3
    assert ids.shape == (2,) and TRASH_PAGE not in ids
    kv.check_invariants()
    # decode appends only when crossing a page boundary
    kv.ensure_writable(0, 18)
    assert kv.held[0] == 2                         # still inside page 1
    kv.ensure_writable(0, 32)
    assert kv.held[0] == 3                         # crossed into page 2
    kv.check_invariants()
    # release returns every page and clears the row
    free_before = kv.n_free
    kv.release(0)
    assert kv.n_free == free_before + 3
    assert kv.held[0] == 0 and kv.worst[0] == 0
    assert (kv.block_table[0] == TRASH_PAGE).all()
    kv.check_invariants()
    assert kv.n_free == kv.num_pages - 1           # nothing leaked


def test_reservation_blocks_overcommit_admission():
    """can_admit accounts for pages already promised to admitted requests,
    so a mid-decode append can never starve."""
    kv = _pool(max_batch=2, max_len=64, page_size=16, num_pages=5)  # 4 usable
    assert kv.can_admit(49)                        # needs 4 pages: exactly fits
    kv.alloc_prefill(0, 17, 49, n_chunks=2)        # holds 2, reserves 4
    assert not kv.can_admit(17)                    # 2 free - 2 outstanding = 0
    kv.ensure_writable(0, 32)                      # append consumes reservation
    kv.ensure_writable(0, 48)
    kv.check_invariants()
    assert not kv.can_admit(17) and kv.n_free == 0
    kv.release(0)
    assert kv.can_admit(49)


def test_pool_validates_geometry():
    with pytest.raises(ValueError):
        _pool(max_len=60, page_size=16)            # not page-aligned
    with pytest.raises(ValueError):
        _pool(max_len=96, page_size=12)            # not a power of two
