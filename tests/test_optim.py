"""Optimizer behaviour: descent, clipping, schedule."""
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.adamw import global_norm


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"x": 2.0 * params["x"]}
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.3


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0, total_steps=10)
    params = {"x": jnp.zeros(4)}
    state = adamw_init(params)
    grads = {"x": jnp.full(4, 1e6)}
    _, _, m = adamw_update(params, grads, state, cfg)
    assert float(m["grad_norm"]) > 1e5            # raw norm reported
    # clipped: first-step Adam update magnitude is ~lr regardless of grad scale


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_lr(cfg, s)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 or lrs[0] < 1e-4
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] < 1e-4                          # decayed at the end


def test_global_norm():
    import pytest
    t = {"a": jnp.ones(4), "b": jnp.ones(9) * 2.0}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(4 + 36), rel=1e-6)
