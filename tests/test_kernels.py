"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU; same kernel code compiles for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention.ops import (
    decode_attention,
    decode_attention_paged,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_intra
from repro.kernels.ssd.ref import ssd_intra_ref


@pytest.mark.parametrize("B,S,Hq,Hkv,D,win,dtype", [
    (2, 256, 4, 2, 64, None, jnp.float32),
    (1, 512, 8, 8, 128, None, jnp.float32),
    (2, 256, 4, 1, 64, 64, jnp.float32),
    (1, 384, 6, 2, 32, 128, jnp.float32),
    (1, 256, 4, 2, 64, None, jnp.bfloat16),
])
def test_flash_attention_allclose(B, S, Hq, Hkv, D, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + Hq), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, window=win, block_q=128, block_k=128)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), win).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@given(st.integers(1, 3), st.sampled_from([128, 192, 256]),
       st.sampled_from([(4, 2), (4, 4), (6, 3)]), st.sampled_from([32, 64]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_hypothesis(B, S, heads, D):
    Hq, Hkv = heads
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), None).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,S,Hq,Hkv,D,pos,win", [
    (2, 512, 8, 2, 64, 300, None),
    (1, 1024, 4, 4, 128, 1000, None),
    (2, 512, 8, 2, 64, 400, 128),
    (1, 256, 8, 1, 64, 17, None),       # pos not block-aligned
    (2, 384, 8, 2, 64, 201, 96),        # GQA + window + partial, unaligned
    (1, 256, 6, 3, 32, 250, 300),       # window wider than the filled cache
])
def test_decode_attention_allclose(B, S, Hq, Hkv, D, pos, win):
    ks = jax.random.split(jax.random.PRNGKey(S + pos), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = decode_attention(q, k, v, pos, window=win, block_k=256)
    ref = decode_attention_ref(q[:, 0], k, v, pos, win)[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,D,ps,n,lens,win", [
    (3, 8, 2, 64, 16, 4, (17, 43, 64), None),     # GQA, partial pages
    (3, 8, 2, 64, 16, 4, (17, 43, 64), 24),       # GQA + sliding window
    (2, 4, 4, 32, 16, 3, (1, 48), None),          # MHA, one-token row
    (2, 8, 1, 64, 32, 2, (33, 50), 40),           # MQA, big pages + window
])
def test_paged_decode_attention_matches_oracles(B, Hq, Hkv, D, ps, n, lens, win):
    """Block-table kernel == gather-over-pages oracle == dense kernel oracle,
    under GQA, sliding windows, and partially filled last pages."""
    P = B * n + 2
    ks = jax.random.split(jax.random.PRNGKey(B * Hq + ps), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k_pages = jax.random.normal(ks[1], (P, ps, Hkv, D))
    v_pages = jax.random.normal(ks[2], (P, ps, Hkv, D))
    rng = np.random.default_rng(0)
    # disjoint random physical pages per row; page 0 is the trash page
    perm = rng.permutation(np.arange(1, P))
    tbl = jnp.asarray(perm[:B * n].reshape(B, n).astype(np.int32))
    lengths = jnp.asarray(np.array(lens, np.int32))
    out = decode_attention_paged(q, k_pages, v_pages, tbl, lengths, window=win)
    ref = paged_decode_attention_ref(q[:, 0], k_pages, v_pages, tbl, lengths,
                                     win)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # cross-check each row against the DENSE kernel oracle on the gathered
    # cache -- the paged path must be exactly the dense computation
    flat_k = np.asarray(k_pages).reshape(P * ps, Hkv, D)
    flat_v = np.asarray(v_pages).reshape(P * ps, Hkv, D)
    for b in range(B):
        idx = (np.asarray(tbl)[b][:, None] * ps + np.arange(ps)[None]).reshape(-1)
        dense = decode_attention_ref(q[b:b + 1, 0],
                                     jnp.asarray(flat_k[idx])[None],
                                     jnp.asarray(flat_v[idx])[None],
                                     int(lens[b]), win)
        np.testing.assert_allclose(np.asarray(out[b, 0]), np.asarray(dense[0]),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,D,ps,n,lens,win", [
    (3, 8, 2, 64, 16, 4, (17, 43, 64), None),     # GQA, partial pages
    (2, 4, 4, 32, 16, 3, (1, 48), 24),            # MHA, one-token row, window
])
def test_paged_decode_attention_int8_matches_gather(B, Hq, Hkv, D, ps, n,
                                                    lens, win):
    """Acceptance: int8 KV through the paged Pallas kernel (in-register
    dequantize) == the dequantize-then-gather route it used to fall back
    to, and == the fp kernel on the dequantized pool."""
    from repro.kernels.decode_attention.ref import (
        paged_decode_attention_int8_ref,
    )
    P = B * n + 2
    ks = jax.random.split(jax.random.PRNGKey(B * Hq + ps + 7), 5)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k_pages = jax.random.randint(ks[1], (P, ps, Hkv, D), -127, 128, jnp.int8)
    v_pages = jax.random.randint(ks[2], (P, ps, Hkv, D), -127, 128, jnp.int8)
    k_scale = jax.random.uniform(ks[3], (P, ps, Hkv, 1), minval=5e-3,
                                 maxval=3e-2)
    v_scale = jax.random.uniform(ks[4], (P, ps, Hkv, 1), minval=5e-3,
                                 maxval=3e-2)
    rng = np.random.default_rng(1)
    perm = rng.permutation(np.arange(1, P))
    tbl = jnp.asarray(perm[:B * n].reshape(B, n).astype(np.int32))
    lengths = jnp.asarray(np.array(lens, np.int32))
    out = decode_attention_paged(q, k_pages, v_pages, tbl, lengths,
                                 window=win, k_scale=k_scale, v_scale=v_scale)
    ref = paged_decode_attention_int8_ref(q[:, 0], k_pages, v_pages, k_scale,
                                          v_scale, tbl, lengths, win)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # and the fp kernel on the pre-dequantized pool agrees
    fp = decode_attention_paged(q, k_pages.astype(jnp.float32) * k_scale,
                                v_pages.astype(jnp.float32) * v_scale,
                                tbl, lengths, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                               atol=2e-5, rtol=2e-5)


def test_int8_paged_block_decode_matches_gather_path():
    """models.lm.block_decode routes int8 + block_table through the kernel
    when use_kernel=True; the caches must match bit-for-bit (same quantize,
    same scatter) and the logits within bf16 noise -- the kernel dequantizes
    in f32 registers where the gather route rounds through cfg.dtype."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_smoke_config("smollm-135m"),
                              kv_cache_dtype="int8")
    m_gather = build_model(cfg)                    # jnp gather + dequantize
    m_kernel = build_model(cfg, use_kernel=True)   # int8 paged Pallas path
    params = m_gather.init_params(jax.random.key(0))
    B, ps, n = 2, 16, 2
    pages = m_gather.init_cache(n * B + 1, ps)     # (L, P, ps, ...) pools
    toks = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab)
    tbl = jnp.asarray(np.array([[1, 2], [3, 4]], np.int32))
    pos = jnp.asarray(np.array([5, 20], np.int32))
    lg_g, cache_g = m_gather.decode_step(params, pages, toks, pos,
                                         block_table=tbl)
    lg_k, cache_k = m_kernel.decode_step(params, pages, toks, pos,
                                         block_table=tbl)
    np.testing.assert_allclose(np.asarray(lg_g), np.asarray(lg_k), atol=0.1)
    # layer-0 writes see identical inputs, so they quantize identically;
    # deeper layers inherit the f32-vs-bf16 attention noise through the
    # residual stream, so their writes may move by a few quantization steps
    np.testing.assert_array_equal(np.asarray(cache_g["k"][0]),
                                  np.asarray(cache_k["k"][0]))
    for name in ("k", "v"):
        g = np.asarray(cache_g[name], np.float32)
        k = np.asarray(cache_k[name], np.float32)
        assert np.mean(g != k) < 0.02 and np.abs(g - k).max() <= 8


@given(st.sampled_from([32, 64, 128]), st.sampled_from([2, 4]),
       st.sampled_from([16, 32]), st.sampled_from([8, 16]))
@settings(max_examples=8, deadline=None)
def test_ssd_intra_hypothesis(q, h, p, n):
    b, nc = 1, 2
    ks = jax.random.split(jax.random.PRNGKey(q + h), 4)
    xb = jax.random.normal(ks[0], (b, nc, q, h, p))
    acs = -jnp.abs(jax.random.normal(ks[1], (b, nc, q, h))).cumsum(2) * 0.1
    Bh = jax.random.normal(ks[2], (b, nc, q, h, n))
    Ch = jax.random.normal(ks[3], (b, nc, q, h, n))
    out = ssd_intra(xb, acs, Bh, Ch)
    ref = jnp.stack([ssd_intra_ref(xb[:, i], acs[:, i], Bh[:, i], Ch[:, i])
                     for i in range(nc)], 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_ssd_full_scan_kernel_path_matches_ref():
    """ssd_chunked(use_kernel=True) == ssd_chunked(use_kernel=False)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, g, n, chunk = 2, 64, 4, 16, 1, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    D = jnp.ones((h,))
    y0 = ssd_chunked(x, dt, A, B, C, D, chunk, use_kernel=False)
    y1 = ssd_chunked(x, dt, A, B, C, D, chunk, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0, np.float32), np.asarray(y1, np.float32),
                               atol=1e-3, rtol=1e-3)


def test_ssd_chunked_matches_naive_recurrence():
    """The chunked SSD equals the literal per-step recurrence."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    b, s, h, p, g, n, chunk = 1, 32, 2, 8, 1, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    D = jnp.zeros((h,))
    y_chunked = ssd_chunked(x, dt, A, B, C, D, chunk, use_kernel=False)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, state = ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], D, state)
        ys.append(y_t)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                               np.asarray(y_naive, np.float32), atol=2e-3, rtol=2e-3)
