"""Chaos-drill layer: deterministic fault scripts (seeded victim choice,
byte-identical replay), the invariant checkers that define "recovered
correctly" (exactly-once, bit-identical outputs, KV conservation, sealed
audit replay), and the drill harness plumbing.  The end-to-end drill over a
REAL replica fleet lives in tests/test_fleet.py (shared spawn fixture); the
converger-vs-baseline soak is benchmarks/chaos_drills.py."""
import pytest

from repro.core.chaos import (
    ChaosAction,
    ChaosScript,
    Violation,
    check_audit,
    check_exactly_once,
    check_kv_conservation,
    check_outputs_match,
)
from repro.core.convergence import (
    AuditLog,
    Converger,
    ConvergerConfig,
    DesiredGroup,
    PoolTarget,
    ScriptedFault,
    ScriptedFaults,
)
from repro.core.scaling import CapacityPlan, UnitPool


# ---------------------------------------------------------------------------------
# fakes: just enough surface for the script to actuate
# ---------------------------------------------------------------------------------

class _FakeReplica:
    def __init__(self, rix):
        self.rix = rix


class _FakePool:
    def __init__(self, n):
        self.serving = [_FakeReplica(i) for i in range(n)]


class _FakeTarget:
    """Duck-typed drill target: records every actuation in order."""

    def __init__(self, n_replicas):
        self.pool = _FakePool(n_replicas)
        self.calls = []

    def kill_replica(self, rep, now):
        self.pool.serving.remove(rep)
        self.calls.append(("kill", rep.rix, now))

    def fire_webhook(self, name, now):
        self.calls.append(("webhook", name, now))


class _Req:
    def __init__(self, rid, output=(1, 2, 3), done_s=5.0):
        self.rid = rid
        self.output = list(output)
        self.done_s = done_s


# ---------------------------------------------------------------------------------
# scripts
# ---------------------------------------------------------------------------------

def test_chaos_action_validation():
    with pytest.raises(ValueError, match="unknown action kind"):
        ChaosAction(0.0, "explode")
    with pytest.raises(ValueError, match="needs a name"):
        ChaosAction(0.0, "webhook")
    with pytest.raises(ValueError, match="frac"):
        ChaosAction(0.0, "corr_kill", frac=0.0)
    with pytest.raises(ValueError, match="at_s"):
        ChaosAction(-1.0, "kill")
    with pytest.raises(TypeError):
        ChaosScript([object()])


def test_script_fires_in_order_and_replays_identically():
    """Actions fire on the first step at/past their timestamp, kills land
    before same-instant webhooks, victims are a seeded draw -- and reset()
    rewinds to a byte-identical re-run (the audit-determinism property)."""
    script = ChaosScript([
        ChaosAction(4.0, "webhook", name="surge"),
        ChaosAction(4.0, "kill", count=1),
        ChaosAction(7.5, "corr_kill", frac=0.5),
    ], seed=11)
    assert [a.kind for a in script.actions] == ["kill", "webhook",
                                                "corr_kill"]

    def run():
        target = _FakeTarget(5)
        for t in range(10):
            script.on_step(target, float(t))
        return target.calls

    first = run()
    assert script.done
    kinds = [c[0] for c in first]
    assert kinds[:2] == ["kill", "webhook"]        # same-instant ordering
    assert len([c for c in first if c[0] == "kill" and c[2] == 4.0]) == 1
    # corr_kill at 7.5 fires at the t=8 step: ceil(0.5 * 4 live) = 2 victims
    corr = [c for c in first if c[2] == 8.0]
    assert len(corr) == 2 and all(c[0] == "kill" for c in corr)
    fired = list(script.fired)
    script.reset()
    assert run() == first                          # same seed, same victims
    assert script.fired == fired


# ---------------------------------------------------------------------------------
# invariant checkers
# ---------------------------------------------------------------------------------

def test_exactly_once_checker_catches_loss_dupes_phantoms():
    ok = [_Req(0), _Req(1)]
    assert check_exactly_once([0, 1], ok) == []
    # a lost request is only a violation at drill END, not mid-flight
    assert check_exactly_once([0, 1, 2], ok, final=False) == []
    lost = check_exactly_once([0, 1, 2], ok)
    assert len(lost) == 1 and "never completed" in lost[0].detail
    dup = check_exactly_once([0, 1], ok + [_Req(1)])
    assert any("2 times" in v.detail for v in dup)
    phantom = check_exactly_once([0], ok)
    assert any("never admitted" in v.detail for v in phantom)
    hollow = check_exactly_once([0], [_Req(0, output=())])
    assert any("without output" in v.detail for v in hollow)


def test_outputs_match_checker_reports_first_divergence():
    ref = [_Req(0, output=(1, 2, 3)), _Req(1, output=(4, 5))]
    assert check_outputs_match([_Req(0), _Req(1, output=(4, 5))], ref) == []
    bad = check_outputs_match([_Req(0, output=(1, 9, 3))], ref)
    assert len(bad) == 1 and "token 1" in bad[0].detail
    trunc = check_outputs_match([_Req(1, output=(4,))], ref)
    assert len(trunc) == 1 and "token 1" in trunc[0].detail
    orphan = check_outputs_match([_Req(7)], ref)
    assert len(orphan) == 1 and "no fault-free reference" in orphan[0].detail
    assert str(bad[0]).startswith("bit_identical:")
    assert isinstance(bad[0], Violation)


def test_check_audit_layers(tmp_path):
    """check_audit reports (not raises) on a broken seal, cross-checks the
    capacity replay against the plan's final state, and flags doctored
    planner steps through verify_plan_replay."""
    path = str(tmp_path / "a.jsonl")
    plan = CapacityPlan(
        (UnitPool("od", provision_delay_s=2.0, max_units=8),),
        starting_units=1,
        faults=ScriptedFaults((ScriptedFault(3.0, "lose", pool="od"),)))
    conv = Converger(plan, ConvergerConfig(build_timeout_s=10.0),
                     audit=AuditLog(path))
    # the controller normally writes the init record; do it by hand here
    conv.audit.append(0.0, "init", pools={"od": 1})
    conv.set_desired(DesiredGroup({"od": PoolTarget(3, 1, 8)}), 0.0)
    t = 0.0
    for _ in range(20):
        plan.land(t)
        conv.converge(t)
        t += 1.0
    conv.audit.seal(t)
    conv.audit.close()
    final = {"od": {"live": plan.live_of("od"),
                    "pending": plan.pending_of("od")}}
    assert check_audit(path, final) == []
    # wrong final state: the replay cross-check names the pool
    drifted = {"od": {"live": final["od"]["live"] + 1, "pending": 0}}
    assert any("replay gives" in v.detail for v in check_audit(path, drifted))
    # truncated tail: reported as a violation, not an exception
    with open(path) as fh:
        lines = fh.read().splitlines()
    p2 = str(tmp_path / "torn.jsonl")
    with open(p2, "w") as fh:
        fh.write("\n".join(lines[:-1]) + "\n")
    broken = check_audit(p2)
    assert len(broken) == 1 and broken[0].invariant == "audit_replay"
    assert "seal" in broken[0].detail


def test_kv_conservation_checker_skips_killed_replicas():
    """Only engines that still exist are checked: serving replicas must
    balance, drained replicas must be empty, killed ones are skipped."""

    class _KV:
        def __init__(self, n_free, num_pages, fail=False):
            self.n_free = n_free
            self.num_pages = num_pages
            self.fail = fail

        def check_invariants(self):
            assert not self.fail, "page leak"

    class _Eng:
        def __init__(self, kv):
            self.kv = kv

    class _Rep:
        def __init__(self, rix, kv, draining=False):
            self.rix = rix
            self.eng = _Eng(kv)
            self.draining = draining

    class _Pool:
        def __init__(self, serving, retired):
            self.serving = serving
            self.retired = retired

    healthy = _Pool([_Rep(0, _KV(9, 10))], [])
    assert check_kv_conservation(healthy, drained=True) == []
    leaky = _Pool([_Rep(0, _KV(5, 10, fail=True))], [])
    assert any("page leak" in v.detail for v in check_kv_conservation(leaky))
    held = _Pool([_Rep(0, _KV(7, 10))], [])
    assert check_kv_conservation(held) == []          # mid-drill: fine
    assert any("still held" in v.detail
               for v in check_kv_conservation(held, drained=True))
    stranded = _Pool([], [_Rep(1, _KV(6, 10), draining=True),
                          _Rep(2, _KV(0, 10), draining=False)])  # killed
    out = check_kv_conservation(stranded)
    assert len(out) == 1 and "stranded 3 pages" in out[0].detail
