"""Distributed train step: loss -> grad -> (optional microbatch accumulation)
-> (optional int8 cross-pod gradient compression) -> AdamW.

Built for pjit: the caller supplies in/out shardings from
``repro.distributed.sharding``; inside, activations follow from the param
layout.  Microbatching uses ``lax.scan`` over grad accumulation so the HLO
stays O(1) in the number of microbatches.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import batch_sharding, param_sharding
from repro.models.registry import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1,
                    compress_pod_grads: bool = False) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    loss_fn = model.loss_fn

    def grads_of(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            return loss, grads

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def acc_fn(carry, mbatch):
            loss_acc, g_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
            g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
            return (loss_acc + loss, g_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zero), mb)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if compress_pod_grads:
            from repro.distributed.compression import int8_pod_allreduce
            grads, opt_state = int8_pod_allreduce(grads, opt_state)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def train_state_shardings(model: Model, mesh, batch_abstract):
    """(param_sh, opt_sh, batch_sh) NamedSharding trees for pjit."""
    p_abs = model.abstract_params()
    p_sh = param_sharding(p_abs, mesh)
    o_abs = jax.eval_shape(adamw_init, p_abs)
    o_sh = param_sharding(o_abs, mesh)  # m/v mirror params; step replicates
    b_sh = batch_sharding(batch_abstract, mesh)
    return p_sh, o_sh, b_sh


class TrainState:
    """Thin convenience holder used by the example drivers."""

    def __init__(self, params, opt_state, step: int = 0):
        self.params = params
        self.opt_state = opt_state
        self.step = step


__all__ = ["make_train_step", "train_state_shardings", "TrainState"]
