"""Expert-parallel MoE via shard_map: the hillclimbed replacement for the
pjit-scatter dispatch (EXPERIMENTS.md SSPerf).

Why: under plain SPMD, the sort-based dispatch's cross-sharding gathers
(x[token_idx] with tokens data-sharded feeding an expert-sharded buffer)
degenerate into full (T*k, D) f32 REPLICATED arrays all-reduced over the model
axis -- measured 7 x 68.7 GB all-reduces per olmoe train step.

Scheme (zero-communication dispatch, one psum combine):
* tokens stay on their (pod, data) shard; every model rank sees the same local
  tokens (activations are replicated over 'model' between TP blocks anyway);
* each model rank owns E/mp experts; routing is computed redundantly (cheap,
  deterministic) on every rank;
* each rank scatters ONLY the tokens routed to its own experts into its local
  (E_loc, C_loc, D) buffer -- no inter-device traffic at all;
* after the expert FFN, each rank holds partial outputs for the local tokens
  that visited its experts; one psum over 'model' completes the combine:
  per layer traffic = |activations| instead of k x |token copies| x E-spread.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.common import MoEConfig
from repro.models.moe import load_balance_loss, router_topk

#: set by launchers (dryrun / train) when a mesh is active; models pick it up.
_EP_MESH = None


def set_ep_mesh(mesh) -> None:
    global _EP_MESH
    _EP_MESH = mesh


def get_ep_mesh():
    return _EP_MESH


def _local_moe(x, params, cfg: MoEConfig, model_axis: str, mp: int):
    """Per-device body: x (T_loc, D) local tokens; params expert-sharded
    (E_loc, D, F) on ``model_axis``; ``mp`` = static model-axis size."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    rank = jax.lax.axis_index(model_axis)
    E_loc = E // mp
    C = max(int(T * k * cfg.capacity_factor / E), min(4, T * k))

    weights, experts, logits = router_topk(x, params["router"], cfg)

    flat_e = experts.reshape(-1)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, st = flat_e[order], flat_w[order], flat_t[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank_in_e = jnp.arange(T * k) - starts[se]
    mine = (se // E_loc) == rank          # routed to an expert owned by this rank
    keep = (rank_in_e < C) & mine

    e_loc = jnp.where(keep, se - rank * E_loc, 0)
    c_idx = jnp.where(keep, rank_in_e, 0)
    src = jnp.where(keep[:, None], x[st], 0.0).astype(x.dtype)
    buf = jnp.zeros((E_loc, C, D), dtype=x.dtype)
    buf = buf.at[e_loc, c_idx].add(src, mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    gathered = y[e_loc, c_idx]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((T, D), dtype=jnp.float32)
    out = out.at[st].add(gathered.astype(jnp.float32) * sw[:, None])
    # ONE combine all-reduce per layer: tokens visited experts on other ranks
    out = jax.lax.psum(out.astype(x.dtype), model_axis)
    aux = load_balance_loss(logits, experts, E)
    return out, aux


def _local_moe_tp(x, params, cfg: MoEConfig, model_axis: str):
    """TP mode (E < model ranks): every rank routes + dispatches ALL experts
    locally, expert FFNs are sharded on the hidden dim F; the down-projection
    produces partial sums completed by the same single psum."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(T * k * cfg.capacity_factor / E), min(4, T * k))

    weights, experts, logits = router_topk(x, params["router"], cfg)
    flat_e = experts.reshape(-1)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, st = flat_e[order], flat_w[order], flat_t[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank_in_e = jnp.arange(T * k) - starts[se]
    keep = rank_in_e < C
    e_idx = jnp.where(keep, se, 0)
    c_idx = jnp.where(keep, rank_in_e, 0)
    src = jnp.where(keep[:, None], x[st], 0.0).astype(x.dtype)
    buf = jnp.zeros((E, C, D), dtype=x.dtype)
    buf = buf.at[e_idx, c_idx].add(src, mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # partial over F shard

    gathered = y[e_idx, c_idx]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((T, D), dtype=jnp.float32)
    out = out.at[st].add(gathered.astype(jnp.float32) * sw[:, None])
    out = jax.lax.psum(out.astype(x.dtype), model_axis)   # completes F partials
    aux = load_balance_loss(logits, experts, E)
    return out, aux


def moe_ffn_ep(x3d, params, cfg: MoEConfig, mesh):
    """x3d: (B, S, D) batch-sharded on (pod, data).  Returns (out, aux).

    EP mode when n_experts divides the model axis; per-expert TP mode otherwise
    (experts replicated in E, sharded on the FFN hidden dim).
    """
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    model_axis = "model"
    mp = mesh.shape["model"]
    ep_mode = cfg.n_experts % mp == 0
    # tiny batches (long-context decode feeds batch=1) cannot shard over the
    # data axes: compute them redundantly on every data rank instead
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    if x3d.shape[0] % dsize != 0:
        daxes = ()

    def body(x_loc, p_loc):
        B, S, D = x_loc.shape
        xf = x_loc.reshape(B * S, D)
        if ep_mode:
            out, aux = _local_moe(xf, p_loc, cfg, model_axis, mp)
        else:
            out, aux = _local_moe_tp(xf, p_loc, cfg, model_axis)
        # aux is identical across model ranks (redundant routing) but differs per
        # data shard: mean over every axis so the P() out_spec is truthful
        aux = jax.lax.pmean(aux, model_axis)
        for ax in daxes:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(B, S, D), aux

    if ep_mode:
        w_specs = {"router": P(None, None), "w_gate": P("model", None, None),
                   "w_up": P("model", None, None), "w_down": P("model", None, None)}
    else:
        w_specs = {"router": P(None, None), "w_gate": P(None, None, "model"),
                   "w_up": P(None, None, "model"), "w_down": P(None, "model", None)}

    x_spec = P(daxes, None, None) if daxes else P(None, None, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, w_specs),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    out, aux = fn(x3d, params)
    return out, jnp.mean(aux)


__all__ = ["moe_ffn_ep", "set_ep_mesh", "get_ep_mesh"]
