"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

Cross-pod (data-center-interconnect) links are the scarcest bandwidth at
multi-pod scale, so the pod-axis gradient reduction is the right place to
compress.  Scheme: per-leaf symmetric int8 quantization with error feedback
(the quantization residual is carried in optimizer-adjacent state and added
back next step), psum over the 'pod' axis only -- the within-pod reduction
stays full precision.

Implementation: partial-auto ``shard_map`` -- 'pod' is manually mapped (so we
control exactly what crosses pods) while 'data'/'model' stay auto-partitioned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quantize(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_allreduce_pod(grads, error_state, *, axis: str = "pod"):
    """Inside shard_map over the pod axis: quantize(grad + error) -> psum ->
    dequantize; returns (reduced_grads, new_error_state)."""
    n = jax.lax.psum(1.0, axis)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quantize(g)
        # int8 payloads cross the pod link; scales are f32 scalars
        total = jax.lax.psum(q.astype(jnp.float32) * scale, axis) / n
        new_e = g - _dequantize(q, scale)
        return total.astype(jnp.float32), new_e

    out = jax.tree.map(one, grads, error_state)
    red = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return red, err


def init_error_state(params_abstract):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params_abstract)


def make_compressed_grad_fn(loss_fn, mesh):
    """Returns grad_fn(params, batch, error_state) -> (loss, grads, new_error)
    where the pod-axis reduction is int8-compressed with error feedback.

    The pod axis is manually mapped; everything else stays under the SPMD
    partitioner (shard_map ``auto`` mode).
    """
    def local_grads(params, batch):
        # batch is the pod-local slice; loss mean is pod-local
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, grads

    # Only the pod axis is manually mapped (we own what crosses pods);
    # 'data'/'model' stay under the automatic SPMD partitioner via ``auto``.
    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P("pod"), P()),
             out_specs=(P(), P(), P()),
             check_rep=False,
             auto=frozenset(a for a in mesh.axis_names if a != "pod"))
    def fn(params, batch, error_state):
        loss, grads = local_grads(params, batch)
        grads, new_err = compress_allreduce_pod(grads, error_state)
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads, new_err

    return fn


__all__ = ["compress_allreduce_pod", "init_error_state", "make_compressed_grad_fn"]
