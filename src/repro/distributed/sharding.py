"""Sharding rules: param-path patterns -> PartitionSpec (DP/TP/EP/SP).

Layout summary (model axis = "model", batch over ("pod", "data")):

* vocab/embedding: vocab-sharded; lm_head column-sharded;
* attention: Q/K/V column-sharded by head, O row-sharded (Megatron layout);
* MLP: gate/up column-, down row-sharded;
* MoE: experts sharded on "model" (EP); router replicated;
* Mamba: z/x/dt head-sharded, B/C (group-shared) replicated, out row-sharded;
* KV caches: head-sharded when kv_heads % model == 0, else head_dim-sharded
  (logit contraction over head_dim psums cheaply);
* long-context (batch 1): KV *sequence* sharded on "data" (SP).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def _last(path) -> str:
    """Last DictKey name in a jax tree path."""
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _in_stack(path) -> bool:
    names = {str(p.key) for p in path if hasattr(p, "key")}
    return bool(names & {"blocks", "enc_blocks", "dec_blocks"})


#: rules by leaf name, WITHOUT the stacked leading layer dim
_RULES = {
    # attention
    "wq": P(None, "model"), "wk": P(None, "model"), "wv": P(None, "model"),
    "xq": P(None, "model"), "xk": P(None, "model"), "xv": P(None, "model"),
    "bq": P("model"), "bk": P("model"), "bv": P("model"),
    "wo": P("model", None), "xo": P("model", None),
    # dense mlp
    "w_gate": P(None, "model"), "w_up": P(None, "model"), "w_down": P("model", None),
    # mamba
    "w_z": P(None, "model"), "w_x": P(None, "model"), "w_dt": P(None, "model"),
    "w_bc": P(None, None),
    "conv_x": P(None, "model"), "conv_bc": P(None, None),
    "A_log": P("model"), "D": P("model"), "dt_bias": P("model"),
    "norm": P("model"),
    "out_proj": P("model", None),
    # norms / misc
    "ln": P(None), "ln1": P(None), "ln2": P(None), "ln_x": P(None),
    "router": P(None, None),
}

#: MoE expert tensors (inside a "moe" subtree): expert dim -> "model"
_MOE_RULES = {
    "w_gate": P("model", None, None), "w_up": P("model", None, None),
    "w_down": P("model", None, None), "router": P(None, None),
}


def _spec_for(path, leaf) -> P:
    name = _last(path)
    names = [str(p.key) for p in path if hasattr(p, "key")]
    if name == "embed":
        return P("model", None)
    if name == "lm_head":
        return P(None, "model")
    if name in ("ln_f", "ln_enc"):
        return P(None)
    rules = _MOE_RULES if "moe" in names else _RULES
    spec = rules.get(name)
    if spec is None:
        spec = P(*([None] * leaf.ndim))
        return spec
    if _in_stack(path):
        spec = P(*((None,) + tuple(spec)))
    # pad/truncate to leaf rank (biases in unstacked shared_attn etc.)
    parts = tuple(spec)
    if len(parts) < leaf.ndim:
        parts = parts + (None,) * (leaf.ndim - len(parts))
    elif len(parts) > leaf.ndim:
        parts = parts[-leaf.ndim:]
    return P(*parts)


def param_sharding(params_abstract, mesh: Mesh):
    """Pytree of NamedSharding matching ``params_abstract`` (ShapeDtypeStructs).

    Falls back to replication on any dim whose size does not divide the mesh
    axis (e.g. 15-head smollm TP on 16): correctness first, the hillclimb pass
    re-shards what matters.
    """
    msize = mesh.shape.get("model", 1)

    def one(path, leaf):
        spec = _spec_for(path, leaf)
        parts = []
        for dim, ax in enumerate(tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))):
            if ax == "model" and leaf.shape[dim] % msize != 0:
                parts.append(None)
            else:
                parts.append(ax)
        # MoE experts with E < model size: shard the FFN hidden dim instead
        # (per-expert tensor parallelism; see repro.distributed.moe_ep TP mode)
        name = _last(path)
        names = [str(pp.key) for pp in path if hasattr(pp, "key")]
        if "moe" in names and name in ("w_gate", "w_up", "w_down") \
                and "model" not in parts:
            f_dim = leaf.ndim - 1 if name in ("w_gate", "w_up") else leaf.ndim - 2
            if leaf.shape[f_dim] % msize == 0:
                parts[f_dim] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(one, params_abstract)


def batch_sharding(batch_abstract, mesh: Mesh):
    """Inputs: batch dim over ('pod','data'); other dims replicated.  Batch
    dims smaller than the data axis fall back to replication (long-context
    decode feeds batch=1)."""
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]

    def one(path, leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dsize != 0:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(mesh, P(*((daxes,) + (None,) * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(one, batch_abstract)


def cache_sharding(cache_abstract, cfg: ModelConfig, mesh: Mesh):
    """KV / SSM cache shardings.

    Layout (L, B, S, H, D) for attention caches; (L, B, H, P, N) ssm;
    (L, B, W, C) conv.  Batch on ('pod','data') when divisible, else the
    SEQUENCE dim goes on 'data' (SP long-context decode); heads on 'model'
    when divisible, else head_dim on 'model'.
    """
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)

    def one(path, leaf):
        name = _last(path)
        if name in ("k", "v", "xk", "xv", "attn_k", "attn_v",
                    "k_scale", "v_scale"):
            L, B, S, H, D = leaf.shape
            b_ax = daxes if B % dsize == 0 else None
            s_ax = None
            if b_ax is None and S % dsize == 0:
                s_ax = daxes
            # model axis preference: heads > sequence > head_dim.
            # Sequence-sharding (flash-decoding-style SP) beats head_dim-sharding
            # when kv_heads < model size: softmax over the sharded S axis needs
            # only scalar-sized psums, while hd-sharding forced per-layer KV
            # all-gathers (measured on mixtral decode_32k -- see EXPERIMENTS SSPerf).
            h_ax = d_ax = None
            s_model = None
            if H % msize == 0:
                h_ax = "model"
            elif s_ax is None and S % msize == 0:
                s_model = "model"
            elif D % msize == 0 and D > 1:
                d_ax = "model"
            return NamedSharding(mesh, P(None, b_ax, s_ax or s_model, h_ax, d_ax))
        if name == "ssm":
            L, B, H, Pd, N = leaf.shape
            b_ax = daxes if B % dsize == 0 else None
            h_ax = "model" if H % msize == 0 else None
            return NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        if name == "conv":
            L, B, W, C = leaf.shape
            b_ax = daxes if B % dsize == 0 else None
            return NamedSharding(mesh, P(None, b_ax, None, None))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def shard_params(params, mesh: Mesh):
    """Device-put concrete params with the rule shardings (small models/tests)."""
    abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    sh = param_sharding(abstract, mesh)
    return jax.device_put(params, sh)


__all__ = ["param_sharding", "batch_sharding", "cache_sharding", "shard_params"]
