"""Parse compiled (post-SPMD) HLO text for collective traffic + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
bytes; those are summed here from the result shapes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
per-device HLO module.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[2,128,4096]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" + "|".join(_COLLECTIVES) + r")\b")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for m in _SHAPE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(dtype, dims)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


# --- TPU v5e hardware constants (per chip) -----------------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   collective_bytes_per_device: float) -> dict:
    """The three per-device roofline terms, in seconds."""
    t_compute = flops_per_device / PEAK_FLOPS_BF16
    t_memory = hbm_bytes_per_device / HBM_BW
    t_collective = collective_bytes_per_device / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


__all__ = ["collective_stats", "CollectiveStats", "roofline_terms",
           "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW"]
