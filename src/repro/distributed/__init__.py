from repro.distributed.sharding import (
    batch_sharding,
    cache_sharding,
    param_sharding,
    shard_params,
)

__all__ = ["param_sharding", "batch_sharding", "cache_sharding", "shard_params"]
