"""Minimal, shard-transparent AdamW + cosine schedule (no external deps).

Optimizer state is a pytree congruent with params, so the same NamedShardings
apply leaf-for-leaf -- m/v inherit the param's layout (a ZeRO-2-like layout
falls out of the hillclimb pass by re-sharding these trees on the data axis).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr", "global_norm"]
