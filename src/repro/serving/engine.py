"""Single-replica serving engine: paged-KV continuous batcher over
prefill/decode step functions, with straggler mitigation hooks.

This is the per-replica substrate the elastic layer (repro.core.elastic)
scales in and out.  Requests are classed by (prefill_len, decode_len) --
the LLM analogue of the paper's tweet classes -- and the engine reports the
application-level signals (queue depth, in-flight count, output score stream)
that drive the paper's auto-scaling policies.  ``Request.score`` is the
*real* application-output signal: the running mean log-probability of the
tokens the model actually generated, fed to the control plane's
``output_score`` channel by the serve driver.

Serving path (attention families; see DESIGN.md "The serving stack"):

* **paged KV cache** (`repro.serving.kvcache`) -- pages allocated at
  prefill, appended as decode crosses page boundaries, freed on completion;
* **bucketed prefill** -- prompts are padded to their ``request_class``
  power-of-two bucket and the true last position is selected with a traced
  index, so jit retraces are bounded by the number of distinct buckets,
  not the number of distinct prompt lengths;
* **active-slot decode** -- one batched heterogeneous-position decode over
  the *active* slots only, compacted and padded to a power-of-two batch
  (idle slots cost nothing; trace count is bounded by log2(max_batch)+1).

Families without a paged decode path (ssm/hybrid, audio/encdec) fall back
to the legacy dense tree cache, which batch-decodes every slot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.serving.kvcache import PagedKVCache


def _bucket(n: int) -> int:
    """Power-of-two length bucket, floor 16."""
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 4)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival_s: float = 0.0
    # filled by the engine
    first_token_s: float | None = None
    done_s: float | None = None
    output: list = field(default_factory=list)
    score: float = 0.0                 # running mean logprob of emitted tokens

    @property
    def request_class(self) -> tuple[int, int]:
        """(prefill bucket, decode bucket) -- the service-demand class."""
        return _bucket(len(self.prompt)), _bucket(self.max_new_tokens)


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 1024
    eos_token: int = -1                # -1: run to max_new_tokens
    greedy: bool = True
    paged: bool = True                 # paged KV cache (attention families)
    page_size: int = 16
    num_pages: int | None = None       # default: max_batch*(max_len/ps) + trash


class ServingEngine:
    """Synchronous continuous batcher (slot-based).

    One decode step advances every *active* slot; finished slots release
    their pages and are refilled from the queue with a fresh bucketed
    prefill.  This mirrors production continuous batching while staying
    simple enough to run under interpret-mode tests.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}       # slot -> request
        # dynamic cap on concurrently active slots (<= cfg.max_batch): the unit
        # of elasticity the scaling control plane actuates on this engine
        self.slot_limit: int = cfg.max_batch
        self.pos = np.zeros(cfg.max_batch, dtype=np.int32)
        self.remaining = np.zeros(cfg.max_batch, dtype=np.int32)
        self.completed: list[Request] = []
        self.step_count = 0
        self.paged = cfg.paged and model.supports_paged
        if self.paged:
            self.kv = PagedKVCache(model.init_cache, max_batch=cfg.max_batch,
                                   max_len=cfg.max_len, page_size=cfg.page_size,
                                   num_pages=cfg.num_pages)
            self._prefill_jit = jax.jit(self._paged_prefill_fn)
            self._decode_jit = jax.jit(self._paged_decode_fn)
        else:
            self.kv = None
            self.cache = None                      # dense tree cache, lazy init
            self._prefill_jit = jax.jit(self._dense_prefill_fn)
            self._decode_jit = jax.jit(self._dense_decode_fn)

    # -- jitted step functions ----------------------------------------------------
    # (bound methods: `self` is closed over, only array args are traced)

    def _paged_prefill_fn(self, params, pages, toks, last_idx, page_ids):
        """Bucketed prefill: toks (1, pb) zero-padded; retraces once per
        distinct bucket pb.  Scatters the prompt's KV into its pages (bucket
        overhang lands in the trash page) and returns the greedy first token
        with its logprob."""
        from repro.serving.kvcache import write_prefill_pages
        logits, cache1 = self.model.prefill(
            params, {"tokens": toks}, max_len=int(toks.shape[1]),
            last_idx=last_idx)
        lp = jax.nn.log_softmax(logits[0, -1])
        tok = jnp.argmax(lp)
        pages = write_prefill_pages(pages, cache1, page_ids)
        return tok, lp[tok], pages

    def _paged_decode_fn(self, params, pages, toks, pos, tbl):
        """One decode for a compacted active-slot batch (padding rows carry
        the trash-page table and write/attend harmlessly)."""
        logits, pages = self.model.decode_step(params, pages, toks, pos,
                                               block_table=tbl)
        lp = jax.nn.log_softmax(logits[:, 0], axis=-1)
        tok = jnp.argmax(lp, axis=-1)
        return tok, jnp.take_along_axis(lp, tok[:, None], axis=1)[:, 0], pages

    def _dense_prefill_fn(self, params, batch):
        logits, cache1 = self.model.prefill(params, batch,
                                            max_len=self.cfg.max_len)
        lp = jax.nn.log_softmax(logits[0, -1])
        tok = jnp.argmax(lp)
        return tok, lp[tok], cache1

    def _dense_decode_fn(self, params, cache, toks, pos):
        logits, cache = self.model.decode_step(params, cache, toks, pos)
        lp = jax.nn.log_softmax(logits[:, 0], axis=-1)
        tok = jnp.argmax(lp, axis=-1)
        return tok, jnp.take_along_axis(lp, tok[:, None], axis=1)[:, 0], cache

    # -- queue interface ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + max(req.max_new_tokens, 1) - 1
        if total > self.cfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens needs {total} cache slots "
                f"> max_len {self.cfg.max_len}")
        if self.paged and self.kv.pages_needed(total) > self.kv.num_pages - 1:
            raise ValueError(
                f"request {req.rid} needs more pages than the pool holds")
        self.queue.append(req)

    @property
    def n_in_system(self) -> int:
        return len(self.queue) + len(self.active)

    @property
    def prefill_trace_count(self) -> int:
        """Compiled prefill variants -- bounded by the distinct buckets seen."""
        return int(self._prefill_jit._cache_size())

    @property
    def decode_trace_count(self) -> int:
        """Compiled decode variants -- bounded by ceil(log2(max_batch))+1
        (paged: one per power-of-two active-batch size)."""
        return int(self._decode_jit._cache_size())

    # -- slot lifecycle -----------------------------------------------------------
    def _reset_slot(self, slot: int) -> None:
        """Free a slot's cache state when it empties (completion, eviction,
        or reclaim of a force-popped slot): release its pages and zero the
        per-slot position/budget registers."""
        if self.paged and self.kv.held[slot]:
            self.kv.release(slot)
        self.pos[slot] = 0
        self.remaining[slot] = 0

    def evict(self, slot: int) -> Request:
        """Straggler mitigation: pull the request off its slot, free the
        slot's pages, and re-enqueue from scratch (backup dispatch)."""
        req = self.active.pop(slot)
        self._reset_slot(slot)
        req.output.clear()
        req.score = 0.0
        req.first_token_s = None
        self.submit(req)
        return req

    # -- scheduling ---------------------------------------------------------------
    def _prefill_into(self, slot: int, req: Request, install: bool):
        """Run one bucketed prefill; install the KV into ``slot`` unless the
        request finishes at fill time (install=False skips allocation -- the
        bucket scatter lands entirely in the trash page)."""
        prompt = np.asarray(req.prompt, np.int32)
        plen = len(prompt)
        if self.paged:
            # bucket >= page_size so the padded prompt is a whole number of
            # page chunks (both are powers of two; max_len is page-aligned)
            pb = min(max(_bucket(plen), self.kv.page_size), self.cfg.max_len)
            padded = np.zeros((1, pb), np.int32)
            padded[0, :plen] = prompt
            n_chunks = pb // self.kv.page_size
            if install:
                total = plen + req.max_new_tokens - 1
                page_ids = self.kv.alloc_prefill(slot, plen, total, n_chunks)
            else:
                page_ids = np.zeros(n_chunks, np.int32)
            tok, logp, self.kv.pages = self._prefill_jit(
                self.params, self.kv.pages, jnp.asarray(padded),
                jnp.int32(plen - 1), jnp.asarray(page_ids))
        else:
            tok, logp, cache1 = self._prefill_jit(
                self.params, {"tokens": jnp.asarray(prompt)[None]})
            if install:
                if self.cache is None:
                    self.cache = jax.tree.map(
                        lambda c: jnp.repeat(jnp.zeros_like(c),
                                             self.cfg.max_batch, axis=1),
                        cache1)
                # install the prefilled cache into the slot (batch dim = axis 1)
                self.cache = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                        full, one.astype(full.dtype), slot, axis=1),
                    self.cache, cache1)
        return int(tok), float(logp)

    def _fill_slots(self, now: float) -> int:
        """Refill free slots from the queue; returns the number of requests
        that finished at fill time (max_new_tokens budget spent by the
        prefill token).  Such a request still consumes its slot for this
        step -- the prefill ran there -- so the slot cap bounds prefill work
        exactly like decode work."""
        limit = min(self.slot_limit, self.cfg.max_batch)
        free = [s for s in range(self.cfg.max_batch) if s not in self.active]
        if self.paged:
            # reclaim pages of slots that were force-popped without release()
            for s in free:
                if self.kv.held[s]:
                    self._reset_slot(s)
        fill_done = 0
        while free and self.queue and len(self.active) + fill_done < limit:
            req = self.queue[0]
            if req.max_new_tokens <= 0:
                # nothing to generate: complete without a prefill or a slot
                self.queue.pop(0)
                req.done_s = now
                self.completed.append(req)
                continue
            install = req.max_new_tokens > 1
            if self.paged and install and not self.kv.can_admit(
                    len(req.prompt) + req.max_new_tokens - 1):
                break        # defer admission until completions free pages
            self.queue.pop(0)
            slot = free.pop(0)
            tok, logp = self._prefill_into(slot, req, install)
            req.output.append(tok)
            req.first_token_s = now
            req.score += (logp - req.score) / len(req.output)
            if not install:
                # the prefill token is the whole budget: finish at fill time
                # (a decode here would emit max_new_tokens + 1 tokens)
                req.done_s = now
                self.completed.append(req)
                fill_done += 1
                continue
            self.pos[slot] = len(req.prompt)
            self.remaining[slot] = req.max_new_tokens - 1
            self.active[slot] = req
        return fill_done

    def _finish(self, slot: int, now: float) -> None:
        req = self.active.pop(slot)
        req.done_s = now
        self.completed.append(req)
        self._reset_slot(slot)

    def _decode_active_paged(self, now: float) -> int:
        """One batched heterogeneous-position decode over the active slots
        only, compacted and padded to a power-of-two batch."""
        slots = sorted(self.active)
        n = len(slots)
        na = 1 << max(int(np.ceil(np.log2(n))), 0)
        toks = np.zeros((na, 1), np.int32)
        posv = np.zeros((na,), np.int32)
        tblv = np.zeros((na, self.kv.pages_per_slot), np.int32)
        for i, s in enumerate(slots):
            self.kv.ensure_writable(s, int(self.pos[s]))
            toks[i, 0] = self.active[s].output[-1]
            posv[i] = self.pos[s]
            tblv[i] = self.kv.block_table[s]
        tok, logp, self.kv.pages = self._decode_jit(
            self.params, self.kv.pages, jnp.asarray(toks), jnp.asarray(posv),
            jnp.asarray(tblv))
        tok = np.asarray(tok)
        logp = np.asarray(logp)
        finished = []
        for i, s in enumerate(slots):
            req = self.active[s]
            t = int(tok[i])
            req.output.append(t)
            req.score += (float(logp[i]) - req.score) / len(req.output)
            self.pos[s] += 1
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or t == self.cfg.eos_token:
                finished.append(s)
        for s in finished:
            self._finish(s, now)
        return n

    def _decode_all_dense(self, now: float) -> int:
        """Legacy fallback (no paged cache): batch-decode every slot of the
        dense tree cache -- idle slots compute garbage that is discarded."""
        toks = np.zeros((self.cfg.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.output[-1]
        tok, logp, self.cache = self._decode_jit(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(self.pos))
        tok = np.asarray(tok)
        logp = np.asarray(logp)
        n = len(self.active)
        finished = []
        for slot, req in self.active.items():
            t = int(tok[slot])
            req.output.append(t)
            req.score += (float(logp[slot]) - req.score) / len(req.output)
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or t == self.cfg.eos_token:
                finished.append(slot)
        for slot in finished:
            self._finish(slot, now)
        return n

    def step(self, now: float | None = None) -> int:
        """One engine step: refill + one batched decode over the active
        slots.  Returns the number of slots that served work this step
        (decodes plus fill-time completions)."""
        now = time.monotonic() if now is None else now
        fill_done = self._fill_slots(now)
        if not self.active:
            if fill_done:
                self.step_count += 1
            return fill_done
        served = (self._decode_active_paged(now) if self.paged
                  else self._decode_all_dense(now))
        self.step_count += 1
        return served + fill_done

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                return
            self.step()
        raise RuntimeError("engine failed to drain")


__all__ = ["Request", "ServeConfig", "ServingEngine"]
