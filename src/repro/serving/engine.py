"""Single-replica serving engine: fixed-slot continuous batcher over
prefill/decode step functions, with straggler mitigation hooks.

This is the per-replica substrate the elastic layer (repro.core.elastic)
scales in and out.  Requests are classed by (prefill_len, decode_len) --
the LLM analogue of the paper's tweet classes -- and the engine reports the
application-level signals (queue depth, in-flight count, output score stream)
that drive the paper's auto-scaling policies.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival_s: float = 0.0
    # filled by the engine
    first_token_s: float | None = None
    done_s: float | None = None
    output: list = field(default_factory=list)
    score: float = 0.0                 # application-data signal (e.g. mean logprob)

    @property
    def request_class(self) -> tuple[int, int]:
        """(prefill bucket, decode bucket) -- the service-demand class."""
        pb = 1 << max(int(np.ceil(np.log2(max(len(self.prompt), 1)))), 4)
        db = 1 << max(int(np.ceil(np.log2(max(self.max_new_tokens, 1)))), 4)
        return pb, db


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 1024
    eos_token: int = -1                # -1: run to max_new_tokens
    greedy: bool = True


class ServingEngine:
    """Synchronous continuous batcher (slot-based).

    One decode step advances every active slot; finished slots are refilled
    from the queue with a fresh prefill.  This mirrors production continuous
    batching while staying simple enough to run under interpret-mode tests.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}       # slot -> request
        # dynamic cap on concurrently active slots (<= cfg.max_batch): the unit
        # of elasticity the scaling control plane actuates on this engine
        self.slot_limit: int = cfg.max_batch
        self.pos = np.zeros(cfg.max_batch, dtype=np.int32)
        self.remaining = np.zeros(cfg.max_batch, dtype=np.int32)
        self.cache = None
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=cfg.max_len))
        self.completed: list[Request] = []
        self.step_count = 0

    # -- queue interface ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def n_in_system(self) -> int:
        return len(self.queue) + len(self.active)

    # -- scheduling ---------------------------------------------------------------
    def _fill_slots(self, now: float) -> int:
        """Refill free slots from the queue; returns the number of requests
        that finished at fill time (max_new_tokens budget spent by the
        prefill token).  Such a request still consumes its slot for this
        step -- the prefill ran there -- so the slot cap bounds prefill work
        exactly like decode work."""
        limit = min(self.slot_limit, self.cfg.max_batch)
        free = [s for s in range(self.cfg.max_batch) if s not in self.active]
        fill_done = 0
        while free and self.queue and len(self.active) + fill_done < limit:
            req = self.queue.pop(0)
            if req.max_new_tokens <= 0:
                # nothing to generate: complete without a prefill or a slot
                req.done_s = now
                self.completed.append(req)
                continue
            slot = free.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, cache1 = self._prefill_one(self.params, {"tokens": toks})
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            req.first_token_s = now
            if req.max_new_tokens == 1:
                # the prefill token is the whole budget: finish at fill time
                # (a decode here would emit max_new_tokens + 1 tokens)
                req.done_s = now
                self.completed.append(req)
                fill_done += 1
                continue
            if self.cache is None:
                self.cache = jax.tree.map(
                    lambda c: jnp.repeat(jnp.zeros_like(c), self.cfg.max_batch, axis=1),
                    cache1)
            # install the prefilled cache into the slot (batch dim = axis 1)
            self.cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1),
                self.cache, cache1)
            self.pos[slot] = len(req.prompt)
            self.remaining[slot] = req.max_new_tokens - 1
            self.active[slot] = req
        return fill_done

    def step(self, now: float | None = None) -> int:
        """One engine step: refill + one decode for all active slots.
        Returns the number of slots that served work this step (decodes plus
        fill-time completions)."""
        now = time.monotonic() if now is None else now
        fill_done = self._fill_slots(now)
        if not self.active:
            if fill_done:
                self.step_count += 1
            return fill_done
        # batch decode: positions differ per slot => run per-slot decode at the
        # max pos and mask.  For simplicity (CPU substrate) we decode slot-wise
        # when positions are heterogeneous, batched when uniform.
        toks = np.zeros((self.cfg.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.output[-1]
        # per-slot positions (vector-pos decode: each slot has its own KV length)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(self.pos))
        next_toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        finished = []
        for slot, req in self.active.items():
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or tok == self.cfg.eos_token:
                req.done_s = now
                finished.append(slot)
        for slot in finished:
            self.completed.append(self.active.pop(slot))
        self.step_count += 1
        return len(self.active) + len(finished) + fill_done

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                return
            self.step()
        raise RuntimeError("engine failed to drain")


__all__ = ["Request", "ServeConfig", "ServingEngine"]
