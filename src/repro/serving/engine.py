"""Single-replica serving engine: paged-KV continuous batcher with a
device-resident decode loop, batched bucketed prefill, and straggler
mitigation hooks.

This is the per-replica substrate the elastic layer (repro.core.elastic)
scales in and out.  Requests are classed by (prefill_len, decode_len) --
the LLM analogue of the paper's tweet classes -- and the engine reports the
application-level signals (queue depth, in-flight count, output score stream)
that drive the paper's auto-scaling policies.  ``Request.score`` is the
*real* application-output signal: the running mean log-probability of the
tokens the model actually generated, fed to the control plane's
``output_score`` channel by the serve driver.

Serving path (attention families; see DESIGN.md "Overlapped prefill and
speculative decode"):

* **paged KV cache** (`repro.serving.kvcache`) -- worst-case pages reserved
  at admission, allocated as spans are written, freed on completion;
* **mixed chunked-prefill / speculative decode** (the default) -- queued
  prompts are admitted with NO prefill dispatch: every engine step runs ONE
  jitted ``lax.while_loop`` over the fixed ``max_batch``-wide slot array in
  which each row either streams its next span-sized prompt chunk or
  verifies a drafted token block (n-gram proposer + longest-agreeing-prefix
  acceptance), so a flash crowd of prompts never stalls in-flight decodes
  and accepted drafts emit multiple tokens per model forward.  The fused
  lm-head epilogue (`repro.kernels.sampling`) streams vocab blocks of the
  head weights so no (B, T, V) logits tensor is materialized; rejected
  draft KV positions are rolled back via the page pool (``shrink_to``).
  One compiled variant total: the width is fixed and the step count is a
  traced operand;
* **batched bucketed prefill** (``chunked_prefill=False``) -- queued
  prompts sharing a power-of-two ``request_class`` bucket are coalesced
  into ONE fixed-width prefill call (padding rows scatter into the trash
  page); a partial group waits at most ``bucket_max_wait`` engine steps for
  bucket-mates before flushing, so cold buckets cannot starve;
* **device-resident decode** -- one jitted ``lax.while_loop`` advances the
  compacted active-slot batch up to K steps entirely on device, carrying
  tokens, positions, remaining budgets, eos/finish masks, and running
  logprob-score sums; the host syncs (one ``np.asarray`` round trip, one
  block-table upload) only every K steps or when a slot finishes.

Families without a paged decode path (ssm/hybrid, audio/encdec) fall back
to the legacy dense tree cache, which batch-decodes every slot -- through
the same K-step device loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import autotune
from repro.kernels.sampling.ops import greedy_epilogue
from repro.models.registry import Model
from repro.serving.kvcache import TRASH_PAGE, PagedKVCache
from repro.serving.speculate import make_proposer, prefix_len


def _bucket(n: int) -> int:
    """Power-of-two length bucket, floor 16."""
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 4)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival_s: float = 0.0
    # filled by the engine
    first_token_s: float | None = None
    done_s: float | None = None
    output: list = field(default_factory=list)
    score: float = 0.0                 # running mean logprob of emitted tokens

    @property
    def request_class(self) -> tuple[int, int]:
        """(prefill bucket, decode bucket) -- the service-demand class."""
        return _bucket(len(self.prompt)), _bucket(self.max_new_tokens)


@dataclass(frozen=True)
class MigratedRequest:
    """One in-flight request lifted off a draining replica: the request,
    its decode progress, and its committed KV pages as host arrays (None
    when nothing is committed yet -- the importer replays the prompt)."""

    req: Request
    pos: int                           # committed KV positions on the source
    remaining: int                     # decode budget left (NOT max_new_tokens)
    kv_chunks: object                  # pytree of (L, h, ps, *rest) or None


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 1024
    eos_token: int = -1                # -1: run to max_new_tokens
    greedy: bool = True
    paged: bool = True                 # paged KV cache (attention families)
    page_size: int | None = None       # None: autotuned per-backend default
    num_pages: int | None = None       # default: max_batch*(max_len/ps) + trash
    decode_steps: int = 8              # device-resident steps per host sync
    prefill_batch: int | None = None   # coalesced prefill width (None: max_batch)
    # -- mixed chunked-prefill / speculative decode (paged families) --
    chunked_prefill: bool = True       # fold prefill chunks into the decode loop
    chunk_size: int | None = None      # prefill tokens per mixed step (None: autotune)
    draft_len: int | None = None       # speculative tokens per step (None: autotune;
                                       # 0 disables speculation)
    proposer: str = "ngram"            # draft proposer kind (speculate.make_proposer)
    ngram: int = 2                     # n-gram order for the lookup proposer
    lmhead_block_v: int | None = None  # fused lm-head vocab tile (None: autotune)
    # -- bucketed-prefill path (chunked_prefill=False) --
    bucket_max_wait: int = 4           # engine steps a partial bucket group may
                                       # wait for bucket-mates before flushing


class ServingEngine:
    """Synchronous continuous batcher (slot-based).

    ``step()`` advances every *active* slot by up to ``decode_steps`` tokens
    in one jitted device loop (default 1 -- the control-plane drivers step
    virtual time one token at a time); finished slots release their pages
    and are refilled from the queue with a batched bucketed prefill.
    ``run_until_drained`` runs at the full ``cfg.decode_steps`` sync cadence.
    This mirrors production continuous batching while staying simple enough
    to run under interpret-mode tests.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}       # slot -> request
        # dynamic cap on concurrently active slots (<= cfg.max_batch): the unit
        # of elasticity the scaling control plane actuates on this engine
        self.slot_limit: int = cfg.max_batch
        self.pos = np.zeros(cfg.max_batch, dtype=np.int32)
        self.remaining = np.zeros(cfg.max_batch, dtype=np.int32)
        self.completed: list[Request] = []
        self.step_count = 0
        self.decode_steps = max(int(cfg.decode_steps), 1)
        self.prefill_batch = int(cfg.prefill_batch or cfg.max_batch)
        self._prefill_rows = 0                     # real rows batched-prefilled
        self._prefill_width = 0                    # padded rows dispatched
        self._bucket_stats: dict[int, list] = {}   # bucket -> [rows, width]
        self._bucket_first_wait: dict[int, int] = {}   # bucket -> first defer step
        self._clock = 0                            # ticks every step() call
        self.paged = cfg.paged and model.supports_paged
        self.chunked = (self.paged and cfg.chunked_prefill
                        and model.verify_step is not None)
        if self.chunked:
            chunk = cfg.chunk_size or autotune.default_chunk_size()
            draft = (cfg.draft_len if cfg.draft_len is not None
                     else autotune.default_draft_len())
            self.spec_len = max(int(draft), 0)
            self.span = max(int(chunk), self.spec_len + 1, 1)
            self.lmhead_block_v = (cfg.lmhead_block_v
                                   if cfg.lmhead_block_v is not None
                                   else autotune.default_lmhead_block_v())
            self.proposer = (make_proposer(cfg.proposer, self.span - 1,
                                           ngram=cfg.ngram)
                             if self.span > 1 else None)
            self._mixed_jit = jax.jit(self._mixed_step_fn)
        else:
            self.spec_len = 0
            self.span = 1
            self.proposer = None
            self._mixed_jit = None
        # speculation / interleave stats (bench artifact)
        self._mixed_emitted = 0                    # tokens emitted by mixed loop
        self._mixed_live_iters = 0                 # live-row loop iterations
        if self.paged:
            page_size = cfg.page_size or autotune.default_page_size()
            self.kv = PagedKVCache(model.init_cache, max_batch=cfg.max_batch,
                                   max_len=cfg.max_len, page_size=page_size,
                                   num_pages=cfg.num_pages)
            self._prefill_jit = jax.jit(self._paged_prefill_fn)
            self._decode_jit = jax.jit(self._paged_decode_fn)
        else:
            self.kv = None
            self.cache = None                      # dense tree cache, lazy init
            self._prefill_jit = jax.jit(self._dense_prefill_fn)
            self._decode_jit = jax.jit(self._dense_decode_fn)

    # -- jitted step functions ----------------------------------------------------
    # (bound methods: `self` is closed over, only array args are traced)

    def _paged_prefill_fn(self, params, pages, toks, last_idx, page_ids):
        """Batched bucketed prefill: toks (nb, pb) zero-padded rows sharing
        one bucket pb; retraces once per distinct bucket (nb is the fixed
        ``prefill_batch`` width).  Scatters each prompt's KV into its pages
        (bucket overhang and padding rows land in the trash page) and
        returns each row's greedy first token with its logprob."""
        from repro.serving.kvcache import write_prefill_pages
        logits, cache = self.model.prefill(
            params, {"tokens": toks}, max_len=int(toks.shape[1]),
            last_idx=last_idx)
        tok, lp = greedy_epilogue(logits[:, 0],
                                  use_kernel=self.model.use_kernel)
        pages = write_prefill_pages(pages, cache, page_ids)
        return tok, lp, pages

    def _decode_loop(self, params, kv, toks, pos, rem, live, n_steps, step_fn):
        """Up to ``n_steps`` greedy decode steps entirely on device.

        Carried state: KV storage, last tokens (na, 1), per-row positions /
        remaining budgets, the live mask (rows park when their budget runs
        out or they emit eos -- their KV writes keep landing in pages they
        still own, harmlessly), the emitted-token buffer, and running
        logprob sums.  ``n_steps`` is a traced operand, so K=1 control-plane
        steps and K=decode_steps drain bursts share one compiled loop per
        power-of-two batch size; the loop exits early once every row parks.
        """
        K = self.decode_steps
        na = toks.shape[0]
        eos = int(self.cfg.eos_token)
        carry = dict(
            i=jnp.int32(0), kv=kv, toks=toks, pos=pos, rem=rem, live=live,
            out_toks=jnp.full((na, K), -1, jnp.int32),
            lp_sum=jnp.zeros((na,), jnp.float32),
            n_emit=jnp.zeros((na,), jnp.int32),
        )

        def cond(c):
            return (c["i"] < n_steps) & jnp.any(c["live"])

        def body(c):
            logits, kv = step_fn(params, c["kv"], c["toks"], c["pos"])
            tok, lp = greedy_epilogue(logits[:, 0],
                                      use_kernel=self.model.use_kernel)
            live = c["live"]
            emit = jnp.where(live, tok, -1)
            out_toks = jax.lax.dynamic_update_slice(
                c["out_toks"], emit[:, None], (jnp.int32(0), c["i"]))
            inc = live.astype(jnp.int32)
            rem = c["rem"] - inc
            nxt = jnp.where(live, tok, c["toks"][:, 0])[:, None]
            live = live & (rem > 0)
            if eos >= 0:
                live = live & (tok != eos)
            return dict(i=c["i"] + 1, kv=kv, toks=nxt, pos=c["pos"] + inc,
                        rem=rem, live=live, out_toks=out_toks,
                        lp_sum=c["lp_sum"] + jnp.where(c["live"], lp, 0.0),
                        n_emit=c["n_emit"] + inc)

        c = jax.lax.while_loop(cond, body, carry)
        return (c["kv"], c["out_toks"], c["lp_sum"], c["n_emit"], c["pos"],
                c["rem"], c["i"])

    def _paged_decode_fn(self, params, pages, toks, pos, rem, live, tbl,
                         n_steps):
        """K-step device loop for a compacted active-slot batch (padding
        rows carry the trash-page table and write/attend harmlessly)."""
        return self._decode_loop(
            params, pages, toks, pos, rem, live, n_steps,
            lambda p, kv, tk, ps: self.model.decode_step(p, kv, tk, ps,
                                                         block_table=tbl))

    def _mixed_step_fn(self, params, pages, hist, ell, pos, rem, live, tbl,
                       n_steps):
        """Up to ``n_steps`` mixed chunked-prefill / speculative-decode steps
        entirely on device: ONE kernel invocation per step serves every row,
        whatever phase it is in.

        Per-row state is the committed token history ``hist`` (prompt +
        emitted; garbage past ``ell``) and the committed-KV count ``pos``.
        Each iteration builds a T-token block per row: block position j
        carries ``hist[pos + j]`` where known (a *prefill chunk*) and a
        proposer draft where not (*speculation*); the invariant
        ``pos <= ell - 1`` makes position 0 always known.  One
        ``verify_step`` scores the whole batch; position j's context is
        correct iff every earlier block token was known or agreed with the
        verifier, so the longest such prefix (``raw_valid``) is committed KV
        and the verifier outputs at committed positions past ``ell - 1``
        are emitted -- capped by the draft budget, the remaining token
        budget, and eos.  A decode row (pos == ell-1) reduces to verify
        last-token + drafts (always >= 1 token out); a mid-prompt row
        commits a chunk and emits nothing; the final chunk emits its first
        tokens in the same invocation that commits it -- no mode flag, no
        separate prefill dispatch, so a flash crowd of prompts never stalls
        in-flight decodes.
        """
        K = self.decode_steps
        T = self.span
        na, H = hist.shape
        eos = int(self.cfg.eos_token)
        cap = min(T, 1 + self.spec_len)    # emitted tokens per row per step
        OUT = K * cap
        jr = jnp.arange(T)
        rows = jnp.arange(na)
        verify = self.model.verify_step

        carry = dict(
            i=jnp.int32(0), kv=pages, hist=hist, ell=ell, pos=pos, rem=rem,
            live=live,
            out_toks=jnp.full((na, OUT), -1, jnp.int32),
            lp_sum=jnp.zeros((na,), jnp.float32),
            n_emit=jnp.zeros((na,), jnp.int32),
            live_iters=jnp.int32(0),
        )

        def cond(c):
            return (c["i"] < n_steps) & jnp.any(c["live"])

        def body(c):
            hist, ell, pos, live = c["hist"], c["ell"], c["pos"], c["live"]
            idx = pos[:, None] + jr[None, :]                  # (na, T)
            known = idx < ell[:, None]
            u = jnp.take_along_axis(hist, jnp.clip(idx, 0, H - 1), axis=1)
            if T > 1:
                drafts = self.proposer(hist, ell)             # (na, T-1)
                didx = jnp.clip(idx - ell[:, None], 0, T - 2)
                u = jnp.where(known, u,
                              jnp.take_along_axis(drafts, didx, axis=1))
            tok, lp, kv = verify(params, c["kv"], u, pos, block_table=tbl,
                                 lmhead_block_v=self.lmhead_block_v)
            # acceptance: block position j is in-sequence iff known, or its
            # token equals the verifier's output after position j-1 (chained
            # through the prefix rule); position 0 is known by invariant
            if T > 1:
                prev_ok = jnp.concatenate(
                    [jnp.ones((na, 1), bool), u[:, 1:] == tok[:, :-1]], axis=1)
                raw_valid = prefix_len(known | prev_ok)       # (na,) >= 1
            else:
                raw_valid = jnp.ones((na,), jnp.int32)
            # emission: verifier outputs at committed positions >= ell-1,
            # capped by draft budget, token budget, and (emitted) eos
            krank = jr[None, :] - (ell - 1 - pos)[:, None]    # emission rank
            cand = ((krank >= 0) & (jr[None, :] < raw_valid[:, None])
                    & (krank < jnp.minimum(c["rem"], cap)[:, None])
                    & live[:, None])
            if eos >= 0:
                eos_hit = cand & (tok == eos)
                emit = cand & (jnp.cumsum(eos_hit, axis=1) - eos_hit == 0)
                ate_eos = (eos_hit & emit).any(axis=1)
            else:
                emit = cand
                ate_eos = jnp.zeros((na,), bool)
            n_new = emit.sum(axis=1).astype(jnp.int32)
            # extend hist with the emitted tokens (flat scatter, OOB drops)
            col = ell[:, None] + krank
            hidx = jnp.where(emit, rows[:, None] * H + jnp.clip(col, 0, H - 1),
                             na * H)
            hist = (hist.reshape(-1)
                    .at[hidx.reshape(-1)].set(tok.reshape(-1), mode="drop")
                    .reshape(na, H))
            ocol = c["n_emit"][:, None] + krank
            oidx = jnp.where(emit,
                             rows[:, None] * OUT + jnp.clip(ocol, 0, OUT - 1),
                             na * OUT)
            out_toks = (c["out_toks"].reshape(-1)
                        .at[oidx.reshape(-1)].set(tok.reshape(-1), mode="drop")
                        .reshape(na, OUT))
            ell_n = ell + n_new
            # committed KV advances by the accepted prefix but never past the
            # last committed token: accepted-but-unemitted drafts roll back
            # (their page-pool writes are re-verified -- rewritten at the
            # same logical positions -- before any mask lets them be read)
            pos_n = jnp.where(live,
                              jnp.minimum(pos + raw_valid, ell_n - 1), pos)
            rem_n = c["rem"] - n_new
            live_n = live & (rem_n > 0) & ~ate_eos
            return dict(
                i=c["i"] + 1, kv=kv, hist=hist, ell=ell_n, pos=pos_n,
                rem=rem_n, live=live_n, out_toks=out_toks,
                lp_sum=c["lp_sum"] + (lp * emit).sum(axis=1),
                n_emit=c["n_emit"] + n_new,
                live_iters=c["live_iters"] + live.sum().astype(jnp.int32),
            )

        c = jax.lax.while_loop(cond, body, carry)
        return (c["kv"], c["out_toks"], c["lp_sum"], c["n_emit"], c["pos"],
                c["rem"], c["i"], c["live_iters"])

    def _dense_prefill_fn(self, params, batch):
        logits, cache1 = self.model.prefill(params, batch,
                                            max_len=self.cfg.max_len)
        tok, lp = greedy_epilogue(logits[:, -1],
                                  use_kernel=self.model.use_kernel)
        return tok[0], lp[0], cache1

    def _dense_decode_fn(self, params, cache, toks, pos, rem, live, n_steps):
        """K-step device loop over the full dense tree cache -- idle slots
        compute garbage that the live mask discards."""
        return self._decode_loop(
            params, cache, toks, pos, rem, live, n_steps,
            lambda p, kv, tk, ps: self.model.decode_step(p, kv, tk, ps))

    # -- queue interface ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + max(req.max_new_tokens, 1) - 1
        if total > self.cfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens needs {total} cache slots "
                f"> max_len {self.cfg.max_len}")
        if self.paged and self.kv.pages_needed(total) > self.kv.num_pages - 1:
            raise ValueError(
                f"request {req.rid} needs more pages than the pool holds")
        self.queue.append(req)

    @property
    def n_in_system(self) -> int:
        return len(self.queue) + len(self.active)

    @property
    def prefill_trace_count(self) -> int:
        """Compiled prefill variants -- bounded by the distinct buckets seen
        (the batch dim is the fixed ``prefill_batch`` width)."""
        return int(self._prefill_jit._cache_size())

    @property
    def decode_trace_count(self) -> int:
        """Compiled decode variants -- bounded by ceil(log2(max_batch))+1
        (paged: one per power-of-two active-batch size; the K-step loop
        takes its step count as a traced operand)."""
        return int(self._decode_jit._cache_size())

    @property
    def mixed_trace_count(self) -> int:
        """Compiled mixed-step variants -- exactly 1 after warmup (the loop
        runs at the fixed ``max_batch`` width with the step count traced)."""
        return int(self._mixed_jit._cache_size()) if self.chunked else 0

    @property
    def prefill_occupancy(self) -> float:
        """Real rows per dispatched prefill row (1.0 = no padding waste)."""
        return self._prefill_rows / max(self._prefill_width, 1)

    @property
    def bucket_occupancy(self) -> dict[int, float]:
        """Per-bucket prefill occupancy (bucketed path only; the chunked
        path has no padded prefill rows to waste)."""
        return {pb: rows / max(width, 1)
                for pb, (rows, width) in sorted(self._bucket_stats.items())}

    @property
    def speculation_stats(self) -> dict[str, float]:
        """Mixed-loop throughput counters: tokens emitted, live-row loop
        iterations, and their ratio (tokens per row-step; > 1 means
        speculation is beating one-token-per-step decode)."""
        return {
            "emitted": float(self._mixed_emitted),
            "live_iters": float(self._mixed_live_iters),
            "tokens_per_row_step": (self._mixed_emitted
                                    / max(self._mixed_live_iters, 1)),
        }

    # -- slot lifecycle -----------------------------------------------------------
    def _reset_slot(self, slot: int) -> None:
        """Free a slot's cache state when it empties (completion, eviction,
        or reclaim of a force-popped slot): release its pages and drop its
        reservation (a chunked slot may hold a reservation before its first
        page), then zero the per-slot position/budget registers."""
        if self.paged and (self.kv.held[slot] or self.kv.worst[slot]):
            self.kv.release(slot)
        self.pos[slot] = 0
        self.remaining[slot] = 0

    def evict(self, slot: int) -> Request:
        """Straggler mitigation: pull the request off its slot, free the
        slot's pages, and re-enqueue from scratch (backup dispatch)."""
        req = self.active.pop(slot)
        self._reset_slot(slot)
        req.output.clear()
        req.score = 0.0
        req.first_token_s = None
        self.submit(req)
        return req

    # -- migration (fleet drain path; see repro.serving.fleet) --------------------
    def export_request(self, slot: int) -> MigratedRequest:
        """Lift the in-flight request off ``slot`` for migration: copy its
        committed KV pages to host arrays, free the slot, and return
        everything :meth:`import_request` needs to resume it elsewhere
        bit-identically.  Call only at a step boundary (host ``pos``/
        ``remaining`` are synced then).  Chunked paged engines only -- the
        mixed loop rebuilds history from prompt + output, so per-row state
        transfers without a dense cache copy."""
        if not self.chunked:
            raise RuntimeError("migration requires the chunked paged engine")
        req = self.active.pop(slot)
        pos = int(self.pos[slot])
        chunks = self.kv.export_slot(slot) if pos > 0 else None
        m = MigratedRequest(req=req, pos=pos,
                            remaining=int(self.remaining[slot]),
                            kv_chunks=chunks)
        self._reset_slot(slot)
        return m

    def can_import(self) -> bool:
        """True if a migrated request could be admitted right now (free slot
        under the cap; page admission is checked per request at import)."""
        return (len(self.active) < min(self.slot_limit, self.cfg.max_batch)
                and len(self.active) < self.cfg.max_batch)

    def import_request(self, m: MigratedRequest) -> int:
        """Re-admit a migrated request with its committed KV installed.

        The decode budget resumes at the exported ``remaining`` (a plain
        ``submit`` would restart it at ``max_new_tokens`` and over-emit);
        the mixed loop then continues from ``pos`` exactly as the source
        would have -- per-row state is independent of batch composition, so
        the emitted tokens are bit-identical.  Returns the slot."""
        if not self.chunked:
            raise RuntimeError("migration requires the chunked paged engine")
        if not self.can_import():
            raise RuntimeError("no free slot under the cap for import")
        total = len(m.req.prompt) + m.req.max_new_tokens - 1
        if not self.kv.can_admit(total):
            raise RuntimeError("page pool cannot admit the migrated request")
        slot = next(s for s in range(self.cfg.max_batch)
                    if s not in self.active)
        if self.kv.held[slot] or self.kv.worst[slot]:
            self._reset_slot(slot)       # reclaim a force-popped slot's pages
        if m.pos > 0 and m.kv_chunks is not None:
            self.kv.import_slot(slot, m.kv_chunks, total)
        else:
            self.kv.reserve(slot, total)
        self.pos[slot] = m.pos
        self.remaining[slot] = m.remaining
        self.active[slot] = m.req
        return slot

    # -- scheduling ---------------------------------------------------------------
    def _note_prefilled(self, slot: int, req: Request, install: bool,
                        tok: int, logp: float, now: float) -> int:
        """Shared post-prefill bookkeeping (paged and dense paths): record
        the first token and its score; either finish at fill time (the
        prefill token was the whole budget) or install the request into its
        slot.  Returns 1 for a fill-time completion, else 0."""
        req.output.append(tok)
        req.first_token_s = now
        req.score += (logp - req.score) / len(req.output)
        if not install:
            # the prefill token is the whole budget: finish at fill time
            # (a decode here would emit max_new_tokens + 1 tokens)
            req.done_s = now
            self.completed.append(req)
            return 1
        self.pos[slot] = len(req.prompt)
        self.remaining[slot] = req.max_new_tokens - 1
        self.active[slot] = req
        return 0

    def _prefill_group(self, group, pb: int, now: float) -> int:
        """One batched bucketed prefill over ``group`` [(slot, req, install)]
        rows sharing bucket ``pb``; returns the number of fill-time
        completions (single-token budgets spent by the prefill argmax)."""
        width = self.prefill_batch
        n_chunks = pb // self.kv.page_size
        toks = np.zeros((width, pb), np.int32)
        last_idx = np.zeros((width,), np.int32)
        page_ids = np.full((width, n_chunks), TRASH_PAGE, np.int32)
        for j, (slot, req, install) in enumerate(group):
            prompt = np.asarray(req.prompt, np.int32)
            plen = len(prompt)
            toks[j, :plen] = prompt
            last_idx[j] = plen - 1
            if install:
                total = plen + req.max_new_tokens - 1
                page_ids[j] = self.kv.alloc_prefill(slot, plen, total,
                                                    n_chunks)
        tokv, lpv, self.kv.pages = self._prefill_jit(
            self.params, self.kv.pages, jnp.asarray(toks),
            jnp.asarray(last_idx), jnp.asarray(page_ids))
        tokv = np.asarray(tokv)
        lpv = np.asarray(lpv)
        self._prefill_rows += len(group)
        self._prefill_width += width
        stats = self._bucket_stats.setdefault(pb, [0, 0])
        stats[0] += len(group)
        stats[1] += width
        fill_done = 0
        for j, (slot, req, install) in enumerate(group):
            fill_done += self._note_prefilled(slot, req, install,
                                              int(tokv[j]), float(lpv[j]), now)
        return fill_done

    def _dense_prefill_into(self, slot: int, req: Request, install: bool):
        """Legacy dense path: one prefill per request, cache installed into
        the slot's rows of the dense tree cache."""
        prompt = np.asarray(req.prompt, np.int32)
        tok, logp, cache1 = self._prefill_jit(
            self.params, {"tokens": jnp.asarray(prompt)[None]})
        if install:
            if self.cache is None:
                self.cache = jax.tree.map(
                    lambda c: jnp.repeat(jnp.zeros_like(c),
                                         self.cfg.max_batch, axis=1),
                    cache1)
            # install the prefilled cache into the slot (batch dim = axis 1)
            self.cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1),
                self.cache, cache1)
        return int(tok), float(logp)

    def _prefill_bucket(self, req: Request) -> int:
        # bucket >= page_size so the padded prompt is a whole number of
        # page chunks (both are powers of two; max_len is page-aligned)
        return min(max(_bucket(len(req.prompt)), self.kv.page_size),
                   self.cfg.max_len)

    def _fill_slots(self, now: float) -> int:
        """Refill free slots from the queue -- paged: coalescing same-bucket
        head-of-queue prompts into batched prefill calls.  Returns the number
        of requests that finished at fill time (max_new_tokens budget spent
        by the prefill token).  Such a request still consumes its slot for
        this step -- the prefill ran there -- so the slot cap bounds prefill
        work exactly like decode work."""
        limit = min(self.slot_limit, self.cfg.max_batch)
        free = [s for s in range(self.cfg.max_batch) if s not in self.active]
        if self.paged:
            # reclaim pages of slots that were force-popped without release()
            for s in free:
                if self.kv.held[s] or self.kv.worst[s]:
                    self._reset_slot(s)
        fill_done = 0
        while free and self.queue and len(self.active) + fill_done < limit:
            req = self.queue[0]
            if req.max_new_tokens <= 0:
                # nothing to generate: complete without a prefill or a slot
                self.queue.pop(0)
                req.done_s = now
                self.completed.append(req)
                continue
            if not self.paged:
                install = req.max_new_tokens > 1
                self.queue.pop(0)
                slot = free.pop(0)
                tok, logp = self._dense_prefill_into(slot, req, install)
                self._prefill_rows += 1            # dense fills one at a time
                self._prefill_width += 1
                fill_done += self._note_prefilled(slot, req, install,
                                                  tok, logp, now)
                continue
            if self.chunked:
                # chunked admission: no prefill dispatch at all -- reserve
                # the worst-case pages and hand the prompt to the mixed
                # loop, which streams it in span-sized chunks interleaved
                # with every other row's decode
                total = len(req.prompt) + req.max_new_tokens - 1
                if not self.kv.can_admit(total):
                    break                # defer until completions free pages
                self.queue.pop(0)
                slot = free.pop(0)
                self.kv.reserve(slot, total)
                self.pos[slot] = 0
                self.remaining[slot] = req.max_new_tokens
                self.active[slot] = req
                continue
            # paged: collect a same-bucket FIFO group for one batched prefill
            pb = self._prefill_bucket(req)
            group: list[tuple[int, Request, bool]] = []
            planned = 0                  # worst-case pages promised to group
            blocked = False
            while (self.queue and free and len(group) < self.prefill_batch
                   and len(self.active) + fill_done + len(group) < limit):
                r = self.queue[0]
                if r.max_new_tokens <= 0:
                    self.queue.pop(0)
                    r.done_s = now
                    self.completed.append(r)
                    continue
                if self._prefill_bucket(r) != pb:
                    break                # next bucket fills in the next group
                install = r.max_new_tokens > 1
                total = len(r.prompt) + r.max_new_tokens - 1
                if install and not self.kv.can_admit(total, planned):
                    blocked = True       # defer until completions free pages
                    break
                if install:
                    planned += self.kv.pages_needed(total)
                self.queue.pop(0)
                group.append((free.pop(0), r, install))
            if not group:
                break                    # head of queue blocked on pages
            full = (len(group) >= self.prefill_batch or not free
                    or len(self.active) + fill_done + len(group) >= limit)
            if (not full and not blocked and self.cfg.bucket_max_wait > 0
                    and (self.active or fill_done)):
                # partial group while the engine has other work: wait for
                # bucket-mates to raise occupancy -- but never beyond
                # ``bucket_max_wait`` engine steps, so a lone request in a
                # cold bucket cannot starve behind a busy decode batch
                first = self._bucket_first_wait.setdefault(pb, self._clock)
                if self._clock - first < self.cfg.bucket_max_wait:
                    for slot, r, _ in reversed(group):
                        free.insert(0, slot)
                        self.queue.insert(0, r)
                    break
            self._bucket_first_wait.pop(pb, None)
            fill_done += self._prefill_group(group, pb, now)
            if blocked:
                break
        return fill_done

    def _finish(self, slot: int, now: float) -> None:
        req = self.active.pop(slot)
        req.done_s = now
        self.completed.append(req)
        self._reset_slot(slot)

    def _apply_decode_outputs(self, rows, out_toks, lp_sum, n_emit, pos_out,
                              rem_out, now: float) -> None:
        """Fold one device-loop sync back into host bookkeeping.

        ``rows``: [(batch row, slot)] -- compacted index order for the paged
        path, identity (slot == row) for the dense path."""
        out_toks = np.asarray(out_toks)
        lp_sum = np.asarray(lp_sum)
        n_emit = np.asarray(n_emit)
        pos_out = np.asarray(pos_out)
        rem_out = np.asarray(rem_out)
        finished = []
        for i, s in rows:
            # position/budget always advance (a mixed-loop row can commit
            # prefill chunks without emitting a single token)
            self.pos[s] = int(pos_out[i])
            self.remaining[s] = int(rem_out[i])
            ne = int(n_emit[i])
            if ne == 0:
                continue
            req = self.active[s]
            prev = len(req.output)
            if prev == 0:
                req.first_token_s = now
            req.output.extend(int(t) for t in out_toks[i, :ne])
            req.score = (req.score * prev + float(lp_sum[i])) / (prev + ne)
            if rem_out[i] <= 0 or req.output[-1] == self.cfg.eos_token:
                finished.append(s)
        for s in finished:
            self._finish(s, now)

    def _decode_active_paged(self, now: float, k: int = 1) -> tuple[int, int]:
        """Up to ``k`` batched heterogeneous-position decode steps over the
        active slots only, compacted and padded to a power-of-two batch, in
        one device loop.  Returns (slots served, device steps executed)."""
        slots = sorted(self.active)
        n = len(slots)
        if n == 0:
            return 0, 0                  # guard: np.log2(0) and an empty jit
        na = 1 << max(int(np.ceil(np.log2(n))), 0)
        toks = np.zeros((na, 1), np.int32)
        posv = np.zeros((na,), np.int32)
        remv = np.zeros((na,), np.int32)
        livev = np.zeros((na,), bool)
        tblv = np.zeros((na, self.kv.pages_per_slot), np.int32)
        for i, s in enumerate(slots):
            # pre-allocate every page the next k on-device writes may touch
            span = min(k, int(self.remaining[s]))
            self.kv.ensure_writable_span(s, int(self.pos[s]), max(span, 1))
            toks[i, 0] = self.active[s].output[-1]
            posv[i] = self.pos[s]
            remv[i] = self.remaining[s]
            livev[i] = True
            tblv[i] = self.kv.block_table[s]
        self.kv.pages, out_toks, lp_sum, n_emit, pos_out, rem_out, iters = \
            self._decode_jit(self.params, self.kv.pages, jnp.asarray(toks),
                             jnp.asarray(posv), jnp.asarray(remv),
                             jnp.asarray(livev), jnp.asarray(tblv),
                             jnp.int32(k))
        self._apply_decode_outputs(list(enumerate(slots)), out_toks, lp_sum,
                                   n_emit, pos_out, rem_out, now)
        return n, int(iters)

    def _decode_active_mixed(self, now: float, k: int = 1) -> tuple[int, int]:
        """Up to ``k`` mixed chunked-prefill / speculative steps over the
        active slots in one device loop.  The batch is the full fixed
        ``max_batch`` width (dead rows carry the trash table), so exactly
        ONE compiled variant serves every slot mix -- no per-population
        retraces on the hot path.  Returns (slots served, loop iterations).
        """
        slots = sorted(self.active)
        n = len(slots)
        if n == 0:
            return 0, 0
        na = self.cfg.max_batch
        T = self.span
        H = self.cfg.max_len + 1           # prompt + every emitted token
        hist = np.zeros((na, H), np.int32)
        ellv = np.zeros((na,), np.int32)
        posv = np.zeros((na,), np.int32)
        remv = np.zeros((na,), np.int32)
        livev = np.zeros((na,), bool)
        tblv = np.zeros((na, self.kv.pages_per_slot), np.int32)
        for i, s in enumerate(slots):
            req = self.active[s]
            plen = len(req.prompt)
            hist[i, :plen] = req.prompt
            if req.output:
                hist[i, plen:plen + len(req.output)] = req.output
            ellv[i] = plen + len(req.output)
            total = plen + req.max_new_tokens - 1
            # pre-allocate every page the next k on-device spans may write;
            # writes past ``total`` hit TRASH table entries harmlessly, so
            # the span never outgrows the admission reservation
            span = min(k * T, total - int(self.pos[s]))
            self.kv.ensure_writable_span(s, int(self.pos[s]), max(span, 1))
            posv[i] = self.pos[s]
            remv[i] = self.remaining[s]
            livev[i] = True
            tblv[i] = self.kv.block_table[s]
        (self.kv.pages, out_toks, lp_sum, n_emit, pos_out, rem_out, iters,
         live_iters) = self._mixed_jit(
            self.params, self.kv.pages, jnp.asarray(hist), jnp.asarray(ellv),
            jnp.asarray(posv), jnp.asarray(remv), jnp.asarray(livev),
            jnp.asarray(tblv), jnp.int32(k))
        self._apply_decode_outputs(list(enumerate(slots)), out_toks, lp_sum,
                                   n_emit, pos_out, rem_out, now)
        self._mixed_emitted += int(np.asarray(n_emit).sum())
        self._mixed_live_iters += int(live_iters)
        # KV rollback: hand back pages that only ever held rejected
        # speculative writes (the next span re-appends them if accepted)
        for s in slots:
            if s in self.active:
                self.kv.shrink_to(s, max(int(self.pos[s]), 1))
        return n, int(iters)

    def _decode_all_dense(self, now: float, k: int = 1) -> tuple[int, int]:
        """Legacy fallback (no paged cache): batch-decode every slot of the
        dense tree cache -- idle slots compute garbage that is discarded.
        Returns (slots served, device steps executed)."""
        slots = sorted(self.active)
        if not slots:
            return 0, 0                  # guard: empty active set
        toks = np.zeros((self.cfg.max_batch, 1), np.int32)
        livev = np.zeros((self.cfg.max_batch,), bool)
        for slot, req in self.active.items():
            toks[slot, 0] = req.output[-1]
            livev[slot] = True
        self.cache, out_toks, lp_sum, n_emit, pos_out, rem_out, iters = \
            self._decode_jit(self.params, self.cache, jnp.asarray(toks),
                             jnp.asarray(self.pos), jnp.asarray(self.remaining),
                             jnp.asarray(livev), jnp.int32(k))
        self._apply_decode_outputs([(s, s) for s in slots], out_toks, lp_sum,
                                   n_emit, pos_out, rem_out, now)
        return len(slots), int(iters)

    def step(self, now: float | None = None, *,
             decode_steps: int | None = None) -> int:
        """One engine step: refill + one batched device loop over the active
        slots (``decode_steps`` tokens per slot, default 1).  Returns the
        number of slots that served work this step (decodes plus fill-time
        completions)."""
        now = time.monotonic() if now is None else now
        k = max(int(decode_steps or 1), 1)
        if k > self.decode_steps:
            # the emitted-token carry buffer is cfg.decode_steps wide (a
            # trace-time constant); silently clamping would make a driver's
            # virtual clock drift from what the engine actually served
            raise ValueError(
                f"decode_steps={k} > ServeConfig.decode_steps="
                f"{self.decode_steps}; raise the config to burst this far")
        self._clock += 1
        fill_done = self._fill_slots(now)
        if not self.active:
            if fill_done:
                self.step_count += 1
            return fill_done
        if self.chunked:
            served, iters = self._decode_active_mixed(now, k)
        elif self.paged:
            served, iters = self._decode_active_paged(now, k)
        else:
            served, iters = self._decode_all_dense(now, k)
        self.step_count += max(iters, 1)
        return served + fill_done

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        """Drain queue + active set at the full device-resident sync cadence
        (``cfg.decode_steps`` tokens between host round trips)."""
        for _ in range(max_steps):
            if not self.queue and not self.active:
                return
            self.step(decode_steps=self.decode_steps)
        raise RuntimeError("engine failed to drain")


__all__ = ["MigratedRequest", "Request", "ServeConfig", "ServingEngine"]
