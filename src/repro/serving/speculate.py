"""Speculative multi-token decode: draft proposers + the acceptance rule.

The engine's mixed step verifies a block of ``T`` tokens per row in one
batched forward.  For a decode row the block is ``[committed-last-token,
draft_1, .., draft_{T-1}]``; the verifier's greedy output at position ``j``
is the model's true next token after block position ``j``, so the longest
prefix of drafts that agrees with the shifted verifier output can be
committed at once -- plus one *bonus* token (the verifier's own output at
the last agreeing position), which is why a step always emits at least one
token and greedy speculative decode is token-exact against the
single-token oracle.

Proposers are pure jit-side functions ``(hist, ell) -> (B, d) int32``:

* ``hist``: (B, H) committed token history (prompt + emitted), garbage past
  ``ell``;
* ``ell``: (B,) int32 valid history lengths;
* returns ``d`` draft tokens per row, to be placed *after* ``hist[ell-1]``.

A wrong draft is never incorrect output -- it only wastes verifier FLOPs --
so proposers are free to be cheap and speculative.  :class:`NGramProposer`
is prompt-lookup decoding (match the trailing n-gram against history, copy
what followed); any callable with the same signature plugs in via
``ServeConfig.proposer`` (e.g. a learned draft head closing over its
params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol

import jax.numpy as jnp

ProposerFn = Callable[..., "jnp.ndarray"]


class Proposer(Protocol):
    """Draft proposer protocol: jit-side callable drafting ``draft_len``
    tokens per row from the committed history."""

    draft_len: int

    def __call__(self, hist, ell): ...


# replint: traced -- jitted from the serving engine mixed step
def prefix_len(match):
    """Length of the leading all-True run along the last axis.

    ``match``: (..., T) bool.  This is the acceptance rule: the number of
    block positions committed is the longest prefix where every draft token
    agreed with the verifier (known-history positions count as agreeing by
    construction).
    """
    return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1)


@dataclass(frozen=True)
class NGramProposer:
    """Prompt-lookup decoding: find the latest earlier occurrence of the
    trailing ``ngram`` committed tokens and propose what followed it.

    Falls back to repeating the last committed token when no match exists
    or the matched continuation runs past known history -- for greedy
    decode on loopy sequences the repeat guess is accepted surprisingly
    often, and a rejected guess costs nothing but the verifier FLOPs the
    step was already paying.
    """

    draft_len: int
    ngram: int = 2

    # replint: traced -- jitted from the serving engine mixed step
    def __call__(self, hist, ell):
        B, H = hist.shape
        i = jnp.arange(H)[None, :]                            # candidate end
        last_i = jnp.clip(ell - 1, 0, H - 1)[:, None]         # (B, 1)
        last = jnp.take_along_axis(hist, last_i, axis=1)      # (B, 1)
        match = jnp.ones((B, H), bool)
        for j in range(self.ngram):
            a = jnp.take_along_axis(hist, jnp.clip(i - j, 0, H - 1), axis=1)
            b = jnp.take_along_axis(hist, jnp.clip(last_i - j, 0, H - 1), axis=1)
            match &= (a == b) & (i - j >= 0)
        # the end of the candidate n-gram must precede the trailing one, and
        # a continuation token must exist: i + 1 <= ell - 1
        valid = (i >= self.ngram - 1) & (i <= ell[:, None] - 2)
        m = jnp.where(match & valid, i, -1).max(axis=1)       # (B,), -1 = none
        cont = m[:, None] + 1 + jnp.arange(self.draft_len)[None, :]
        known = (m[:, None] >= 0) & (cont < ell[:, None])
        toks = jnp.take_along_axis(hist, jnp.clip(cont, 0, H - 1), axis=1)
        return jnp.where(known, toks, last)


@dataclass(frozen=True)
class RepeatProposer:
    """Degenerate proposer: repeat the last committed token.  Useful as the
    cheapest baseline and as the fallback body of fancier proposers."""

    draft_len: int

    # replint: traced -- jitted from the serving engine mixed step
    def __call__(self, hist, ell):
        last_i = jnp.clip(ell - 1, 0, hist.shape[1] - 1)[:, None]
        last = jnp.take_along_axis(hist, last_i, axis=1)      # (B, 1)
        return jnp.broadcast_to(last, (hist.shape[0], self.draft_len))


def make_proposer(kind: str, draft_len: int, *, ngram: int = 2) -> Proposer:
    """Proposer registry for config-string construction."""
    if kind == "ngram":
        return NGramProposer(draft_len=draft_len, ngram=ngram)
    if kind == "repeat":
        return RepeatProposer(draft_len=draft_len)
    raise ValueError(f"unknown proposer kind: {kind!r}")


__all__ = ["Proposer", "ProposerFn", "prefix_len",
           "NGramProposer", "RepeatProposer", "make_proposer"]
