"""Paged KV cache: block-table storage + the cache-ops interface decode runs on.

The serving engine's KV memory is a pool of fixed-size *pages* (``page_size``
tokens each), shared by every decode slot.  A slot owns an ordered list of
pages recorded in its block-table row: ``block_table[s, i]`` is the physical
page holding logical positions ``[i*ps, (i+1)*ps)`` of slot ``s``.  Page 0 is
the reserved TRASH page -- it is never allocated, and absorbs the writes of
padding rows and prefill-bucket overhang so every jit shape stays fixed.

Free-list discipline (pinned by tests, documented in DESIGN.md):

* **ownership** -- a non-trash page id is held by at most one slot at a time;
  ``free + held == num_pages - 1`` always;
* **alloc at prefill** -- ``ceil(prompt_len / ps)`` pages; **append** one page
  when decode crosses a page boundary; **free** every page when the slot is
  released (completion, eviction, or reclaim of a force-popped slot);
* **reservation** -- admission reserves the slot's worst-case page count
  (``ceil((prompt_len + max_new - 1) / ps)``), so a mid-decode append can
  never deadlock on an empty pool.

The pure functions (`paged_update`, `paged_gather`, `write_prefill_pages`)
and the small cache-ops classes below are the jit-side interface
:func:`repro.models.lm.block_decode` consumes -- dense and paged storage
behind one ``write / view / mask`` contract.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0


# ---------------------------------------------------------------------------------
# pure jit-side page ops
# ---------------------------------------------------------------------------------

# replint: traced -- jitted from the serving engine
def paged_update(cache, new, block_table, pos):
    """Scatter one new token per batch row into the page pool.

    cache: (P, ps, *rest); new: (B, 1, *rest); block_table: (B, n) int32;
    pos: (B,) logical write positions.  Rows whose table entry is the trash
    page write harmlessly into page 0.
    """
    P, ps = cache.shape[0], cache.shape[1]
    rest = cache.shape[2:]
    B = new.shape[0]
    idx = block_table[jnp.arange(B), pos // ps] * ps + pos % ps      # (B,)
    flat = cache.reshape((P * ps,) + rest)
    flat = flat.at[idx].set(new[:, 0].astype(cache.dtype))
    return flat.reshape(cache.shape)


# replint: traced -- jitted from the serving engine
def paged_update_span(cache, new, block_table, pos):
    """Scatter a span of ``T`` new tokens per batch row into the page pool.

    cache: (P, ps, *rest); new: (B, T, *rest); block_table: (B, n) int32;
    pos: (B,) logical positions of each row's span start -- row b writes
    logical positions [pos[b], pos[b] + T).  This is the mixed chunked-
    prefill / speculative-verify write: positions past a row's allocated
    pages hit TRASH block-table entries and land in page 0; positions past
    the table itself are clamped to the row's last logical slot, whose
    entry is TRASH unless the row is full -- and a full row only overflows
    after it has parked, when its KV is never read again.
    """
    P, ps = cache.shape[0], cache.shape[1]
    rest = cache.shape[2:]
    B, T = new.shape[0], new.shape[1]
    n = block_table.shape[1]
    p = jnp.clip(pos[:, None] + jnp.arange(T)[None, :], 0, n * ps - 1)  # (B, T)
    pages = jnp.take_along_axis(block_table, p // ps, axis=1)           # (B, T)
    idx = pages * ps + p % ps
    flat = cache.reshape((P * ps,) + rest)
    flat = flat.at[idx.reshape(-1)].set(
        new.reshape((B * T,) + rest).astype(cache.dtype))
    return flat.reshape(cache.shape)


# replint: traced -- jitted from the serving engine
def paged_gather(cache, block_table):
    """Reconstruct the dense per-slot view from the page pool.

    cache: (P, ps, *rest); block_table: (B, n) -> (B, n*ps, *rest); entry j of
    row b is logical position j of slot b (table order == logical order).
    """
    P, ps = cache.shape[0], cache.shape[1]
    rest = cache.shape[2:]
    B, n = block_table.shape
    flat = cache.reshape((P * ps,) + rest)
    idx = (block_table[:, :, None] * ps
           + jnp.arange(ps, dtype=block_table.dtype)[None, None, :]).reshape(B, n * ps)
    return flat[idx]


# replint: traced -- jitted from the serving engine
def write_prefill_pages(pages, cache, page_ids):
    """Scatter a batched prefill cache into the pool, page-chunked.

    pages: pytree of (L, P, ps, *rest); cache: matching pytree of
    (L, B, pb, *rest) with pb a multiple of ps; page_ids: (B, pb // ps) int32
    (or (pb // ps,) for B == 1) -- real pages first, trash (0) for the bucket
    overhang past each prompt.  Real page ids are unique across rows (free-
    list ownership); several rows may scatter their overhang into the trash
    page, where any of the duplicate writes may win -- all are garbage.
    """
    ids = jnp.reshape(jnp.asarray(page_ids, jnp.int32), (-1,))

    def scatter(pg, c):
        L, _, ps = pg.shape[:3]
        rest = pg.shape[3:]
        B, nc = c.shape[1], c.shape[2] // ps
        chunks = c.reshape((L, B * nc, ps) + rest).astype(pg.dtype)
        return pg.at[:, ids].set(chunks)

    return jax.tree.map(scatter, pages, cache)


# ---------------------------------------------------------------------------------
# cache-ops: the write / view / mask contract block_decode consumes
# ---------------------------------------------------------------------------------

# replint: traced -- jitted from the serving engine
def _vector_mask(seq_len, pos, window):
    """(B, Sq=1, S) validity mask for per-row positions -- shared by the dense
    vector path and the paged path so their semantics can never diverge."""
    k_pos = jnp.arange(seq_len)
    valid = k_pos[None, :] < pos[:, None] + 1                 # (B, S)
    valid &= jnp.where(window > 0, k_pos[None, :] > pos[:, None] - window, True)
    return valid[:, None, :]


# replint: traced -- jitted from the serving engine
def _span_mask(seq_len, pos, q_len, window):
    """(B, T, S) causal mask for a T-token span starting at per-row ``pos``:
    query j of row b sits at logical position pos[b] + j and attends keys
    k <= pos[b] + j (minus the sliding window, when set).  The T=1 slice is
    exactly :func:`_vector_mask` -- the mixed chunked-prefill / speculative
    path and the single-token decode path can never diverge."""
    k_pos = jnp.arange(seq_len)                               # (S,)
    q_pos = pos[:, None] + jnp.arange(q_len)[None, :]         # (B, T)
    valid = k_pos[None, None, :] <= q_pos[:, :, None]         # (B, T, S)
    valid &= jnp.where(window > 0,
                       k_pos[None, None, :] > q_pos[:, :, None] - window, True)
    return valid


class DenseScalarOps:
    """Uniform-position dense cache: all rows write at the same scalar pos."""

    def write(self, cache, new, pos):
        return jax.lax.dynamic_update_slice(
            cache, new.astype(cache.dtype), (0, pos) + (0,) * (cache.ndim - 2))

    def view(self, cache):
        return cache

    def mask(self, seq_len, pos, window):
        k_pos = jnp.arange(seq_len)
        valid = k_pos < pos + 1
        valid &= jnp.where(window > 0, k_pos > pos - window, True)
        return valid[None, :]                                 # (Sq=1, S)


class DenseVectorOps:
    """Heterogeneous-position dense cache: per-row write positions (B,)."""

    def write(self, cache, new, pos):
        zeros = (0,) * (cache.ndim - 2)
        return jax.vmap(
            lambda cb, nb, pb: jax.lax.dynamic_update_slice(
                cb, nb.astype(cb.dtype), (pb,) + zeros))(cache, new, pos)

    def view(self, cache):
        return cache

    def mask(self, seq_len, pos, window):
        return _vector_mask(seq_len, pos, window)


@dataclass
class PagedOps:
    """Block-table paged cache: pool leaves are (P, ps, *rest), shared by all
    rows; logical order is recovered by gathering in table order."""

    block_table: jax.Array                                    # (B, n) int32

    def write(self, cache, new, pos):
        return paged_update(cache, new, self.block_table, pos)

    def write_span(self, cache, new, pos):
        return paged_update_span(cache, new, self.block_table, pos)

    def view(self, cache):
        return paged_gather(cache, self.block_table)

    def mask(self, seq_len, pos, window):
        return _vector_mask(seq_len, pos, window)

    def span_mask(self, seq_len, pos, q_len, window):
        return _span_mask(seq_len, pos, q_len, window)


# ---------------------------------------------------------------------------------
# the host-side pool
# ---------------------------------------------------------------------------------

class PagedKVCache:
    """Page pool + block tables + free list for one :class:`ServingEngine`.

    ``init_cache_fn(batch, max_len)`` is the model's cache constructor; its
    leaf layout (L, B, S, *rest) is reinterpreted as per-page (L, P, ps, *rest)
    pools, so the same class serves f32/bf16 and int8 (value + scale leaves)
    caches without knowing the schema.
    """

    def __init__(self, init_cache_fn, *, max_batch: int, max_len: int,
                 page_size: int = 16, num_pages: int | None = None):
        if page_size < 1 or page_size & (page_size - 1):
            # power of two: every pow2 prefill bucket >= page_size is then a
            # whole number of page chunks
            raise ValueError(f"page_size={page_size} must be a power of two")
        if max_len % page_size:
            raise ValueError(f"max_len={max_len} not a multiple of "
                             f"page_size={page_size}")
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        # worst case: every slot full, plus the trash page
        self.num_pages = (num_pages if num_pages is not None
                          else max_batch * self.pages_per_slot + 1)
        proto = jax.eval_shape(lambda: init_cache_fn(1, page_size))
        self.pages = jax.tree.map(
            lambda s: jnp.zeros((s.shape[0], self.num_pages) + s.shape[2:],
                                s.dtype), proto)
        self.block_table = np.zeros((max_batch, self.pages_per_slot), np.int32)
        self.held = np.zeros(max_batch, np.int32)         # pages owned per slot
        self.worst = np.zeros(max_batch, np.int32)        # reserved worst case
        self._free: list[int] = list(range(self.num_pages - 1, TRASH_PAGE, -1))
        self._outstanding = 0                             # sum(worst - held)

    # -- accounting -------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return max(math.ceil(n_tokens / self.page_size), 1)

    def can_admit(self, total_tokens: int, planned: int = 0) -> bool:
        """True if the pool can guarantee a request writing ``total_tokens``
        logical positions (prompt + decode appends) will never starve.

        ``planned``: worst-case pages already promised to co-admitted
        requests whose allocation has not executed yet (batched prefill
        collects a group before allocating any of it)."""
        return (self.pages_needed(total_tokens)
                <= self.n_free - self._outstanding - planned)

    # -- lifecycle --------------------------------------------------------------
    def alloc_prefill(self, slot: int, prompt_len: int, total_tokens: int,
                      n_chunks: int) -> np.ndarray:
        """Allocate the prompt's pages for ``slot`` and reserve its worst case.

        Returns the (n_chunks,) int32 page-id vector for the bucketed prefill
        scatter -- real pages first, trash for the bucket overhang.
        """
        n = self.pages_needed(prompt_len)
        worst = max(self.pages_needed(total_tokens), n)
        if n > self.n_free:
            raise RuntimeError("page pool exhausted despite reservation")
        ids = [self._free.pop() for _ in range(n)]
        self.block_table[slot, :n] = ids
        self.held[slot] = n
        self.worst[slot] = worst
        self._outstanding += worst - n
        out = np.full(n_chunks, TRASH_PAGE, np.int32)
        out[:n] = ids
        return out

    def reserve(self, slot: int, total_tokens: int) -> None:
        """Register ``slot``'s worst-case page count without allocating yet.

        Chunked-prefill admission: the slot's pages are appended lazily by
        :meth:`ensure_writable_span` as chunks stream in, but the reservation
        must be on the books from admission so co-admitted requests cannot
        promise away the pages this one will need."""
        worst = self.pages_needed(total_tokens)
        if worst > self.pages_per_slot:
            raise RuntimeError(f"reservation past slot capacity at slot {slot}")
        self._outstanding += worst - int(self.worst[slot])
        self.worst[slot] = worst

    def ensure_writable(self, slot: int, pos: int) -> None:
        """Append a page if the next write at logical ``pos`` crosses into an
        unallocated page (decode-time growth)."""
        self.ensure_writable_span(slot, pos, 1)

    def ensure_writable_span(self, slot: int, pos: int, n: int) -> None:
        """Make logical positions [pos, pos + n) of ``slot`` writable,
        appending pages as needed.

        This is the device-resident decode loop's contract: the host
        pre-allocates every page the next K on-device steps may write, so the
        jitted multi-step loop never has to sync back for a page append.  The
        span is bounded by the slot's remaining token budget, which the
        admission reservation already covers -- pre-allocating it early can
        never starve another slot's reserved append.
        """
        if n <= 0:
            return
        last_page = (pos + n - 1) // self.page_size
        if last_page >= self.pages_per_slot:
            raise RuntimeError(f"span past slot capacity at slot {slot}")
        if self.held[slot] < pos // self.page_size:
            raise RuntimeError(f"non-contiguous page growth at slot {slot}")
        while self.held[slot] <= last_page:
            if not self._free:
                raise RuntimeError("page pool exhausted despite reservation")
            self.block_table[slot, self.held[slot]] = self._free.pop()
            self.held[slot] += 1
            self._outstanding -= 1

    def shrink_to(self, slot: int, n_tokens: int) -> int:
        """Return pages past ``ceil(n_tokens / ps)`` to the free list.

        Speculative-decode rollback: the host pre-allocates pages for the
        worst case (every draft token accepted); after the sync reveals how
        many were actually committed, pages holding only rejected positions
        are handed back and their table entries reset to TRASH.  The freed
        pages re-enter ``_outstanding`` -- the slot's reservation still
        covers them, so a later accept-heavy burst can re-append without
        starving anyone.  Rejected tokens *within* the kept pages are not
        scrubbed: the next verify writes the same logical positions before
        any mask lets them be read.

        Returns the number of pages freed."""
        keep = min(self.pages_needed(n_tokens), int(self.held[slot]))
        freed = int(self.held[slot]) - keep
        if freed <= 0:
            return 0
        for i in range(keep, int(self.held[slot])):
            self._free.append(int(self.block_table[slot, i]))
            self.block_table[slot, i] = TRASH_PAGE
        self.held[slot] = keep
        self._outstanding += freed
        return freed

    def release(self, slot: int) -> None:
        """Return every page ``slot`` holds and drop its reservation."""
        n = int(self.held[slot])
        if n:
            self._free.extend(int(p) for p in self.block_table[slot, :n])
        self._outstanding -= int(self.worst[slot]) - n
        self.block_table[slot] = TRASH_PAGE
        self.held[slot] = 0
        self.worst[slot] = 0

    # -- migration (drain path; see engine.export_request) ----------------------
    def export_slot(self, slot: int):
        """Copy ``slot``'s held pages out of the pool as HOST arrays, in
        logical order: a pytree of (L, h, ps, *rest) leaves with h = pages
        held.  Positions past the slot's committed count inside the last
        page are garbage, exactly as they are on the source after a
        ``shrink_to`` -- the importer rewrites them before any mask lets
        them be read.  Returns None for a slot with no pages yet."""
        h = int(self.held[slot])
        if h == 0:
            return None
        ids = np.asarray(self.block_table[slot, :h])
        return jax.tree.map(lambda pg: np.asarray(pg[:, ids]), self.pages)

    # replint: traced -- write_prefill_pages is jit-side; the eager call here
    # is the cold migration path
    def import_slot(self, slot: int, chunks, total_tokens: int) -> None:
        """Install chunks from :meth:`export_slot` as ``slot``'s committed
        KV: allocate exactly their page count, put the slot's worst-case
        reservation (``total_tokens``) on the books, and scatter the pages
        into the pool in logical order."""
        h = jax.tree.leaves(chunks)[0].shape[1]
        ids = self.alloc_prefill(slot, h * self.page_size, total_tokens, h)
        cache = jax.tree.map(
            lambda c: jnp.asarray(c).reshape(
                (c.shape[0], 1, h * self.page_size) + c.shape[3:]), chunks)
        self.pages = write_prefill_pages(self.pages, cache, ids)

    # -- invariants (tests) -----------------------------------------------------
    def check_invariants(self) -> None:
        owned = [int(p) for s in range(self.block_table.shape[0])
                 for p in self.block_table[s, :self.held[s]]]
        assert TRASH_PAGE not in owned, "trash page allocated to a slot"
        assert len(owned) == len(set(owned)), "page owned by two slots"
        assert len(owned) + self.n_free == self.num_pages - 1, "page leak"
        assert self._outstanding == int((self.worst - self.held).sum())
        assert TRASH_PAGE not in self._free


__all__ = [
    "TRASH_PAGE", "paged_update", "paged_update_span", "paged_gather",
    "write_prefill_pages",
    "DenseScalarOps", "DenseVectorOps", "PagedOps", "PagedKVCache",
]
