"""The replica fleet: an elastic pool of real ServingEngines actuated by the
convergence plane.

This is where the capacity plane's abstract units become live engines.  The
paper's headline economics -- fewer SLA violations at fewer resources --
require scale-up to mean a NEW engine spawned from a checkpoint with a
*measured* provisioning delay, and scale-down to drain without dropping a
token.  Three parts (see DESIGN.md "The replica fleet"):

* :class:`ReplicaPool` -- owns the lifecycle.  ``spawn`` loads the latest
  checkpoint (`repro.checkpoint`), re-places params via
  `repro.core.elastic.remesh.scale_replicas`, builds a
  :class:`~repro.serving.ServingEngine`, and warms it with a probe decode
  (compiling the mixed loop) -- the wall clock of all of that IS the
  provisioning delay the plan prices (`CapacityPlan.calibrate_delay`).
  ``drain`` stops admitting and migrates every in-flight request by
  exporting its committed KV pages + positions
  (:meth:`~repro.serving.ServingEngine.export_request`) and re-admitting on
  a surviving replica -- the emitted tokens are bit-identical to an
  unmigrated run because the mixed loop's per-row state is independent of
  batch composition.  ``kill`` models abrupt unit loss: a dead host's KV
  cannot be exported, so its requests restart from scratch.
* :class:`FleetRouter` -- the front door.  Admission is gated per replica
  (free slot under the cap AND page admission), least-loaded first; with an
  :class:`~repro.core.scaling.capacity.Sla` the queue is served strictest
  deadline first, so the cheapest class (longest deadline) sheds -- waits --
  first under page pressure.  Fleet-aggregated occupancy and queue depth
  feed SignalBus channels so the controller sees application data across
  replicas.
* :class:`FleetExecutor` -- the convergence binding.  ``LaunchUnit`` /
  ``DrainUnit`` / ``ReplaceUnhealthy`` steps actuate the ReplicaPool; the
  CapacityPlan ledger is kept in sync as a side effect, so step timeouts,
  stuck builds (a spawn that raises), and provisioning delays are MEASURED
  at the engine level, not injected.

:class:`FleetBackend` drives it all as a
:class:`~repro.core.scaling.backend.ScalableBackend` (unit = replica) over
the same virtual-time step protocol as `repro.launch.serve.ServeBackend`.
A single-replica fleet is behaviorally identical to the bare engine (pinned
by tests/test_fleet.py).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint import load_checkpoint
from repro.core.elastic.remesh import scale_replicas
from repro.core.scaling import (
    ControllerConfig,
    RunReport,
    ScalingController,
    SignalBus,
    UnitPool,
    make_policy,
)
from repro.serving.engine import (
    MigratedRequest, Request, ServeConfig, ServingEngine,
)

FLEET_POOL = "replica"

#: SignalBus channels a fleet backend records every virtual second
FLEET_CHANNELS = ("output_score", "fleet_occupancy", "fleet_queue_depth")


class Replica:
    """One live ServingEngine plus fleet bookkeeping (identity, health,
    and per-replica warm-throughput counters for the bench)."""

    def __init__(self, rix: int, eng: ServingEngine, spawn_s: float):
        self.rix = rix
        self.eng = eng
        self.spawn_s = spawn_s        # measured provisioning wall time
        self.healthy = True
        self.draining = False
        self.busy_s = 0.0             # wall time spent inside step()
        self.tokens = 0               # tokens THIS replica emitted

    def step(self, now: float, decode_steps: int = 1) -> int:
        t0 = time.perf_counter()
        before = self._emitted()
        served = self.eng.step(now=now, decode_steps=decode_steps)
        self.busy_s += time.perf_counter() - t0
        self.tokens += self._emitted() - before
        return served

    def _emitted(self) -> int:
        return (sum(len(r.output) for r in self.eng.completed)
                + sum(len(r.output) for r in self.eng.active.values()))

    @property
    def free_slots(self) -> int:
        return (min(self.eng.slot_limit, self.eng.cfg.max_batch)
                - len(self.eng.active))

    @property
    def tokens_per_busy_s(self) -> float:
        """This replica's warm throughput over its own stepping wall time --
        on a time-sliced single-core runner this is the per-host rate, so
        the fleet aggregate is the sum across replicas."""
        return self.tokens / max(self.busy_s, 1e-9)


class ReplicaPool:
    """Owns the replica lifecycle: spawn from the checkpoint store, warm,
    drain-with-migration, replace-unhealthy, abrupt kill.

    ``ckpt`` is either a :class:`~repro.checkpoint.CheckpointManager`
    (``latest()`` picks the newest complete checkpoint) or a direct ``.npz``
    path.  ``spawn_fault`` is a test hook: a callable returning True makes
    the next spawn raise -- the executor books it as a measured stuck build.
    """

    def __init__(self, model, ckpt, serve_cfg: ServeConfig, *,
                 model_parallel: int = 1, spawn_fault=None):
        self.model = model
        self.ckpt = ckpt
        self.serve_cfg = serve_cfg
        self.model_parallel = model_parallel
        self.spawn_fault = spawn_fault
        self.serving: list[Replica] = []
        self.provisioning: list[tuple[float, Replica]] = []  # (ready_at, r)
        self.retired: list[Replica] = []
        self.migrated: list[MigratedRequest] = []  # awaiting re-admission
        self._next_rix = 0

    # -- lifecycle --------------------------------------------------------------
    def _ckpt_path(self) -> str:
        if hasattr(self.ckpt, "latest"):
            path = self.ckpt.latest()
            if path is None:
                raise RuntimeError("no complete checkpoint to spawn from")
            return path
        return self.ckpt

    def spawn(self) -> tuple[Replica, float]:
        """Bring up one replica: checkpoint load -> remesh -> engine build ->
        probe decode (compiles the mixed loop so the replica serves warm).
        Returns ``(replica, measured wall seconds)``; raises on failure --
        the caller books that as a stuck build."""
        t0 = time.perf_counter()
        if self.spawn_fault is not None and self.spawn_fault():
            raise RuntimeError("spawn failed (injected)")
        params, _ = load_checkpoint(self._ckpt_path(),
                                    self.model.abstract_params())
        _, params = scale_replicas(params, devices=jax.devices(),
                                   model_parallel=self.model_parallel)
        eng = ServingEngine(self.model, params, self.serve_cfg)
        rix = self._next_rix
        self._next_rix += 1
        # probe decode, two waves through the real serving path: the first
        # call compiles against fresh (uncommitted) page arrays, every later
        # call sees jit-output (committed) pages -- XLA builds a distinct
        # executable for each, so a single wave would leave the steady-state
        # compile to leak into the first real request after activation
        for wave in range(2):
            eng.submit(Request(rid=-1 - rix, prompt=np.ones(4, np.int32),
                               max_new_tokens=2))
            eng.run_until_drained()
        eng.completed.clear()
        rep = Replica(rix, eng, time.perf_counter() - t0)
        return rep, rep.spawn_s

    def activate_to(self, n_live: int) -> None:
        """Plan-led activation: promote provisioning replicas (earliest
        ready first) until ``serving`` matches the plan's live count.  The
        plan's landing clock is the source of truth -- it was calibrated
        from the measured spawn time, so ready order == landing order."""
        self.provisioning.sort(key=lambda e: e[0])
        while len(self.serving) < n_live and self.provisioning:
            _, rep = self.provisioning.pop(0)
            self.serving.append(rep)

    # -- drain / loss -----------------------------------------------------------
    def drain(self, replica: Replica) -> int:
        """Stop admitting on ``replica`` and migrate every in-flight request
        off it: committed KV pages + positions export to a surviving replica
        (or the migrated backlog when none fits right now).  The request
        resumes with its decode budget intact -- not from scratch."""
        replica.draining = True
        self.serving.remove(replica)
        self.retired.append(replica)
        for slot in sorted(replica.eng.active):
            self.place_migrated(replica.eng.export_request(slot))
        for req in replica.eng.queue:     # queued-but-unadmitted: no KV yet
            self.migrated.append(MigratedRequest(
                req=req, pos=0, remaining=req.max_new_tokens, kv_chunks=None))
        replica.eng.queue.clear()
        replica.eng.kv.check_invariants()  # all pages back on the free list
        return 1

    def kill(self, replica: Replica) -> list[Request]:
        """Abrupt unit loss: the host is gone, so in-flight KV cannot be
        exported -- its requests restart from scratch through the migrated
        backlog (progress cleared, same semantics as an eviction)."""
        self.serving.remove(replica)
        self.retired.append(replica)
        lost = []
        for slot in sorted(replica.eng.active):
            req = replica.eng.active.pop(slot)
            req.output.clear()
            req.score = 0.0
            req.first_token_s = None
            lost.append(req)
        lost.extend(replica.eng.queue)
        replica.eng.queue.clear()
        for req in lost:
            self.migrated.append(MigratedRequest(
                req=req, pos=0, remaining=req.max_new_tokens, kv_chunks=None))
        return lost

    def place_migrated(self, m: MigratedRequest) -> bool:
        """Re-admit a migrated request on the most-free surviving replica
        that can take it NOW (slot + pages); otherwise park it in the
        migrated backlog for the router to retry each step."""
        total = len(m.req.prompt) + m.req.max_new_tokens - 1
        for r in sorted(self.serving, key=lambda r: (-r.free_slots, r.rix)):
            if r.draining or not r.healthy:
                continue
            if r.eng.can_import() and r.eng.kv.can_admit(total):
                r.eng.import_request(m)
                return True
        self.migrated.append(m)
        return False

    # -- fleet-wide views -------------------------------------------------------
    @property
    def n_unhealthy(self) -> int:
        return sum(not r.healthy for r in self.serving)

    @property
    def n_in_system(self) -> int:
        return (len(self.migrated)
                + sum(r.eng.n_in_system for r in self.serving))

    def total_slots(self) -> int:
        return sum(min(r.eng.slot_limit, r.eng.cfg.max_batch)
                   for r in self.serving)

    def occupancy(self) -> float:
        return (sum(len(r.eng.active) for r in self.serving)
                / max(self.total_slots(), 1))


def _restartable(m: MigratedRequest) -> bool:
    """True when a migrated entry holds NO decode progress -- a fresh submit
    is exactly equivalent (kill-path restarts and drained queued-but-
    unadmitted requests).  Entries holding committed KV or emitted tokens
    must go through priority re-admission to keep their progress."""
    return (m.pos == 0 and m.kv_chunks is None
            and m.remaining == m.req.max_new_tokens and not m.req.output)


class FleetRouter:
    """SLA-class-aware front door over a :class:`ReplicaPool`.

    Admission order: migrated entries holding decode progress first (their
    committed KV must land on a survivor), then the queue -- FIFO by
    default; with an ``sla``, strictest absolute deadline (arrival + class
    deadline) first, so under page pressure the cheapest class (longest
    deadline) is the one left waiting.  Requests restarting from scratch
    after a ``kill`` hold NO progress, so they re-enter the queue at their
    ORIGINAL deadline (``arrival_s`` survives the kill) -- a crash must not
    launder a cheap class past premium queued work, nor reset the victim's
    own SLA clock.  A request is handed to a replica only when it can be
    admitted THERE right now: a free slot under the cap and worst-case page
    admission -- the same test the engine's own scheduler applies, so a
    single-replica fleet admits on exactly the bare engine's schedule.
    """

    def __init__(self, pool: ReplicaPool, sla=None):
        self.pool = pool
        self.sla = sla
        self.queue: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def backlog(self) -> int:
        return len(self.queue) + len(self.pool.migrated)

    def _deadline(self, req: Request) -> float:
        pb, db = req.request_class
        return req.arrival_s + self.sla.deadline_s(f"p{pb}d{db}")

    def dispatch(self, now: float) -> int:
        """One admission pass; returns requests placed on a replica."""
        del now
        pool = self.pool
        placed = 0
        folded = False
        backlog, pool.migrated = pool.migrated, []
        for m in backlog:
            if _restartable(m):            # no progress: back through the
                self.queue.append(m.req)   # queue at the original deadline
                folded = True
            else:                          # re-admission keeps progress
                placed += bool(pool.place_migrated(m))
        if self.sla is not None and len(self.queue) > 1:
            self.queue.sort(key=self._deadline)   # stable: FIFO within ties
        elif folded and len(self.queue) > 1:
            # no SLA classes: restore global arrival order (stable, so
            # same-arrival submits keep their relative order)
            self.queue.sort(key=lambda r: r.arrival_s)
        # per-replica pages/slots promised in THIS pass (reservations only
        # execute inside the engine's next step)
        planned: dict[int, int] = {}
        taken: dict[int, int] = {}
        while self.queue:
            req = self.queue[0]
            if req.max_new_tokens <= 0:    # completes at fill time, no slot
                target = next((r for r in self.pool.serving
                               if not r.draining and r.healthy), None)
                if target is None:
                    break
                self.queue.pop(0)
                target.eng.submit(req)
                placed += 1
                continue
            total = len(req.prompt) + req.max_new_tokens - 1
            target = None
            for r in sorted(self.pool.serving,
                            key=lambda r: (-(r.free_slots
                                             - taken.get(r.rix, 0)), r.rix)):
                if r.draining or not r.healthy:
                    continue
                if (r.free_slots - taken.get(r.rix, 0) > 0
                        and r.eng.kv.can_admit(total,
                                               planned.get(r.rix, 0))):
                    target = r
                    break
            if target is None:
                break                      # head-of-line: shed = wait
            self.queue.pop(0)
            target.eng.submit(req)
            taken[target.rix] = taken.get(target.rix, 0) + 1
            planned[target.rix] = (planned.get(target.rix, 0)
                                   + target.eng.kv.pages_needed(total))
            placed += 1
        return placed


class FleetExecutor:
    """Convergence :class:`~repro.core.convergence.StepExecutor` that
    actuates the ReplicaPool and keeps the CapacityPlan ledger in sync.

    ``launch`` spawns for real and calibrates the pool's provisioning delay
    from the measured wall time BEFORE booking the unit, so the plan's
    landing clock equals the replica's readiness; a spawn that raises is
    booked as a measured stuck build, which the converger's existing
    timeout / cancel / backoff machinery then handles."""

    def __init__(self, pool: ReplicaPool, plan, name: str = FLEET_POOL, *,
                 calibrate: bool = True):
        self.pool = pool
        self.plan = plan
        self.name = name
        # calibrate=False books the CONFIGURED provisioning delay instead of
        # the measured spawn wall time: chaos drills need the plan's landing
        # clock -- and therefore the audit log -- byte-identical across
        # same-seed re-runs, which measured wall time can never be
        self.calibrate = calibrate
        self._stuck = 0      # measured stuck builds currently on the books

    def launch(self, pool: str, count: int, now: float) -> int:
        applied = 0
        for _ in range(int(count)):
            try:
                rep, dt = self.pool.spawn()
            except RuntimeError:
                applied += self.plan.queue_stuck(pool, 1, now)
                self._stuck += 1
                continue
            if self.calibrate:
                self.plan.calibrate_delay(pool, dt)
            queued = self.plan.request(pool, 1, now)
            if queued:
                self.pool.provisioning.append((now + dt, rep))
                applied += queued
            else:                          # ceiling refused: discard the spawn
                self.pool.retired.append(rep)
        return applied

    def cancel_pending(self, pool: str, count: int, now: float) -> int:
        del now
        applied = self.plan.cancel_pending(pool, count)
        # the plan cancels stuck builds first; only the rest correspond to
        # provisioning replicas we must discard (newest first, matching the
        # plan's pending cancel order)
        from_stuck = min(applied, self._stuck)
        self._stuck -= from_stuck
        for _ in range(min(applied - from_stuck, len(self.pool.provisioning))):
            self.pool.provisioning.sort(key=lambda e: e[0])
            _, rep = self.pool.provisioning.pop()
            self.pool.retired.append(rep)
        return applied

    def drain(self, pool: str, count: int, now: float) -> int:
        del now
        take = self.plan.drain(pool, count)    # ledger first: floor applies
        order = sorted(self.pool.serving,
                       key=lambda r: (r.healthy, -r.rix))  # sick, then newest
        for r in order[:min(take, len(self.pool.serving))]:
            self.pool.drain(r)
        return take

    def replace_unhealthy(self, pool: str, count: int,
                          now: float) -> tuple[int, int]:
        sick = [r for r in self.pool.serving if not r.healthy]
        k = min(int(count), len(sick))
        if k <= 0:
            return 0, 0
        drained, _ = self.plan.replace_unhealthy(pool, k, now,
                                                 queue_replacements=False)
        queued = 0
        for r in sick[:drained]:
            self.pool.drain(r)             # migrate its work off first
            queued += self.launch(pool, 1, now)   # measured respawn
        return drained, queued


class FleetBackend:
    """ScalableBackend over a ReplicaPool (unit = replica), driven by the
    convergence plane through a :class:`FleetExecutor`.

    Mirrors the :class:`~repro.launch.serve.ServeBackend` virtual-time step
    protocol; ``on_step(backend, t)`` is a fault-drill hook called after
    capacity convergence and before admission each step."""

    def __init__(self, pool: ReplicaPool, requests, *, sla_s: float,
                 horizon_s: float, policy=None, adapt_period_s: float = 5.0,
                 app_window_s: float = 10.0, starting_replicas: int = 1,
                 max_replicas: int = 4, min_replicas: int = 1,
                 provision_delay_s: float = 3.0, cost_rate: float = 1.0,
                 decode_steps: int = 1, sla=None, converge=None,
                 convergence: bool = True, group=None, calibrate: bool = True,
                 audit_path=None, on_step=None):
        self.pool = pool
        self.router = FleetRouter(pool, sla=sla)
        self.requests = sorted(requests, key=lambda r: r.arrival_s)
        self.sla_s = sla_s
        self.sla = sla
        self.horizon_s = horizon_s
        self.decode_steps = max(int(decode_steps), 1)
        self.on_step = on_step
        self.completed: list[Request] = []
        self._reported: dict[int, int] = {}    # replica rix -> completions seen
        if policy is None:
            policy = make_policy("target")
        unit_pool = UnitPool(FLEET_POOL, provision_delay_s=provision_delay_s,
                             cost_rate=cost_rate, min_units=min_replicas,
                             max_units=max_replicas)
        # convergence=False is the imperative baseline the chaos drills
        # compare against: same real spawns/drains through the same
        # FleetExecutor (the controller's actuation seam), but no desired
        # state, no healing, no retry machinery -- faults are only repaired
        # if the policy happens to vote capacity back.  calibrate=False
        # books configured (not measured) provisioning delays so a scripted
        # drill's audit log is byte-identical across same-seed re-runs.
        self.controller = ScalingController(
            policy,
            ControllerConfig(
                adapt_period_s=adapt_period_s,
                step_s=1.0,
                app_window_s=app_window_s,
                signal_channel="output_score",
                pools=(unit_pool,),
                convergence=convergence,
                converge=converge,
                group=group,
                audit_path=audit_path,
            ),
            SignalBus(FLEET_CHANNELS, bin_s=1.0),
            starting_units=starting_replicas,
            executor_factory=lambda plan: FleetExecutor(
                pool, plan, FLEET_POOL, calibrate=calibrate),
        )
        # the starting fleet spawns for real, NOW: the measured wall time
        # calibrates the pool's provisioning delay from step zero
        for _ in range(starting_replicas):
            rep, dt = pool.spawn()
            if calibrate:
                self.controller.plan.calibrate_delay(FLEET_POOL, dt)
            pool.serving.append(rep)

    def fire_webhook(self, name: str, now: float):
        """Mid-incident operator intent: arm the scaling group's webhook
        ``name`` (convergence mode applies its floors to the desired state
        immediately -- see ``ScalingController.fire_webhook``)."""
        return self.controller.fire_webhook(name, now)

    def _collect_completions(self) -> list[Request]:
        fresh = []
        for r in self.pool.serving + self.pool.retired:
            seen = self._reported.get(r.rix, 0)
            if len(r.eng.completed) > seen:
                fresh.extend(r.eng.completed[seen:])
                self._reported[r.rix] = len(r.eng.completed)
        self.completed.extend(fresh)
        return fresh

    def kill_replica(self, replica: Replica, now: float) -> None:
        """Fault drill: abrupt replica loss.  The plan ledger records a
        measured unit loss; the converger heals by launching -- a real
        spawn -- at its next pass."""
        self.pool.kill(replica)
        self.controller.plan.mark_lost(FLEET_POOL, 1, now)

    def run(self) -> RunReport:
        ctrl, pool, router = self.controller, self.pool, self.router
        bus = ctrl.bus
        t = 0.0
        head = 0
        units_hist: list[int] = []
        backlog_peak = 0
        while (head < len(self.requests) or router.backlog
               or any(r.eng.n_in_system for r in pool.serving)):
            units = ctrl.on_step_start(t)   # land + converge (spawns happen
            pool.activate_to(units)         # inside, measured)
            if self.on_step is not None:
                self.on_step(self, t)
            new_arr = 0
            while (head < len(self.requests)
                   and self.requests[head].arrival_s <= t):
                router.submit(self.requests[head])
                head += 1
                new_arr += 1
            router.dispatch(t)
            served = sum(r.step(t, self.decode_steps) for r in pool.serving)
            fresh = self._collect_completions()
            if fresh:
                bus.record("output_score",
                           np.array([r.arrival_s for r in fresh]),
                           np.array([r.score for r in fresh]))
            now_arr = np.array([t])
            bus.record("fleet_occupancy", now_arr,
                       np.array([pool.occupancy()]))
            bus.record("fleet_queue_depth", now_arr,
                       np.array([float(router.backlog)]))
            ctrl.plan.set_unhealthy(FLEET_POOL, pool.n_unhealthy)
            units_hist.append(len(pool.serving))
            backlog_peak = max(backlog_peak, len(pool.migrated))
            ctrl.note_step(min(1.0, served / max(pool.total_slots(), 1)),
                           new_arr)
            ctrl.maybe_adapt(time=t + 1.0,
                             n_in_system=router.backlog + pool.n_in_system)
            t += 1.0
            if t > self.horizon_s + 10_000:
                raise RuntimeError("fleet backend failed to drain")

        if ctrl.audit is not None:
            ctrl.audit.seal(t)
            ctrl.audit.close()
        units_arr = np.asarray(units_hist, dtype=np.int64)
        lat = np.array([r.done_s - r.arrival_s for r in self.completed])
        classes = np.array([f"p{r.request_class[0]}d{r.request_class[1]}"
                            for r in self.completed])
        per_replica = {
            f"replica{r.rix}": {"tokens": r.tokens, "busy_s": r.busy_s,
                                "spawn_s": r.spawn_s}
            for r in pool.serving + pool.retired}
        return RunReport(
            backend="fleet",
            workload=f"{len(self.requests)} requests",
            policy=ctrl.policy.describe(),
            sla_s=self.sla_s,
            latencies=lat,
            unit_seconds=float(units_arr.sum()),
            units_t=units_arr,
            n_decisions_up=ctrl.n_up,
            n_decisions_down=ctrl.n_down,
            unit_name="replica",
            decisions=ctrl.decision_log,
            sla=self.sla,
            classes=classes,
            extra={"per_replica": per_replica,
                   "migrated_backlog_peak": backlog_peak},
            **ctrl.plan.report_kwargs(),
        )


__all__ = ["FLEET_CHANNELS", "FLEET_POOL", "FleetBackend", "FleetExecutor",
           "FleetRouter", "Replica", "ReplicaPool"]
