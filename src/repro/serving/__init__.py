from repro.serving.engine import (
    MigratedRequest, Request, ServeConfig, ServingEngine,
)

__all__ = ["MigratedRequest", "Request", "ServeConfig", "ServingEngine"]
