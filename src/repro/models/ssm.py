"""Mamba-2 SSD (state-space duality) block, chunked matmul formulation.

TPU adaptation of the CUDA selective scan: instead of warp-level scans, the
sequence is split into chunks of length Q and the recurrence is expressed as
dense matmuls (MXU work) + a short ``lax.scan`` over chunk states:

  intra-chunk:  Y_intra = ((C B^T) .* decay_mask) X
  chunk state:  S_i     = sum_t a(t->end) B_t x_t
  inter-chunk:  S       = scan over chunks (decay^Q carry)
  inter out:    Y_inter = C_t a(start->t) S_{i-1}

This mirrors the official SSD "chunked" algorithm (arXiv:2405.21060 SS6).
The Pallas kernel in ``repro.kernels.ssd`` fuses the intra-chunk part; this
module is the pure-jnp reference and the default path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import SSMConfig


def ssd_chunked(x, dt, A, B, C, D, chunk: int, *, use_kernel: bool = False,
                initial_state=None, return_state: bool = False):
    """SSD scan.

    x:  (b, s, h, p)   inputs per head
    dt: (b, s, h)      softplus-activated step sizes (>0)
    A:  (h,)           negative decay rates
    B:  (b, s, g, n)   input projections (state dim n, g groups)
    C:  (b, s, g, n)   output projections
    D:  (h,)           skip
    Returns y: (b, s, h, p) (+ final state (b, h, p, n) if requested).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk:
        padlen = chunk - s % chunk
        pad = lambda a: jnp.pad(a, [(0, 0), (0, padlen)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = pad(x), pad(dt), pad(B), pad(C)   # dt=0 rows are identity steps
        s = s + padlen
    nc = s // chunk
    rep = h // g

    # fold dt into x and decay
    xb = (x * dt[..., None]).astype(jnp.float32)                 # (b,s,h,p)
    a = A[None, None, :] * dt                                    # (b,s,h)  negative
    xb = xb.reshape(b, nc, chunk, h, p)
    a = a.reshape(b, nc, chunk, h)
    Bq = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cq = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Bh = jnp.repeat(Bq, rep, axis=3)                             # (b,nc,q,h,n)
    Ch = jnp.repeat(Cq, rep, axis=3)

    # cumulative log-decay within chunk
    acs = jnp.cumsum(a, axis=2)                                  # (b,nc,q,h)

    # ---- intra-chunk (quadratic in chunk length; the Pallas kernel target) ----------
    if use_kernel:
        from repro.kernels.ssd.ops import ssd_intra
        y_intra = ssd_intra(xb, acs, Bh, Ch)
    else:
        # L[t,u] = exp(acs_t - acs_u) for t >= u
        diff = acs[:, :, :, None, :] - acs[:, :, None, :, :]     # (b,nc,t,u,h)
        tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        Lmask = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bcthn,bcuhn->bctuh", Ch, Bh)
        y_intra = jnp.einsum("bctuh,bctuh,bcuhp->bcthp", scores, Lmask, xb)

    # ---- chunk states ----------------------------------------------------------------
    seg = jnp.exp(acs[:, :, -1:, :] - acs)                       # decay t -> chunk end
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, seg, xb)  # (b,nc,h,p,n)
    chunk_decay = jnp.exp(acs[:, :, -1, :])                      # (b,nc,h)

    # ---- inter-chunk recurrence (short scan over nc) ---------------------------------
    def step(carry, inp):
        st, dec = inp                                            # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                        # emit the *incoming* state

    init = jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None \
        else initial_state.astype(jnp.float32)
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,nc,h,p,n)

    # ---- inter-chunk output ------------------------------------------------------------
    dec_in = jnp.exp(acs)                                        # decay start -> t
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Ch, dec_in, prev_states)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    y = y[:, :s_orig].astype(x.dtype)
    if return_state:
        return y, final
    return y


def ssd_decode_step(x1, dt1, A, B1, C1, D, state):
    """Single-token recurrent update.

    x1: (b, h, p); dt1: (b, h); B1/C1: (b, g, n); state: (b, h, p, n).
    Returns (y (b,h,p), new_state).
    """
    b, h, p = x1.shape
    g, n = B1.shape[1], B1.shape[2]
    rep = h // g
    Bh = jnp.repeat(B1, rep, axis=1).astype(jnp.float32)         # (b,h,n)
    Ch = jnp.repeat(C1, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(A[None] * dt1)                                   # (b,h)
    xd = (x1 * dt1[..., None]).astype(jnp.float32)
    new_state = state * a[..., None, None] + xd[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + x1.astype(jnp.float32) * D[None, :, None]
    return y.astype(x1.dtype), new_state


def mamba2_block(x, params, cfg: SSMConfig, *, use_kernel: bool = False,
                 state=None, conv_state=None, decode: bool = False):
    """Full Mamba-2 mixer.

    x: (b, s, d).  params: w_z/w_x (d, d_in), w_bc (d, 2*g*n), w_dt (d, h),
    conv_x (w, d_in), conv_bc (w, 2*g*n), A_log (h,), D (h,), dt_bias (h,),
    norm (d_in,), out_proj (d_in, d).

    In decode mode s == 1 and (state, conv_state) carry the recurrence;
    returns (y, new_state, new_conv_state).  conv_state: (b, w, d_in + 2*g*n).
    """
    b, s, d = x.shape
    d_in = cfg.expand * d
    h = d_in // cfg.head_dim
    g, n, w = cfg.n_groups, cfg.d_state, cfg.conv_width

    z = x @ params["w_z"]                                        # (b,s,d_in)
    xBC = jnp.concatenate([x @ params["w_x"], x @ params["w_bc"]], axis=-1)
    dt = x @ params["w_dt"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,s,h)
    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]], axis=-1)

    # depthwise causal conv over (x, B, C)
    if decode:
        new_conv = jnp.concatenate([conv_state[:, 1:], xBC[:, :1]], axis=1)
        xBC = jnp.einsum("bwc,wc->bc", new_conv, conv_w)[:, None]
        conv_out_state = new_conv
    else:
        pad = jnp.zeros((b, w - 1, xBC.shape[-1]), xBC.dtype)
        xp = jnp.concatenate([pad, xBC], axis=1)
        conv_out_state = xp[:, -w:]     # last w pre-conv inputs (decode carry)
        xBC = sum(
            xp[:, i : i + s] * conv_w[i][None, None]
            for i in range(w)
        )
    xBC = jax.nn.silu(xBC)
    xs, B, C = jnp.split(xBC, [d_in, d_in + g * n], axis=-1)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (h,) negative

    if decode:
        x1 = xs.reshape(b, h, cfg.head_dim)
        y, new_state = ssd_decode_step(
            x1, dt[:, 0], A, B.reshape(b, g, n), C.reshape(b, g, n),
            params["D"], state)
        y = y.reshape(b, 1, d_in)
    else:
        xh = xs.reshape(b, s, h, cfg.head_dim)
        out = ssd_chunked(
            xh, dt, A, B.reshape(b, s, g, n), C.reshape(b, s, g, n),
            params["D"], cfg.chunk, use_kernel=use_kernel,
            initial_state=state, return_state=True)
        y, new_state = out
        y = y.reshape(b, s, d_in)

    # gated RMSNorm (Mamba-2 normalizes y * silu(z))
    yz = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), axis=-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    yz = yz * params["norm"]
    return yz @ params["out_proj"], new_state, conv_out_state


__all__ = ["ssd_chunked", "ssd_decode_step", "mamba2_block"]
