"""Pure Mamba-2 LM (mamba2-1.3b) and the Zamba2-style hybrid (SSM stack with a
single shared attention(+MLP) block applied every N layers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import sdpa
from repro.models.common import (
    ModelConfig, apply_rope, gated_mlp, init_dense, rms_norm, rope_tables,
)
from repro.models.lm import (
    _lm_head, _prefill_attention, _project_qkv, _remat, init_block_params,
)
from repro.models.ssm import mamba2_block


# ---------------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------------

def init_mamba_layer(rng, cfg: ModelConfig):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    h = d_in // s.head_dim
    g, n, w = s.n_groups, s.d_state, s.conv_width
    ks = jax.random.split(rng, 6)
    # in_proj is split into semantically separate matrices so tensor parallelism
    # can shard z/x/dt by SSM head while replicating the (group-shared) B/C
    # projections -- the standard Mamba TP layout.
    return {
        "ln": jnp.ones((d,), cfg.dtype),
        "w_z": init_dense(ks[0], (d, d_in), cfg.dtype),
        "w_x": init_dense(ks[1], (d, d_in), cfg.dtype),
        "w_bc": init_dense(ks[2], (d, 2 * g * n), cfg.dtype),
        "w_dt": init_dense(ks[3], (d, h), cfg.dtype),
        "conv_x": init_dense(ks[4], (w, d_in), cfg.dtype, scale=w ** -0.5),
        "conv_bc": init_dense(ks[5], (w, 2 * g * n), cfg.dtype, scale=w ** -0.5),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), cfg.dtype),
        "out_proj": init_dense(ks[2], (d_in, d), cfg.dtype),
    }


def init_params(rng, cfg: ModelConfig):
    k_embed, k_blocks, k_head, k_attn = jax.random.split(rng, 4)
    blocks = jax.vmap(lambda k: init_mamba_layer(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": init_dense(k_embed, (cfg.vocab, cfg.d_model), cfg.dtype, scale=0.02),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, (cfg.d_model, cfg.vocab), cfg.dtype)
    if cfg.shared_attn_every:
        params["shared_attn"] = init_block_params(k_attn, cfg)  # attn + mlp block
    return params


def _n_attn_calls(cfg: ModelConfig) -> int:
    if not cfg.shared_attn_every:
        return 0
    return sum(1 for i in range(cfg.n_layers)
               if i % cfg.shared_attn_every == cfg.shared_attn_every - 1)


# ---------------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------------

def _shared_attn_forward(x, params, cos, sin, cfg: ModelConfig, use_kernel: bool):
    bp = params["shared_attn"]
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(h, bp, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = _prefill_attention(q, k, v, jnp.int32(-1), use_kernel)
    x = x + o.reshape(*x.shape[:2], -1) @ bp["wo"]
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    f = gated_mlp(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
    return x + f, (k, v)


def forward(params, batch, cfg: ModelConfig, *, use_kernel: bool = False,
            collect_cache: bool = False):
    x = params["embed"][batch["tokens"]] if cfg.input_mode == "tokens" \
        else batch["embeds"].astype(cfg.dtype)
    B, S, _ = x.shape
    every = cfg.shared_attn_every
    cos = sin = None
    if every:
        cos, sin = rope_tables(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)

    ssm_states, conv_states, attn_kv = [], [], []

    def mamba_body(x, bp):
        h = rms_norm(x, bp["ln"], cfg.norm_eps)
        y, st, cv = mamba2_block(h, bp, cfg.ssm, use_kernel=use_kernel)
        return x + y, (st, cv)

    mamba_body = _remat(mamba_body, cfg)

    if not every:
        x, (sts, cvs) = jax.lax.scan(mamba_body, x, params["blocks"])
    else:
        # super-block structure: scan chunks of `every` ssm layers, then the shared
        # attention block (same weights each call, per-call KV cache).
        L = cfg.n_layers
        n_super = L // every
        rest = L - n_super * every
        blocks = params["blocks"]
        head = jax.tree.map(lambda a: a[: n_super * every].reshape(
            (n_super, every) + a.shape[1:]), blocks)
        sts_all, cvs_all = [], []
        for j in range(n_super):    # n_super ~ 9: unrolled outer, scanned inner
            sub = jax.tree.map(lambda a: a[j], head)
            x, (st, cv) = jax.lax.scan(mamba_body, x, sub)
            sts_all.append(st)
            cvs_all.append(cv)
            x, kv = _shared_attn_forward(x, params, cos, sin, cfg, use_kernel)
            attn_kv.append(kv)
        if rest:
            tail = jax.tree.map(lambda a: a[n_super * every:], blocks)
            x, (st, cv) = jax.lax.scan(mamba_body, x, tail)
            sts_all.append(st)
            cvs_all.append(cv)
        sts = jnp.concatenate(sts_all, 0)
        cvs = jnp.concatenate(cvs_all, 0)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    if collect_cache:
        cache = {"ssm": sts, "conv": cvs}
        if every:
            cache["attn_k"] = jnp.stack([k for k, _ in attn_kv])
            cache["attn_v"] = jnp.stack([v for _, v in attn_kv])
        return logits, cache
    return logits, jnp.float32(0.0)


def loss_fn(params, batch, cfg: ModelConfig, *, use_kernel: bool = False):
    logits, _ = forward(params, batch, cfg, use_kernel=use_kernel)
    tgt = batch["targets"]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(logp, tgt[:, 1:, None], axis=-1)[..., 0]
    mask = (tgt[:, 1:] >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return loss, {"ce": loss}


# ---------------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    cache = {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width, conv_ch), cfg.dtype),
    }
    if cfg.shared_attn_every:
        calls = _n_attn_calls(cfg)
        hd = cfg.resolved_head_dim
        cache["attn_k"] = jnp.zeros((calls, batch, max_len, cfg.n_kv_heads, hd), cfg.dtype)
        cache["attn_v"] = jnp.zeros((calls, batch, max_len, cfg.n_kv_heads, hd), cfg.dtype)
    return cache


def prefill(params, batch, cfg: ModelConfig, max_len: int | None = None,
            *, use_kernel: bool = False):
    logits, cache = forward(params, batch, cfg, use_kernel=use_kernel,
                            collect_cache=True)
    S = (batch["tokens"].shape[1] if cfg.input_mode == "tokens"
         else batch["embeds"].shape[1])
    max_len = max_len or S
    if cfg.shared_attn_every and max_len > S:
        pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
        cache["attn_k"] = jnp.pad(cache["attn_k"], pad)
        cache["attn_v"] = jnp.pad(cache["attn_v"], pad)
    return logits[:, -1:], cache


def decode_step(params, cache, token, pos, cfg: ModelConfig):
    x = params["embed"][token]
    every = cfg.shared_attn_every
    cos = sin = None
    if every:
        cos, sin = rope_tables(jnp.array([pos]), cfg.resolved_head_dim, cfg.rope_theta)

    def mamba_body(x, layer):
        bp, st, cv = layer
        h = rms_norm(x, bp["ln"], cfg.norm_eps)
        y, st, cv = mamba2_block(h, bp, cfg.ssm, state=st, conv_state=cv, decode=True)
        return x + y, (st, cv)

    if not every:
        x, (sts, cvs) = jax.lax.scan(
            mamba_body, x, (params["blocks"], cache["ssm"], cache["conv"]))
        new_cache = {"ssm": sts, "conv": cvs}
    else:
        L = cfg.n_layers
        n_super = L // every
        rest = L - n_super * every
        blocks = params["blocks"]
        split = lambda a, lo, hi: jax.tree.map(lambda t: t[lo:hi], a)
        sts_all, cvs_all, ks_all, vs_all = [], [], [], []
        bp_attn = params["shared_attn"]
        for j in range(n_super):
            lo, hi = j * every, (j + 1) * every
            x, (st, cv) = jax.lax.scan(
                mamba_body, x,
                (split(blocks, lo, hi), cache["ssm"][lo:hi], cache["conv"][lo:hi]))
            sts_all.append(st); cvs_all.append(cv)
            # shared attention decode, call-j cache
            h = rms_norm(x, bp_attn["ln1"], cfg.norm_eps)
            q, k, v = _project_qkv(h, bp_attn, cfg)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
            ck = jax.lax.dynamic_update_slice(cache["attn_k"][j], k.astype(cfg.dtype),
                                              (0, pos, 0, 0))
            cv_ = jax.lax.dynamic_update_slice(cache["attn_v"][j], v.astype(cfg.dtype),
                                               (0, pos, 0, 0))
            valid = jnp.arange(ck.shape[1]) < pos + 1
            o = sdpa(q, ck, cv_, valid[None, :])
            x = x + o.reshape(*x.shape[:2], -1) @ bp_attn["wo"]
            h2 = rms_norm(x, bp_attn["ln2"], cfg.norm_eps)
            x = x + gated_mlp(h2, bp_attn["mlp"]["w_gate"], bp_attn["mlp"]["w_up"],
                              bp_attn["mlp"]["w_down"])
            ks_all.append(ck); vs_all.append(cv_)
        if rest:
            lo = n_super * every
            x, (st, cv) = jax.lax.scan(
                mamba_body, x,
                (split(blocks, lo, L), cache["ssm"][lo:], cache["conv"][lo:]))
            sts_all.append(st); cvs_all.append(cv)
        new_cache = {
            "ssm": jnp.concatenate(sts_all, 0),
            "conv": jnp.concatenate(cvs_all, 0),
            "attn_k": jnp.stack(ks_all),
            "attn_v": jnp.stack(vs_all),
        }

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg), new_cache


__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step", "init_cache"]
