"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_len, d); the encoder is a bidirectional
transformer, the decoder adds causal self-attention + cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import sdpa
from repro.models.common import (
    ModelConfig, apply_rope, gated_mlp, init_dense, rms_norm, rope_tables,
)
from repro.models.lm import _lm_head, _project_qkv, _remat, init_block_params


def _init_dec_block(rng, cfg: ModelConfig):
    p = init_block_params(rng, cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(jax.random.fold_in(rng, 7), 4)
    p["ln_x"] = jnp.ones((d,), cfg.dtype)
    p["xq"] = init_dense(ks[0], (d, cfg.n_heads * hd), cfg.dtype)
    p["xk"] = init_dense(ks[1], (d, cfg.n_kv_heads * hd), cfg.dtype)
    p["xv"] = init_dense(ks[2], (d, cfg.n_kv_heads * hd), cfg.dtype)
    p["xo"] = init_dense(ks[3], (cfg.n_heads * hd, d), cfg.dtype)
    return p


def init_params(rng, cfg: ModelConfig):
    k_embed, k_enc, k_dec, k_head = jax.random.split(rng, 4)
    enc = jax.vmap(lambda k: init_block_params(k, cfg))(
        jax.random.split(k_enc, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: _init_dec_block(k, cfg))(
        jax.random.split(k_dec, cfg.n_layers))
    return {
        "embed": init_dense(k_embed, (cfg.vocab, cfg.d_model), cfg.dtype, scale=0.02),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "ln_enc": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        "lm_head": init_dense(k_head, (cfg.d_model, cfg.vocab), cfg.dtype),
    }


def encode(params, enc_embeds, cfg: ModelConfig):
    """enc_embeds: (B, T_enc, d) precomputed frame embeddings (frontend stub)."""
    x = enc_embeds.astype(cfg.dtype)
    T = x.shape[1]
    cos, sin = rope_tables(jnp.arange(T), cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, bp):
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h, bp, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        o = sdpa(q, k, v, None)                       # bidirectional
        x = x + o.reshape(*x.shape[:2], -1) @ bp["wo"]
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        f = gated_mlp(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
        return x + f, None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _cross_attend(x, bp, xk, xv, cfg: ModelConfig):
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
    q = (h @ bp["xq"]).reshape(B, S, cfg.n_heads, hd)
    return x + sdpa(q, xk, xv, None).reshape(B, S, -1) @ bp["xo"]


def _dec_cross_kv(bp, enc_out, cfg: ModelConfig):
    B, T, d = enc_out.shape
    hd = cfg.resolved_head_dim
    xk = (enc_out @ bp["xk"]).reshape(B, T, cfg.n_kv_heads, hd)
    xv = (enc_out @ bp["xv"]).reshape(B, T, cfg.n_kv_heads, hd)
    return xk, xv


def forward(params, batch, cfg: ModelConfig, *, use_kernel: bool = False):
    """Teacher-forced training forward: batch = {enc_embeds, tokens}."""
    enc_out = encode(params, batch["enc_embeds"], cfg)
    x = params["embed"][batch["tokens"]]
    B, S, _ = x.shape
    cos, sin = rope_tables(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)
    causal = jnp.tril(jnp.ones((S, S), bool))

    def body(x, bp):
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h, bp, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        x = x + sdpa(q, k, v, causal).reshape(B, S, -1) @ bp["wo"]
        xk, xv = _dec_cross_kv(bp, enc_out, cfg)
        x = _cross_attend(x, bp, xk, xv, cfg)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        f = gated_mlp(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
        return x + f, None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg), jnp.float32(0.0)


def loss_fn(params, batch, cfg: ModelConfig, *, use_kernel: bool = False):
    logits, _ = forward(params, batch, cfg)
    tgt = batch["targets"]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(logp, tgt[:, 1:, None], axis=-1)[..., 0]
    mask = (tgt[:, 1:] >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return loss, {"ce": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads, hd), cfg.dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads, hd), cfg.dtype),
    }


def prefill(params, batch, cfg: ModelConfig, max_len: int | None = None,
            *, use_kernel: bool = False):
    """Encode audio + run the decoder prompt; cache self-KV and cross-KV."""
    enc_out = encode(params, batch["enc_embeds"], cfg)
    x = params["embed"][batch["tokens"]]
    B, S, _ = x.shape
    max_len = max_len or S
    cos, sin = rope_tables(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)
    causal = jnp.tril(jnp.ones((S, S), bool))

    def body(x, bp):
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h, bp, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        x = x + sdpa(q, k, v, causal).reshape(B, S, -1) @ bp["wo"]
        xk, xv = _dec_cross_kv(bp, enc_out, cfg)
        x = _cross_attend(x, bp, xk, xv, cfg)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        f = gated_mlp(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
        return x + f, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _lm_head(params, x[:, -1:], cfg)
    if max_len > S:
        pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    return logits, {"k": ks.astype(cfg.dtype), "v": vs.astype(cfg.dtype),
                    "xk": xks.astype(cfg.dtype), "xv": xvs.astype(cfg.dtype)}


def decode_step(params, cache, token, pos, cfg: ModelConfig):
    x = params["embed"][token]
    cos, sin = rope_tables(jnp.array([pos]), cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, layer):
        bp, ck, cv, xk, xv = layer
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h, bp, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
        valid = jnp.arange(ck.shape[1]) < pos + 1
        x = x + sdpa(q, ck, cv, valid[None, :]).reshape(*x.shape[:2], -1) @ bp["wo"]
        x = _cross_attend(x, bp, xk, xv, cfg)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        f = gated_mlp(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
        return x + f, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"],
                                         cache["v"], cache["xk"], cache["xv"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg), {"k": ks, "v": vs,
                                      "xk": cache["xk"], "xv": cache["xv"]}


__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_cache", "encode"]
