"""Multi-head attention (GQA / causal / sliding-window / cross) in pure JAX.

The jnp path here is also the oracle for the Pallas kernels in
``repro.kernels``; ``use_kernel`` switches the prefill path to the Pallas
flash-attention kernel (interpret-mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(q_len: int, kv_len: int, *, causal: bool, window: int | None,
                   q_offset: int | jax.Array = 0) -> jax.Array:
    """(q_len, kv_len) boolean mask; True = attend.

    ``q_offset``: absolute position of query row 0 (for decode / chunked prefill).
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    m = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        m &= k_pos <= q_pos
    if window is not None:
        m &= k_pos > q_pos - window
    return m


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
         *, kv_valid_len: jax.Array | None = None) -> jax.Array:
    """Scaled dot-product attention with GQA head-group broadcasting.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D); mask: (Sq, Sk) or None.
    ``kv_valid_len``: optional scalar/per-batch count of valid KV entries
    (decode with a partially-filled cache).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = qf.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if mask is not None:
        if mask.ndim == 3:      # per-batch mask (B, Sq, Sk)
            logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        else:
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_valid_len is not None:
        k_pos = jnp.arange(k.shape[1])
        valid = k_pos[None] < jnp.reshape(kv_valid_len, (-1, 1))   # (B, Sk)
        logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def mha_prefill(q, k, v, *, causal: bool = True, window: int | None = None,
                use_kernel: bool = False) -> jax.Array:
    """Full-sequence attention.  q/k/v: (B, S, H{q,kv}, D)."""
    if use_kernel:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window)
    mask = attention_mask(q.shape[1], k.shape[1], causal=causal, window=window)
    return sdpa(q, k, v, mask)


def mha_decode(q1, k_cache, v_cache, pos, *, window: int | None = None,
               use_kernel: bool = False) -> jax.Array:
    """One-token decode: q1 (B, 1, Hq, D) against caches (B, S_max, Hkv, D);
    ``pos`` = number of valid entries (the new token's KV must already be
    written at index pos-1)."""
    if use_kernel:
        from repro.kernels.decode_attention.ops import decode_attention
        return decode_attention(q1, k_cache, v_cache, pos, window=window)
    S = k_cache.shape[1]
    k_pos = jnp.arange(S)
    valid = k_pos < pos
    if window is not None:
        valid &= k_pos >= pos - window
    mask = valid[None, :]                    # (1, S) -> (Sq=1, Sk)
    return sdpa(q1, k_cache, v_cache, mask)


__all__ = ["attention_mask", "sdpa", "mha_prefill", "mha_decode", "NEG_INF"]
