"""Model bundle: uniform functional interface over the zoo's families."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class Model:
    """Pure-function bundle; everything is jit/pjit-able with explicit shardings."""

    cfg: ModelConfig
    init_params: Callable          # rng -> params
    forward: Callable              # (params, batch) -> (logits, aux)
    loss_fn: Callable              # (params, batch) -> (loss, metrics)
    prefill: Callable              # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable          # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable           # (batch, max_len) -> cache
    supports_paged: bool = False   # decode_step accepts block_table= (paged KV)
    use_kernel: bool = False       # Pallas tier on (decode attn + epilogue)
    # (params, cache, tokens (B,T), pos (B,), block_table=) ->
    # (tok (B,T), lp (B,T), cache): span scoring through the fused lm-head;
    # None for families without the paged mixed path
    verify_step: Callable | None = None

    def abstract_params(self):
        return jax.eval_shape(self.init_params, jax.random.key(0))


def build_model(cfg: ModelConfig, *, use_kernel: bool = False) -> Model:
    paged = cfg.family in ("dense", "moe", "vlm")
    if paged:
        from repro.models import lm as mod
    elif cfg.family in ("ssm", "hybrid"):
        from repro.models import mamba_lm as mod
    elif cfg.family in ("audio", "encdec"):
        from repro.models import whisper as mod
    else:
        raise ValueError(f"unknown family {cfg.family}")

    decode_kwargs = {"use_kernel": use_kernel} if paged else {}
    return Model(
        cfg=cfg,
        init_params=partial(mod.init_params, cfg=cfg),
        forward=partial(mod.forward, cfg=cfg, use_kernel=use_kernel),
        loss_fn=partial(mod.loss_fn, cfg=cfg, use_kernel=use_kernel),
        prefill=partial(mod.prefill, cfg=cfg, use_kernel=use_kernel),
        decode_step=partial(mod.decode_step, cfg=cfg, **decode_kwargs),
        init_cache=partial(mod.init_cache, cfg),
        supports_paged=paged,
        use_kernel=use_kernel,
        verify_step=(partial(mod.verify_step, cfg=cfg, use_kernel=use_kernel,
                             lmhead_kernel=use_kernel)
                     if paged else None),
    )


__all__ = ["Model", "build_model"]
