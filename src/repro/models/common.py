"""Shared model configuration + primitive layers (pure JAX, shard-friendly).

Every architecture in the zoo is described by one :class:`ModelConfig`; the
builders in `repro.models.registry` turn a config into a :class:`Model` bundle of
pure functions (init / train logits / prefill / decode_step) suitable for
``jax.jit`` with explicit shardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    n_groups: int = 1
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0              # 0 => attention-free
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 32000
    head_dim: int = 0             # 0 => d_model // n_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window / local-global interleave
    window: int | None = None             # SWA width for windowed layers
    global_every: int | None = None       # gemma3: 1 global layer every N (rest local)
    # MoE / SSM
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # zamba2-style shared attention block applied every N ssm layers
    shared_attn_every: int | None = None
    # encoder-decoder (whisper): encoder length & layers
    n_enc_layers: int = 0
    enc_len: int = 0
    # modality frontend stub: model consumes precomputed embeddings for the
    # encoder/prefix instead of token ids
    input_mode: str = "tokens"            # tokens | embeddings
    dtype: Any = jnp.bfloat16
    # remat policy for train_step: none | block | dots
    remat: str = "block"
    # KV cache storage: "native" (= dtype) or "int8" (per-token/head symmetric
    # quantization; halves the decode memory term -- EXPERIMENTS SSPerf 4.3)
    kv_cache_dtype: str = "native"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode shape?"""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.window is not None or self.global_every is not None:
            return True   # SWA / mostly-local attention
        return False

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        total = self.vocab * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab * d                  # lm head
        per_attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.qkv_bias:
            per_attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        per_mlp = 3 * d * self.d_ff if self.d_ff else 0
        if self.moe:
            per_mlp = d * self.moe.n_experts \
                + self.moe.n_experts * 3 * d * self.moe.d_expert
        if self.family == "ssm" or (self.family == "hybrid" and self.ssm):
            s = self.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            per_ssm = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_h) \
                + d_in * d + s.conv_width * (d_in + 2 * s.n_groups * s.d_state) \
                + 2 * n_h
            if self.family == "ssm":
                total += L * (per_ssm + 2 * d)
                return int(total)
            # hybrid: L ssm layers + ONE shared attn+mlp block
            total += L * (per_ssm + 2 * d)
            total += per_attn + per_mlp + 2 * d
            return int(total)
        per_block = per_attn + per_mlp + 2 * d
        if self.n_enc_layers:   # decoder blocks also carry cross-attention
            per_block_dec = per_attn * 2 + per_mlp + 3 * d
            total += self.n_enc_layers * per_block + L * per_block_dec
        else:
            total += L * per_block
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        dense_experts = L * self.moe.n_experts * 3 * d * self.moe.d_expert
        active_experts = L * self.moe.top_k * 3 * d * self.moe.d_expert
        return int(full - dense_experts + active_experts)


# ---------------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding: (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU feed-forward; weights (d, f), (d, f), (f, d)."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_dense(rng: jax.Array, shape: tuple[int, ...], dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * std).astype(dtype)


def split_keys(rng: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(rng, n))


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig",
    "rms_norm", "rope_tables", "apply_rope", "gated_mlp",
    "init_dense", "split_keys",
]
