"""Mixture-of-Experts layer: top-k routing with capacity-bounded sort-based
dispatch (GShard-style dropping), TPU/SPMD-friendly.

The dispatch avoids the O(T*E*C) one-hot tensors of the classic einsum
formulation: tokens' (token, expert) assignments are sorted by expert id, the
rank within each expert group is computed from the sorted run starts, and
tokens beyond the expert capacity are dropped (their combine weight is zero, so
the residual path carries them -- standard dropping semantics).

Under pjit, experts are sharded on the "model" axis ((E, D, F) with E sharded);
XLA inserts the token all-to-alls.  The hillclimbed shard_map variant lives in
``repro.distributed.moe_ep``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MoEConfig

# hillclimb knob (EXPERIMENTS SSPerf): explicit sharding constraints on the
# dispatch buffers keep the expert computation expert-sharded and the token
# views data-sharded, steering SPMD to all-to-alls instead of full-buffer
# all-reduces.  On by default; set REPRO_MOE_CONSTRAIN=0 for the baseline.
_CONSTRAIN = os.environ.get("REPRO_MOE_CONSTRAIN", "1") == "1"


def _constrain(x, spec):
    if not _CONSTRAIN:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x   # no mesh context (single-device tests)


def router_topk(x: jax.Array, w_router: jax.Array, cfg: MoEConfig):
    """x: (T, D) -> (weights (T,k), experts (T,k), router logits for aux loss)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    return weights, experts, logits


def load_balance_loss(router_logits: jax.Array, experts: jax.Array, n_experts: int) -> jax.Array:
    """Switch-Transformer aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    p_mean = probs.mean(0)
    occupancy = jax.nn.one_hot(experts[:, 0], n_experts, dtype=jnp.float32).mean(0)
    return n_experts * jnp.sum(occupancy * p_mean)


def moe_ffn(x: jax.Array, params: dict, cfg: MoEConfig):
    """x: (T, D).  params: router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D).

    Returns (out (T, D), aux_loss scalar).
    """
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # capacity floor of 4 keeps tiny decode batches drop-free; training shapes are
    # governed by capacity_factor as usual
    C = max(int(T * k * cfg.capacity_factor / E), min(4, T * k))

    weights, experts, logits = router_topk(x, params["router"], cfg)

    # ---- flatten (token, choice) pairs and sort by expert ---------------------------
    flat_e = experts.reshape(-1)                      # (T*k,)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, st = flat_e[order], flat_w[order], flat_t[order]
    # rank within expert group = position - index of first element of that expert
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - starts[se]
    keep = rank < C                                   # capacity dropping

    # ---- dispatch: build (E, C, D) expert inputs ------------------------------------
    buf = jnp.zeros((E, C, D), dtype=x.dtype)
    e_idx = jnp.where(keep, se, 0)
    c_idx = jnp.where(keep, rank, 0)
    src = jnp.where(keep[:, None], x[st], 0.0).astype(x.dtype)
    buf = buf.at[e_idx, c_idx].add(src, mode="drop")
    buf = _constrain(buf, P("model", None, None))

    # ---- expert FFN (batched over E; sharded on the model axis under pjit) ----------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = _constrain(h, P("model", None, None))
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = _constrain(y, P("model", None, None))

    # ---- combine ---------------------------------------------------------------------
    gathered = y[e_idx, c_idx]                        # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((T, D), dtype=jnp.float32)
    out = out.at[st].add(gathered.astype(jnp.float32) * sw[:, None])
    aux = load_balance_loss(logits, experts, E)
    return out.astype(x.dtype), aux


__all__ = ["moe_ffn", "router_topk", "load_balance_loss"]
