"""Decoder-only LM supporting the dense / GQA / SWA / local-global / MoE variants
of the zoo, built as pure functions over a scanned, stacked-parameter block stack.

Key properties:
* ``lax.scan`` over layers keeps HLO size O(1) in depth (fast 512-device compiles);
* prefill attention streams over query chunks (blockwise softmax) above
  ``STREAM_THRESHOLD`` so 32k-token prefill never materializes an (S, S) tensor;
* decode uses a preallocated KV cache with position-masked single-token attention;
* per-layer heterogeneity (local vs global attention) is expressed as a scanned
  boolean so the stack stays homogeneous.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.attention import sdpa
from repro.models.common import (
    ModelConfig, apply_rope, gated_mlp, init_dense, rms_norm, rope_tables,
)
from repro.models.moe import moe_ffn
from repro.serving import kvcache

STREAM_THRESHOLD = 4096
STREAM_CHUNK = 512


# ---------------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------------

def init_block_params(rng, cfg: ModelConfig):
    """One transformer block; leaves later get a leading L dim via vmap."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 10)
    p = {
        "ln1": jnp.ones((d,), cfg.dtype),
        "ln2": jnp.ones((d,), cfg.dtype),
        "wq": init_dense(ks[0], (d, Hq * hd), cfg.dtype),
        "wk": init_dense(ks[1], (d, Hkv * hd), cfg.dtype),
        "wv": init_dense(ks[2], (d, Hkv * hd), cfg.dtype),
        "wo": init_dense(ks[3], (Hq * hd, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), cfg.dtype)
    if cfg.moe:
        m = cfg.moe
        p["moe"] = {
            "router": init_dense(ks[4], (d, m.n_experts), jnp.float32),
            "w_gate": init_dense(ks[5], (m.n_experts, d, m.d_expert), cfg.dtype),
            "w_up": init_dense(ks[6], (m.n_experts, d, m.d_expert), cfg.dtype),
            "w_down": init_dense(ks[7], (m.n_experts, m.d_expert, d), cfg.dtype,
                                 scale=m.d_expert ** -0.5),
        }
    else:
        p["mlp"] = {
            "w_gate": init_dense(ks[4], (d, cfg.d_ff), cfg.dtype),
            "w_up": init_dense(ks[5], (d, cfg.d_ff), cfg.dtype),
            "w_down": init_dense(ks[6], (cfg.d_ff, d), cfg.dtype,
                                 scale=cfg.d_ff ** -0.5),
        }
    return p


def init_params(rng, cfg: ModelConfig):
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda k: init_block_params(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": init_dense(k_embed, (cfg.vocab, cfg.d_model), cfg.dtype, scale=0.02),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, (cfg.d_model, cfg.vocab), cfg.dtype)
    return params


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32: -1 = full/global attention, else SWA width for that layer."""
    L = cfg.n_layers
    if cfg.global_every:
        w = cfg.window or 1024
        return jnp.array(
            [-1 if (i % cfg.global_every == cfg.global_every - 1) else w
             for i in range(L)], dtype=jnp.int32)
    if cfg.window:
        return jnp.full((L,), cfg.window, dtype=jnp.int32)
    return jnp.full((L,), -1, dtype=jnp.int32)


# ---------------------------------------------------------------------------------
# attention with streaming prefill
# ---------------------------------------------------------------------------------

def _stream_attention(q, k, v, window: jax.Array, q_offset: int = 0):
    """Blockwise-softmax causal attention, O(S * chunk) memory.

    q: (B, S, Hq, D); window: scalar int32 (-1 = unlimited).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    nq = S // STREAM_CHUNK
    qc = q.reshape(B, nq, STREAM_CHUNK, Hq, D).transpose(1, 0, 2, 3, 4)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    k_pos = jnp.arange(k.shape[1])

    def chunk_fn(_, qi_i):
        qi, i = qi_i
        qf = qi.astype(jnp.float32) * (D ** -0.5)
        qf = qf.reshape(B, STREAM_CHUNK, Hkv, group, D)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
        q_pos = i * STREAM_CHUNK + jnp.arange(STREAM_CHUNK) + q_offset
        m = k_pos[None, :] <= q_pos[:, None]
        m &= jnp.where(window > 0, k_pos[None, :] > q_pos[:, None] - window, True)
        logits = jnp.where(m[None, None, None], logits, attn_mod.NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, vf)
        return None, out.reshape(B, STREAM_CHUNK, Hq, D).astype(qi.dtype)

    _, outs = jax.lax.scan(chunk_fn, None, (qc, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, D)


def _prefill_attention(q, k, v, window: jax.Array, use_kernel: bool):
    S = q.shape[1]
    if use_kernel:
        from repro.kernels.flash_attention.ops import flash_attention_dyn
        return flash_attention_dyn(q, k, v, window)
    if S > STREAM_THRESHOLD and S % STREAM_CHUNK == 0:
        return _stream_attention(q, k, v, window)
    mask = attn_mod.attention_mask(S, S, causal=True, window=None)
    k_pos = jnp.arange(S)
    wmask = jnp.where(window > 0,
                      k_pos[None, :] > k_pos[:, None] - window, True)
    return sdpa(q, k, v, mask & wmask)


# ---------------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------------

def _project_qkv(x, bp, cfg: ModelConfig):
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    q = x @ bp["wq"]
    k = x @ bp["wk"]
    v = x @ bp["wv"]
    if cfg.qkv_bias:
        q = q + bp["bq"]
        k = k + bp["bk"]
        v = v + bp["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _ffn(h, bp, cfg: ModelConfig):
    if cfg.moe:
        import os
        from repro.distributed import moe_ep
        mesh = moe_ep.get_ep_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and os.environ.get("REPRO_MOE_EP", "1") == "1":
            return moe_ep.moe_ffn_ep(h, bp["moe"], cfg.moe, mesh)
        B, S, d = h.shape
        out, aux = moe_ffn(h.reshape(B * S, d), bp["moe"], cfg.moe)
        return out.reshape(B, S, d), aux
    return gated_mlp(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"]), 0.0


def block_forward(x, bp, window, cos, sin, cfg: ModelConfig, use_kernel: bool):
    """Training / prefill block: x (B, S, d)."""
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(h, bp, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = _prefill_attention(q, k, v, window, use_kernel)
    x = x + o.reshape(*x.shape[:2], -1) @ bp["wo"]
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, aux = _ffn(h, bp, cfg)
    return x + f, (k, v), aux


def block_decode(x, bp, window, cache_k, cache_v, pos, cos, sin, cfg: ModelConfig,
                 cache_ks=None, cache_vs=None, block_table=None,
                 use_kernel: bool = False):
    """One-token decode.  x: (B, 1, d).

    KV storage sits behind the cache-ops interface (`repro.serving.kvcache`):
    dense caches are (B, S_max, Hkv, hd) with ``pos`` a scalar (uniform batch)
    or (B,) vector (continuous batching); with ``block_table`` (B, n_pages)
    the caches are paged pools (P, page_size, Hkv, hd) shared by all rows, and
    the new token scatters into the row's current page.
    ``cache_ks/vs``: per-token/head int8 scales when kv_cache_dtype == int8."""
    int8_kv = cache_ks is not None
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(h, bp, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if int8_kv:
        k_store, k_sc = _kv_quantize(k)
        v_store, v_sc = _kv_quantize(v)
    else:
        k_store, v_store = k, v
    if block_table is not None:
        ops = kvcache.PagedOps(block_table)
    elif jnp.ndim(pos) == 1:
        ops = kvcache.DenseVectorOps()
    else:
        ops = kvcache.DenseScalarOps()
    cache_k = ops.write(cache_k, k_store, pos)
    cache_v = ops.write(cache_v, v_store, pos)
    if int8_kv:
        cache_ks = ops.write(cache_ks, k_sc, pos)
        cache_vs = ops.write(cache_vs, v_sc, pos)
    if block_table is not None and use_kernel:
        # Pallas path: attend over the page pool directly, no gather; int8
        # pools carry their scales into the kernel and dequantize in-register
        from repro.kernels.decode_attention.ops import decode_attention_paged
        o = decode_attention_paged(q, cache_k, cache_v, block_table, pos + 1,
                                   window=window,
                                   k_scale=cache_ks if int8_kv else None,
                                   v_scale=cache_vs if int8_kv else None)
    else:
        k_eff = ops.view(cache_k)
        v_eff = ops.view(cache_v)
        if int8_kv:
            k_eff = _kv_dequantize(k_eff, ops.view(cache_ks), cfg.dtype)
            v_eff = _kv_dequantize(v_eff, ops.view(cache_vs), cfg.dtype)
        mask = ops.mask(k_eff.shape[1], pos, window)
        o = sdpa(q, k_eff, v_eff, mask)
    x = x + o.reshape(*x.shape[:2], -1) @ bp["wo"]
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn(h, bp, cfg)
    return x + f, (cache_k, cache_v, cache_ks, cache_vs)


def block_verify(x, bp, window, cache_k, cache_v, pos, cos, sin, cfg: ModelConfig,
                 cache_ks=None, cache_vs=None, block_table=None,
                 use_kernel: bool = False):
    """Span decode: x (B, T, d), each row's T tokens at consecutive logical
    positions starting at ``pos[b]``.

    This is the mixed chunked-prefill / speculative-verify block: the span's
    KV is scattered into the paged pool first (so query t attends its own
    key), then per-query causal attention runs over the row's pages -- via
    the mixed Pallas kernel or the gather + span-mask route.  T = 1 is
    exactly :func:`block_decode`."""
    int8_kv = cache_ks is not None
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(h, bp, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if int8_kv:
        k_store, k_sc = _kv_quantize(k)
        v_store, v_sc = _kv_quantize(v)
    else:
        k_store, v_store = k, v
    ops = kvcache.PagedOps(block_table)
    cache_k = ops.write_span(cache_k, k_store, pos)
    cache_v = ops.write_span(cache_v, v_store, pos)
    if int8_kv:
        cache_ks = ops.write_span(cache_ks, k_sc, pos)
        cache_vs = ops.write_span(cache_vs, v_sc, pos)
    if use_kernel:
        from repro.kernels.decode_attention.ops import decode_attention_mixed
        o = decode_attention_mixed(q, cache_k, cache_v, block_table, pos,
                                   window=window,
                                   k_scale=cache_ks if int8_kv else None,
                                   v_scale=cache_vs if int8_kv else None)
    else:
        k_eff = ops.view(cache_k)
        v_eff = ops.view(cache_v)
        if int8_kv:
            k_eff = _kv_dequantize(k_eff, ops.view(cache_ks), cfg.dtype)
            v_eff = _kv_dequantize(v_eff, ops.view(cache_vs), cfg.dtype)
        mask = ops.span_mask(k_eff.shape[1], pos, q.shape[1], window)
        o = sdpa(q, k_eff, v_eff, mask)
    x = x + o.reshape(*x.shape[:2], -1) @ bp["wo"]
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn(h, bp, cfg)
    return x + f, (cache_k, cache_v, cache_ks, cache_vs)


# ---------------------------------------------------------------------------------
# model-level functions
# ---------------------------------------------------------------------------------

def _embed_in(params, batch, cfg: ModelConfig):
    if cfg.input_mode == "embeddings":
        return batch["embeds"].astype(cfg.dtype)
    return params["embed"][batch["tokens"]]


def _lm_head(params, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ w).astype(jnp.float32)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# replint: traced -- jitted from the serving engine
def forward(params, batch, cfg: ModelConfig, *, use_kernel: bool = False):
    """Full-sequence forward -> (logits (B,S,V) f32, aux)."""
    x = _embed_in(params, batch, cfg)
    B, S, _ = x.shape
    cos, sin = rope_tables(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)
    windows = layer_windows(cfg)

    def body(x, layer):
        bp, w = layer
        x, _, aux = block_forward(x, bp, w, cos, sin, cfg, use_kernel)
        return x, aux

    body = _remat(body, cfg)
    x, auxs = jax.lax.scan(body, x, (params["blocks"], windows))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg), jnp.sum(auxs)


# replint: traced -- jitted from the serving engine
def loss_fn(params, batch, cfg: ModelConfig, *, use_kernel: bool = False):
    logits, aux = forward(params, batch, cfg, use_kernel=use_kernel)
    tgt = batch["targets"]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(logp, tgt[:, 1:, None], axis=-1)[..., 0]
    mask = (tgt[:, 1:] >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def _kv_quantize(x):
    """x: (..., hd) -> (int8 values, f32 scale over the last dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1] + (1,)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
    }


# replint: traced -- jitted from the serving engine
def prefill(params, batch, cfg: ModelConfig, max_len: int | None = None,
            *, use_kernel: bool = False, last_idx=None):
    """Run the prompt, return (last-position logits, cache dict).

    ``last_idx``: traced position of the true last prompt token -- a scalar,
    or a (B,) vector for batched bucketed prefill (each row selects its own
    last position).  Bucketed prefill pads prompts to a fixed power-of-two
    length so one compiled shape serves the whole bucket; the causal mask
    keeps positions <= last_idx independent of the padding, and ``last_idx``
    selects the real logits."""
    x = _embed_in(params, batch, cfg)
    B, S, _ = x.shape
    max_len = max_len or S
    cos, sin = rope_tables(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)
    windows = layer_windows(cfg)

    def body(x, layer):
        bp, w = layer
        x, (k, v), _ = block_forward(x, bp, w, cos, sin, cfg, use_kernel)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if last_idx is None:
        x_last = x[:, -1:]
    elif jnp.ndim(last_idx) == 0:
        x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    else:
        x_last = x[jnp.arange(B), last_idx][:, None]          # per-row select
    logits = _lm_head(params, x_last, cfg)
    if max_len > S:
        pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    if cfg.kv_cache_dtype == "int8":
        kq, ksc = _kv_quantize(ks)
        vq, vsc = _kv_quantize(vs)
        return logits, {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    return logits, {"k": ks.astype(cfg.dtype), "v": vs.astype(cfg.dtype)}


# replint: traced -- jitted from the serving engine
def decode_step(params, cache, token, pos, cfg: ModelConfig, *,
                block_table=None, use_kernel: bool = False):
    """token: (B, 1) int32 (or (B,1,d) embeds); pos: scalar int32 count of
    cached tokens, or (B,) per-row counts (continuous batching).  With
    ``block_table`` (B, n_pages) the cache leaves are paged pools
    (L, P, page_size, ...) -- see `repro.serving.kvcache`.  Returns
    (logits (B,1,V), new cache)."""
    if cfg.input_mode == "embeddings" and token.ndim == 3:
        x = token.astype(cfg.dtype)
    else:
        x = params["embed"][token]
    if jnp.ndim(pos) == 1:
        cos, sin = rope_tables(pos[:, None], cfg.resolved_head_dim, cfg.rope_theta)
    else:
        cos, sin = rope_tables(jnp.array([pos]), cfg.resolved_head_dim, cfg.rope_theta)
    windows = layer_windows(cfg)

    int8_kv = cfg.kv_cache_dtype == "int8"

    def body(x, layer):
        if int8_kv:
            bp, w, ck, cv, cks, cvs = layer
        else:
            bp, w, ck, cv = layer
            cks = cvs = None
        x, (ck, cv, cks, cvs) = block_decode(x, bp, w, ck, cv, pos, cos, sin, cfg,
                                             cache_ks=cks, cache_vs=cvs,
                                             block_table=block_table,
                                             use_kernel=use_kernel)
        return x, ((ck, cv, cks, cvs) if int8_kv else (ck, cv))

    if int8_kv:
        x, (ks, vs, kss, vss) = jax.lax.scan(
            body, x, (params["blocks"], windows, cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
    else:
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows,
                                             cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg), new_cache


# replint: traced -- jitted from the serving engine mixed step
def verify_step(params, cache, tokens, pos, cfg: ModelConfig, *,
                block_table, use_kernel: bool = False,
                lmhead_kernel: bool = False, lmhead_block_v: int = 0):
    """Score a T-token span per row in one forward: tokens (B, T) int32 at
    logical positions ``pos[b] + t`` over a paged cache.

    Returns ``(tok (B, T) int32, lp (B, T) f32, new_cache)``: the greedy
    next token and its logprob *after each span position*, computed through
    the fused lm-head epilogue so the (B, T, V) logits tensor is never
    materialized.  One function serves every mixed-step role:

    * decode row (T == 1): ``tok[:, 0]`` is the next token -- identical to
      ``decode_step`` + ``greedy_epilogue``;
    * speculative verify (T == 1 + d): ``tok[:, j]`` is the model's true
      output after draft j, giving the acceptance rule its oracle;
    * prefill chunk: the span's KV is committed, ``tok[:, -1]`` seeds
      decode when the chunk is the prompt's last.
    """
    from repro.kernels.sampling.ops import fused_lmhead_greedy
    x = params["embed"][tokens]
    T = tokens.shape[1]
    cos, sin = rope_tables(pos[:, None] + jnp.arange(T)[None, :],
                           cfg.resolved_head_dim, cfg.rope_theta)
    windows = layer_windows(cfg)
    int8_kv = cfg.kv_cache_dtype == "int8"

    def body(x, layer):
        if int8_kv:
            bp, w, ck, cv, cks, cvs = layer
        else:
            bp, w, ck, cv = layer
            cks = cvs = None
        x, (ck, cv, cks, cvs) = block_verify(x, bp, w, ck, cv, pos, cos, sin,
                                             cfg, cache_ks=cks, cache_vs=cvs,
                                             block_table=block_table,
                                             use_kernel=use_kernel)
        return x, ((ck, cv, cks, cvs) if int8_kv else (ck, cv))

    if int8_kv:
        x, (ks, vs, kss, vss) = jax.lax.scan(
            body, x, (params["blocks"], windows, cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
    else:
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows,
                                             cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    w_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    tok, lp = fused_lmhead_greedy(x, w_head, use_kernel=lmhead_kernel,
                                  block_v=lmhead_block_v)
    return tok, lp, new_cache


__all__ = [
    "init_params", "forward", "loss_fn", "prefill", "decode_step", "init_cache",
    "verify_step", "layer_windows", "block_forward", "block_decode",
    "block_verify",
]
