"""Jit-ready wrapper for the SSD intra-chunk kernel (model-layout adapter)."""
from __future__ import annotations

import jax

from repro.kernels.ssd.kernel import ssd_intra_fwd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


# replint: traced -- jitted from the serving engine
def ssd_intra(xb, acs, Bh, Ch):
    """Model layout: xb (b, nc, q, h, p); acs (b, nc, q, h); Bh/Ch (b, nc, q, h, n).

    Returns y_intra (b, nc, q, h, p) fp32.
    """
    b, nc, q, h, p = xb.shape
    flat = lambda a: a.reshape((b * nc,) + a.shape[2:])
    y = ssd_intra_fwd(flat(xb), flat(acs), flat(Bh), flat(Ch),
                      interpret=_interpret())
    return y.reshape(b, nc, q, h, p)


__all__ = ["ssd_intra"]
