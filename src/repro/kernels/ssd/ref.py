"""Pure-jnp oracle for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_ref(xb, acs, Bh, Ch):
    """xb: (bc,q,h,p); acs: (bc,q,h); Bh/Ch: (bc,q,h,n) -> (bc,q,h,p) fp32."""
    q = xb.shape[1]
    diff = acs[:, :, None, :] - acs[:, None, :, :]          # (bc,t,u,h)
    tri = jnp.tril(jnp.ones((q, q), dtype=bool))
    L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bthn,buhn->btuh", Ch, Bh)
    return jnp.einsum("btuh,btuh,buhp->bthp", scores, L, xb.astype(jnp.float32))
