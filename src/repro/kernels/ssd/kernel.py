"""Mamba-2 SSD intra-chunk kernel (Pallas TPU).

The intra-chunk term of the SSD duality is, per (batch, chunk, head):

    scores = C B^T                (q x n @ n x q  -> MXU)
    L      = tril(exp(acs_t - acs_u))
    y      = (scores * L) @ x     (q x q @ q x p  -> MXU)

which is three MXU ops + a VPU mask per grid cell -- exactly the shape of work
the TPU wants, replacing the CUDA selective-scan's warp shuffles.  Grid:
(batch * n_chunks, heads); all operands for one (chunk, head) fit easily in
VMEM (chunk<=256, state n<=128, head dim p<=64 => < 1 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, acs_ref, b_ref, c_ref, o_ref):
    x = x_ref[0, :, 0].astype(jnp.float32)        # (q, p)
    acs = acs_ref[0, :, 0].astype(jnp.float32)    # (q,)
    B = b_ref[0, :, 0].astype(jnp.float32)        # (q, n)
    C = c_ref[0, :, 0].astype(jnp.float32)        # (q, n)
    q = x.shape[0]
    scores = C @ B.T                              # (q, q)
    t = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    u = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(t >= u, jnp.exp(acs[:, None] - acs[None, :]), 0.0)
    y = (scores * L) @ x                          # (q, p)
    o_ref[0, :, 0] = y.astype(o_ref.dtype)


def ssd_intra_fwd(xb, acs, Bh, Ch, *, interpret: bool = False):
    """Intra-chunk SSD.

    xb:  (bc, q, h, p) fp32   (batch*chunks flattened)
    acs: (bc, q, h)    fp32   cumulative log-decay within chunk
    Bh:  (bc, q, h, n) fp32
    Ch:  (bc, q, h, n) fp32
    Returns y_intra: (bc, q, h, p) fp32.
    """
    bc, q, h, p = xb.shape
    n = Bh.shape[-1]
    grid = (bc, h)
    return pl.pallas_call(
        functools.partial(_ssd_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, q, 1), lambda b, hh: (b, 0, hh)),
            pl.BlockSpec((1, q, 1, n), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, q, 1, n), lambda b, hh: (b, 0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, p), lambda b, hh: (b, 0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
        interpret=interpret,
    )(xb, acs, Bh, Ch)
