"""Flash-decoding for TPU: single-token attention over a long KV cache.

The CUDA flash-decoding trick (split-K across SMs + cross-SM reduction) maps to
TPU as a sequential KV-block grid dimension with fp32 VMEM scratch carrying the
running (max, sum, acc) -- the sequential grid is free on TPU since blocks
stream through VMEM anyway; the win is never materializing (Hq, S) logits in
HBM and reading K/V exactly once.

The valid cache length ``pos`` and the window are scalar-prefetch operands, so
the same compiled kernel serves every decode step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(s_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, block_k: int, group: int, sm_scale: float):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    pos = s_ref[0]        # number of valid cache entries (incl. current token)
    window = s_ref[1]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k
    live = k_start < pos
    live &= jnp.where(window > 0, k_start + block_k - 1 >= pos - window, True)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale           # (Hq, d)
        k = k_ref[0].astype(jnp.float32)                      # (bk, Hkv, d)
        v = v_ref[0].astype(jnp.float32)
        # GQA: logits[h, t] = q[h] . k[t, h // group]
        kr = jnp.repeat(k, group, axis=1)                     # (bk, Hq, d)
        s = jnp.einsum("hd,thd->ht", q, kr)                   # (Hq, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos < pos
        valid &= jnp.where(window > 0, k_pos >= pos - window, True)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        vr = jnp.repeat(v, group, axis=1)                     # (bk, Hq, d)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("ht,thd->hd", p, vr)
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q, k_cache, v_cache, scalars, *, block_k: int = 1024,
                         interpret: bool = False):
    """q: (B, Hq, D); caches: (B, S, Hkv, D); scalars: (2,) int32 [pos, window].

    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    block_k = min(block_k, S)
    nk = pl.cdiv(S, block_k)

    kernel = functools.partial(_dec_kernel, block_k=block_k, group=group,
                               sm_scale=D ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, ki, s: (b, 0, 0)),
            pl.BlockSpec((1, block_k, Hkv, D), lambda b, ki, s: (b, ki, 0, 0)),
            pl.BlockSpec((1, block_k, Hkv, D), lambda b, ki, s: (b, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, ki, s: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(scalars, q, k_cache, v_cache)


def _paged_dec_kernel(tbl_ref, len_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                      page_size: int, group: int, sm_scale: float,
                      int8: bool = False):
    """Block-table flash-decoding: grid (B, n_pages); iteration ``pi`` streams
    the page ``tbl_ref[b, pi]`` holding logical positions
    [pi*ps, (pi+1)*ps) of row b.  The block table is a scalar-prefetch
    operand, so the page DMA address is computed before the body runs --
    the same compiled kernel serves every decode step and every slot mix.

    With ``int8=True`` two extra page-pool refs carry the per-token/head f32
    scales and K/V are dequantized in-register after the page DMA -- the int8
    pool is what streams through VMEM, so the HBM traffic stays halved."""
    if int8:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    pi = pl.program_id(1)
    npg = pl.num_programs(1)
    length = len_ref[b]     # valid logical entries for this row (incl. current)
    window = win_ref[0]

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = pi * page_size
    live = k_start < length
    live &= jnp.where(window > 0, k_start + page_size - 1 >= length - window, True)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale           # (Hq, d)
        k = k_ref[0].astype(jnp.float32)                      # (ps, Hkv, d)
        v = v_ref[0].astype(jnp.float32)
        if int8:
            k = k * ks_ref[0]                                 # (ps, Hkv, 1)
            v = v * vs_ref[0]
        kr = jnp.repeat(k, group, axis=1)                     # (ps, Hq, d)
        s = jnp.einsum("hd,thd->ht", q, kr)                   # (Hq, ps)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos < length
        valid &= jnp.where(window > 0, k_pos >= length - window, True)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        vr = jnp.repeat(v, group, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("ht,thd->hd", p, vr)
        m_scr[...] = m_cur

    @pl.when(pi == npg - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _paged_mixed_kernel(tbl_ref, start_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                        page_size: int, group: int, sm_scale: float,
                        q_len: int, int8: bool = False):
    """Mixed-span block-table flash attention: each row carries ``q_len``
    queries at consecutive logical positions ``start[b] + t`` -- prefill
    chunks, speculative verify blocks, and plain decode (q_len == 1) are the
    same kernel.  Query ``t`` attends keys ``k <= start[b] + t`` (per-query
    causal), minus the sliding window; the T = 1 slice reduces exactly to
    :func:`_paged_dec_kernel` with ``length = start + 1``."""
    if int8:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    pi = pl.program_id(1)
    npg = pl.num_programs(1)
    start = start_ref[b]    # logical position of this row's first query
    window = win_ref[0]

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = pi * page_size
    # the page is live if ANY query can see ANY of its keys; per-query
    # masking below handles the rest
    live = k_start < start + q_len
    live &= jnp.where(window > 0, k_start + page_size - 1 >= start + 1 - window,
                      True)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale           # (T, Hq, d)
        k = k_ref[0].astype(jnp.float32)                      # (ps, Hkv, d)
        v = v_ref[0].astype(jnp.float32)
        if int8:
            k = k * ks_ref[0]                                 # (ps, Hkv, 1)
            v = v * vs_ref[0]
        kr = jnp.repeat(k, group, axis=1)                     # (ps, Hq, d)
        s = jnp.einsum("thd,phd->thp", q, kr)                 # (T, Hq, ps)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        q_pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        valid = k_pos <= q_pos
        valid &= jnp.where(window > 0, k_pos > q_pos - window, True)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]                                   # (T, Hq)
        m_cur = jnp.maximum(m_prev, s.max(axis=2))
        alpha = jnp.exp(m_prev - m_cur)
        # explicit zero where invalid: a query whose window starts past this
        # whole (block-live) page still has m == NEG_INF, and exp(s - m)
        # would be exp(0) garbage for its masked lanes
        p = jnp.where(valid, jnp.exp(s - m_cur[:, :, None]), 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=2)
        vr = jnp.repeat(v, group, axis=1)                     # (ps, Hq, d)
        acc_scr[...] = (acc_scr[...] * alpha[:, :, None]
                        + jnp.einsum("thp,phd->thd", p, vr))
        m_scr[...] = m_cur

    @pl.when(pi == npg - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, :, None]).astype(o_ref.dtype)


def paged_mixed_attention_fwd(q, k_pages, v_pages, block_table, starts,
                              window, *, k_scale=None, v_scale=None,
                              interpret: bool = False):
    """q: (B, T, Hq, D) -- T queries per row at logical positions
    ``starts[b] + t``; pages: (P, page_size, Hkv, D); block_table: (B, n)
    int32; starts: (B,) int32; window: (1,) int32, -1 = unlimited.

    Per-query causal attention over each row's own pages; the KV for the
    span itself must already be written (query t attends its own key).
    Returns (B, T, Hq, D).
    """
    B, T, Hq, D = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    n_pages = block_table.shape[1]
    group = Hq // Hkv
    int8 = k_scale is not None

    kernel = functools.partial(_paged_mixed_kernel, page_size=page_size,
                               group=group, sm_scale=D ** -0.5, q_len=T,
                               int8=int8)
    page_spec = pl.BlockSpec((1, page_size, Hkv, D),
                             lambda b, pi, tbl, st, win: (tbl[b, pi], 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, T, Hq, D), lambda b, pi, tbl, st, win: (b, 0, 0, 0)),
        page_spec,
        page_spec,
    ]
    inputs = [q, k_pages, v_pages]
    if int8:
        scale_spec = pl.BlockSpec(
            (1, page_size, Hkv, 1),
            lambda b, pi, tbl, st, win: (tbl[b, pi], 0, 0, 0))
        in_specs += [scale_spec, scale_spec]
        inputs += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, T, Hq, D),
                               lambda b, pi, tbl, st, win: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T, Hq), jnp.float32),
            pltpu.VMEM((T, Hq), jnp.float32),
            pltpu.VMEM((T, Hq, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, Hq, D), q.dtype),
        interpret=interpret,
    )(block_table, starts, window, *inputs)


def paged_decode_attention_fwd(q, k_pages, v_pages, block_table, lengths,
                               window, *, k_scale=None, v_scale=None,
                               interpret: bool = False):
    """q: (B, Hq, D); pages: (P, page_size, Hkv, D); block_table: (B, n) int32;
    lengths: (B,) int32 valid logical entries per row (incl. the current
    token); window: (1,) int32, -1 = unlimited.

    ``k_scale``/``v_scale``: optional (P, page_size, Hkv, 1) f32 pools for
    int8 pages -- when given, K/V pages are dequantized inside the kernel
    (the int8 KV path no longer falls back to the jnp gather route).

    Returns (B, Hq, D).  Rows attend only to their own pages; table entries
    past a row's live pages may point anywhere (trash page) -- those grid
    steps are masked dead by ``lengths``.
    """
    B, Hq, D = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    n_pages = block_table.shape[1]
    group = Hq // Hkv
    int8 = k_scale is not None

    kernel = functools.partial(_paged_dec_kernel, page_size=page_size,
                               group=group, sm_scale=D ** -0.5, int8=int8)
    page_spec = pl.BlockSpec((1, page_size, Hkv, D),
                             lambda b, pi, tbl, lens, win: (tbl[b, pi], 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, Hq, D), lambda b, pi, tbl, lens, win: (b, 0, 0)),
        page_spec,
        page_spec,
    ]
    inputs = [q, k_pages, v_pages]
    if int8:
        scale_spec = pl.BlockSpec(
            (1, page_size, Hkv, 1),
            lambda b, pi, tbl, lens, win: (tbl[b, pi], 0, 0, 0))
        in_specs += [scale_spec, scale_spec]
        inputs += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, pi, tbl, lens, win: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
    )
    out_dtype = q.dtype
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), out_dtype),
        interpret=interpret,
    )(block_table, lengths, window, *inputs)
