"""Jit-ready wrapper for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_fwd,
    paged_decode_attention_fwd,
    paged_mixed_attention_fwd,
)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


# replint: traced -- jitted from the serving engine
def decode_attention(q1, k_cache, v_cache, pos, *, window: int | None = None,
                     block_k: int | None = None):
    """q1: (B, 1, Hq, D); caches: (B, S, Hkv, D); pos: scalar int32 valid length.

    ``block_k=None`` resolves the autotuned per-backend default
    (`repro.kernels.decode_attention.autotune`).  Returns (B, 1, Hq, D).
    """
    if block_k is None:
        from repro.kernels.decode_attention.autotune import default_block_k
        block_k = default_block_k()
    scalars = jnp.stack([jnp.asarray(pos, jnp.int32),
                         jnp.asarray(window if window else -1, jnp.int32)])
    out = decode_attention_fwd(q1[:, 0], k_cache, v_cache, scalars,
                               block_k=block_k, interpret=_interpret())
    return out[:, None]


# replint: traced -- jitted from the serving engine
def decode_attention_paged(q1, k_pages, v_pages, block_table, lengths, *,
                           window=None, k_scale=None, v_scale=None):
    """Block-table decode attention over a paged KV pool.

    q1: (B, 1, Hq, D); pages: (P, page_size, Hkv, D); block_table: (B, n)
    int32 (logical page i of row b lives in physical page block_table[b, i]);
    lengths: (B,) valid logical entries per row, including the current token.
    ``window`` may be a python int/None or a traced int32 scalar (-1 / None =
    unlimited), so the call sites inside a scanned layer stack can pass the
    per-layer window.  ``k_scale``/``v_scale``: (P, page_size, Hkv, 1) f32
    pools for int8 pages (dequantized in-kernel).  Returns (B, 1, Hq, D).
    """
    win = jnp.reshape(jnp.asarray(-1 if window is None else window, jnp.int32),
                      (1,))
    out = paged_decode_attention_fwd(
        q1[:, 0], k_pages, v_pages, jnp.asarray(block_table, jnp.int32),
        jnp.asarray(lengths, jnp.int32), win, k_scale=k_scale, v_scale=v_scale,
        interpret=_interpret())
    return out[:, None]


# replint: traced -- jitted from the serving engine mixed step
def decode_attention_mixed(q, k_pages, v_pages, block_table, starts, *,
                           window=None, k_scale=None, v_scale=None):
    """Mixed-span block-table attention over a paged KV pool.

    q: (B, T, Hq, D) -- T consecutive queries per row, the first at logical
    position ``starts[b]`` (so a decode row has T == 1 and
    ``starts == pos``, a prefill chunk has T == chunk_size, a speculative
    verify block T == 1 + draft_len); pages / block_table / scales as in
    :func:`decode_attention_paged`.  The span's own KV must be written
    before the call.  Returns (B, T, Hq, D).
    """
    win = jnp.reshape(jnp.asarray(-1 if window is None else window, jnp.int32),
                      (1,))
    return paged_mixed_attention_fwd(
        q, k_pages, v_pages, jnp.asarray(block_table, jnp.int32),
        jnp.asarray(starts, jnp.int32), win, k_scale=k_scale, v_scale=v_scale,
        interpret=_interpret())


__all__ = ["decode_attention", "decode_attention_paged",
           "decode_attention_mixed"]
