"""Jit-ready wrapper for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_fwd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def decode_attention(q1, k_cache, v_cache, pos, *, window: int | None = None,
                     block_k: int = 1024):
    """q1: (B, 1, Hq, D); caches: (B, S, Hkv, D); pos: scalar int32 valid length.

    Returns (B, 1, Hq, D).
    """
    scalars = jnp.stack([jnp.asarray(pos, jnp.int32),
                         jnp.asarray(window if window else -1, jnp.int32)])
    out = decode_attention_fwd(q1[:, 0], k_cache, v_cache, scalars,
                               block_k=block_k, interpret=_interpret())
    return out[:, None]


__all__ = ["decode_attention"]
