"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, pos: int, window: int | None = None):
    """q: (B, Hq, D); caches: (B, S, Hkv, D).  Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(S)
    valid = k_pos < pos
    if window is not None and window > 0:
        valid &= k_pos >= pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_table, lengths,
                               window: int | None = None):
    """Gather-over-pages oracle for the paged kernel.

    q: (B, Hq, D); pages: (P, ps, Hkv, D); block_table: (B, n) int32;
    lengths: (B,) valid logical entries per row.  Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    P, ps, Hkv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    n = block_table.shape[1]
    idx = (block_table[:, :, None] * ps + jnp.arange(ps)[None, None, :]
           ).reshape(B, n * ps)
    k = k_pages.reshape(P * ps, Hkv, D)[idx]                  # (B, S, Hkv, D)
    v = v_pages.reshape(P * ps, Hkv, D)[idx]
    group = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    k_pos = jnp.arange(n * ps)
    valid = k_pos[None, :] < lengths[:, None]                 # (B, S)
    if window is not None and window > 0:
        valid &= k_pos[None, :] >= lengths[:, None] - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def paged_decode_attention_int8_ref(q, k_pages, v_pages, k_scale, v_scale,
                                    block_table, lengths,
                                    window: int | None = None):
    """Int8 oracle: dequantize the whole pool, then the fp gather path --
    the route the int8 engine used before the kernel learned int8 pages."""
    kf = k_pages.astype(jnp.float32) * k_scale
    vf = v_pages.astype(jnp.float32) * v_scale
    return paged_decode_attention_ref(q, kf, vf, block_table, lengths, window)
