"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, pos: int, window: int | None = None):
    """q: (B, Hq, D); caches: (B, S, Hkv, D).  Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(S)
    valid = k_pos < pos
    if window is not None and window > 0:
        valid &= k_pos >= pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
