"""Page-size / block-k autotuning for the decode-attention tier.

The paged decode kernel's only tile knob is the page size (one grid step
streams one page), and the dense flash-decoding kernel's is ``block_k``.
Neither has a universally best value: bigger pages amortize DMA issue and
grid overhead but waste bandwidth on partially filled last pages and shrink
the scheduler's allocation granularity; bigger ``block_k`` does the same for
the dense cache.

``sweep_page_size`` / ``sweep_block_k`` time the decode path the *current
backend actually executes* (CPU: the jitted gather+SDPA route the serving
engine runs; TPU/GPU: the Pallas kernels) and ``pick_defaults`` reduces a
sweep to the fastest configuration.  ``benchmarks/kernels_bench.py`` runs the
sweep and persists it as a JSON artifact; the table below holds the defaults
seeded from those sweeps, and is what :class:`repro.serving.ServeConfig`
resolves when ``page_size`` is left unset.
"""
from __future__ import annotations

import time
from statistics import median

import jax
import jax.numpy as jnp
import numpy as np

#: sweep-seeded defaults per backend (see benchmarks/artifacts/
#: kernels_paged_sweep.json for the data source).  TPU favors 32-token pages:
#: (32, 128) is the f32 minimum tile, so 16-token pages waste half of every
#: sublane; the CPU gather path is page-size-insensitive above 16, where the
#: free-list granularity argument wins.
#: ``chunk_size`` (prefill tokens folded into one mixed step per row),
#: ``draft_len`` (speculative tokens proposed per row per step), and
#: ``lmhead_block_v`` (vocab tile of the fused lm-head epilogue; 0 = single
#: fused matmul, the right call off-TPU) were seeded from the mixed-step
#: sweep (``sweep_span_width``).  Bigger chunks finish prefill in fewer
#: steps but inflate every mixed step's span width (decode rows pay the
#: padding); more drafts amortize the per-step fixed cost but waste
#: verifier FLOPs once the acceptance rate tails off.
DEFAULTS = {
    "cpu": {"page_size": 16, "block_k": 256,
            "chunk_size": 16, "draft_len": 3, "lmhead_block_v": 0},
    "tpu": {"page_size": 32, "block_k": 512,
            "chunk_size": 32, "draft_len": 3, "lmhead_block_v": 2048},
    "gpu": {"page_size": 16, "block_k": 256,
            "chunk_size": 16, "draft_len": 3, "lmhead_block_v": 2048},
}


def backend() -> str:
    return jax.default_backend()


def default_page_size(be: str | None = None) -> int:
    return DEFAULTS.get(be or backend(), DEFAULTS["cpu"])["page_size"]


def default_block_k(be: str | None = None) -> int:
    return DEFAULTS.get(be or backend(), DEFAULTS["cpu"])["block_k"]


def default_chunk_size(be: str | None = None) -> int:
    return DEFAULTS.get(be or backend(), DEFAULTS["cpu"])["chunk_size"]


def default_draft_len(be: str | None = None) -> int:
    return DEFAULTS.get(be or backend(), DEFAULTS["cpu"])["draft_len"]


def default_lmhead_block_v(be: str | None = None) -> int:
    return DEFAULTS.get(be or backend(), DEFAULTS["cpu"])["lmhead_block_v"]


def _time_jitted(fn, *args, reps: int = 10) -> float:
    """Median wall microseconds per call of an already-jitted fn."""
    out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready(), out)   # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(median(ts))


def _paged_inputs(rng, page_size, *, total_tokens, B, Hq, Hkv, D):
    """Same logical workload re-laid-out for each page size."""
    n = max(total_tokens // page_size, 1)
    P = B * n + 2
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k_pages = jax.random.normal(ks[1], (P, page_size, Hkv, D))
    v_pages = jax.random.normal(ks[2], (P, page_size, Hkv, D))
    perm = np.random.default_rng(0).permutation(np.arange(1, P))
    tbl = jnp.asarray(perm[:B * n].reshape(B, n).astype(np.int32))
    lengths = jnp.full((B,), n * page_size, jnp.int32)
    return q, k_pages, v_pages, tbl, lengths


def sweep_page_size(page_sizes=(8, 16, 32, 64), *, total_tokens: int = 256,
                    B: int = 4, Hq: int = 8, Hkv: int = 2, D: int = 64,
                    reps: int = 10) -> list[dict]:
    """Time one paged decode-attention step per page size (fixed logical
    cache length), on the path the current backend serves from."""
    from repro.models.attention import sdpa
    from repro.serving.kvcache import _vector_mask, paged_gather

    rng = jax.random.key(0)
    rows = []
    for ps in page_sizes:
        q, k_pages, v_pages, tbl, lengths = _paged_inputs(
            rng, ps, total_tokens=total_tokens, B=B, Hq=Hq, Hkv=Hkv, D=D)
        if backend() == "cpu":
            # the gather route the CPU engine runs (kernel would interpret)
            def step(q, kp, vp, tbl, lens):
                k = paged_gather(kp, tbl)
                v = paged_gather(vp, tbl)
                mask = _vector_mask(k.shape[1], lens - 1, jnp.int32(-1))
                return sdpa(q, k, v, mask)
        else:
            from repro.kernels.decode_attention.ops import decode_attention_paged

            def step(q, kp, vp, tbl, lens):
                return decode_attention_paged(q, kp, vp, tbl, lens)
        us = _time_jitted(jax.jit(step), q, k_pages, v_pages, tbl, lengths,
                          reps=reps)
        rows.append({"page_size": int(ps), "us_per_step": us,
                     "backend": backend()})
    return rows


def _chunked_decode_ref(q, k_cache, v_cache, pos: int, block_k: int):
    """Blockwise streaming decode attention (the kernel's loop structure in
    jnp): scan KV in ``block_k`` chunks carrying running (max, sum, acc).
    Unlike the one-shot oracle this genuinely depends on block_k, so the
    CPU sweep measures a real chunking tradeoff rather than timing noise."""
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    nk = S // block_k
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kc = k_cache.astype(jnp.float32).reshape(B, nk, block_k, Hkv, D)
    vc = v_cache.astype(jnp.float32).reshape(B, nk, block_k, Hkv, D)

    def chunk(carry, inp):
        m, l, acc = carry
        kb, vb, i = inp                                       # (B, bk, Hkv, D)
        kr = jnp.repeat(kb, group, axis=2)
        s = jnp.einsum("bhd,bthd->bht", qf, kr)               # (B, Hq, bk)
        k_pos = i * block_k + jnp.arange(block_k)
        s = jnp.where(k_pos[None, None, :] < pos, s, -1e30)
        m_cur = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        vr = jnp.repeat(vb, group, axis=2)
        acc = acc * alpha[..., None] + jnp.einsum("bht,bthd->bhd", p, vr)
        return (m_cur, l * alpha + p.sum(axis=-1), acc), None

    init = (jnp.full((B, Hq), -1e30), jnp.zeros((B, Hq)),
            jnp.zeros((B, Hq, D)))
    (m, l, acc), _ = jax.lax.scan(
        chunk, init, (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
                      jnp.arange(nk)))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def sweep_block_k(block_ks=(128, 256, 512, 1024), *, S: int = 1024,
                  B: int = 4, Hq: int = 8, Hkv: int = 2, D: int = 64,
                  reps: int = 10) -> list[dict]:
    """Time one dense flash-decoding step per block_k (CPU times a chunked
    streaming oracle with the kernel's loop structure; TPU/GPU time the
    kernel itself)."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    rows = []
    for bk in block_ks:
        if backend() == "cpu":
            fn = jax.jit(lambda q, k, v, bk=bk: _chunked_decode_ref(
                q[:, 0], k, v, S // 2, block_k=min(bk, S)))
        else:
            from repro.kernels.decode_attention.ops import decode_attention
            fn = jax.jit(lambda q, k, v, bk=bk: decode_attention(
                q, k, v, S // 2, block_k=bk))
        us = _time_jitted(fn, q, kc, vc, reps=reps)
        rows.append({"block_k": int(bk), "us_per_step": us,
                     "backend": backend()})
    return rows


def sweep_span_width(widths=(1, 2, 4, 8, 16, 32), *, total_tokens: int = 256,
                     B: int = 4, Hq: int = 8, Hkv: int = 2, D: int = 64,
                     page_size: int | None = None, reps: int = 10) -> list[dict]:
    """Time one mixed-span attention step per query width T.

    ``us_per_token = us_per_step / T`` is the quantity chunk-size and
    draft-length trade against: a chunk of C tokens costs one T = C mixed
    row-step instead of C decode steps, and a draft of d tokens costs one
    T = d + 1 verify instead of up to d + 1 steps -- but only pays off while
    per-token cost still falls with T.
    """
    from repro.models.attention import sdpa
    from repro.serving.kvcache import _span_mask, paged_gather

    ps = page_size or default_page_size()
    rng = jax.random.key(2)
    rows = []
    for T in widths:
        q1, k_pages, v_pages, tbl, lengths = _paged_inputs(
            rng, ps, total_tokens=total_tokens, B=B, Hq=Hq, Hkv=Hkv, D=D)
        q = jnp.broadcast_to(q1, (B, T, Hq, D))
        starts = lengths - T
        if backend() == "cpu":
            def step(q, kp, vp, tbl, st):
                k = paged_gather(kp, tbl)
                v = paged_gather(vp, tbl)
                mask = _span_mask(k.shape[1], st, q.shape[1], jnp.int32(-1))
                return sdpa(q, k, v, mask)
        else:
            from repro.kernels.decode_attention.ops import decode_attention_mixed

            def step(q, kp, vp, tbl, st):
                return decode_attention_mixed(q, kp, vp, tbl, st)
        us = _time_jitted(jax.jit(step), q, k_pages, v_pages, tbl, starts,
                          reps=reps)
        rows.append({"span_width": int(T), "us_per_step": us,
                     "us_per_token": us / T, "backend": backend()})
    return rows


def pick_defaults(page_rows: list[dict], block_rows: list[dict],
                  span_rows: list[dict] | None = None) -> dict:
    """Reduce sweeps to the fastest configuration (the autotuned default)."""
    best_ps = min(page_rows, key=lambda r: r["us_per_step"])
    best_bk = min(block_rows, key=lambda r: r["us_per_step"])
    out = {"backend": backend(), "page_size": best_ps["page_size"],
           "block_k": best_bk["block_k"]}
    if span_rows:
        # widest span still paying for itself in per-token cost is the chunk
        # size; drafts stop at the knee less one (the verify block is d + 1)
        best_span = min(span_rows, key=lambda r: r["us_per_token"])
        out["chunk_size"] = best_span["span_width"]
        out["draft_len"] = max(best_span["span_width"] - 1, 1)
    return out


__all__ = ["DEFAULTS", "backend", "default_page_size", "default_block_k",
           "default_chunk_size", "default_draft_len", "default_lmhead_block_v",
           "sweep_page_size", "sweep_block_k", "sweep_span_width",
           "pick_defaults"]
