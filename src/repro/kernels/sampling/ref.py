"""Oracle for the fused sampling epilogue: materialize log_softmax, gather."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_epilogue_ref(logits):
    """logits: (B, V) -> (token (B,) int32, logprob (B,) f32) via the full
    normalized log-prob tensor (what the pre-fusion decode epilogue did)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    chosen = jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]
    return tok, chosen


def lmhead_greedy_ref(h, w):
    """h: (..., d); w: (d, V) -> (token, logprob) via the materialized
    logits tensor + full log_softmax (what the fused path must match)."""
    lead = h.shape[:-1]
    logits = h.reshape(-1, h.shape[-1]).astype(jnp.float32) @ w.astype(jnp.float32)
    tok, lp = greedy_epilogue_ref(logits)
    return tok.reshape(lead), lp.reshape(lead)


__all__ = ["greedy_epilogue_ref", "lmhead_greedy_ref"]
