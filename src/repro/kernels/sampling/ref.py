"""Oracle for the fused sampling epilogue: materialize log_softmax, gather."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_epilogue_ref(logits):
    """logits: (B, V) -> (token (B,) int32, logprob (B,) f32) via the full
    normalized log-prob tensor (what the pre-fusion decode epilogue did)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    chosen = jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]
    return tok, chosen


__all__ = ["greedy_epilogue_ref"]
