"""Jit-ready fused sampling epilogue: argmax token + chosen-token logprob.

Two implementations behind one call:

* the pure-jnp fusion (default) -- max / streaming-free logsumexp /
  one-element gather; XLA fuses it into the lm-head matmul's consumer, so no
  normalized (B, V) log-prob tensor is ever written to memory;
* the Pallas streaming kernel (``use_kernel=True``) for the TPU tier, one
  vocab pass through VMEM.

Both are token-exact vs the ``log_softmax`` oracle (``ref.py``); ties break
like ``jnp.argmax`` (first maximal index).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sampling.kernel import greedy_epilogue_fwd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


# replint: traced -- jitted from the serving engine
def greedy_epilogue(logits, *, use_kernel: bool = False, block_v: int = 2048):
    """logits: (B, V) f32 -> (token (B,) int32, logprob (B,) f32).

    The chosen token's logprob is ``max(logits) - logsumexp(logits)`` -- the
    full-vocab ``log_softmax`` is never materialized.
    """
    if use_kernel:
        return greedy_epilogue_fwd(logits, block_v=block_v,
                                   interpret=_interpret())
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    tok = jnp.argmax(x, axis=-1).astype(jnp.int32)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=-1))
    return tok, m - lse


__all__ = ["greedy_epilogue"]
