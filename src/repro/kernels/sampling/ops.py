"""Jit-ready fused sampling epilogue: argmax token + chosen-token logprob.

Two implementations behind one call:

* the pure-jnp fusion (default) -- max / streaming-free logsumexp /
  one-element gather; XLA fuses it into the lm-head matmul's consumer, so no
  normalized (B, V) log-prob tensor is ever written to memory;
* the Pallas streaming kernel (``use_kernel=True``) for the TPU tier, one
  vocab pass through VMEM.

Both are token-exact vs the ``log_softmax`` oracle (``ref.py``); ties break
like ``jnp.argmax`` (first maximal index).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sampling.kernel import (NEG_INF, greedy_epilogue_fwd,
                                           lmhead_epilogue_fwd)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


# replint: traced -- jitted from the serving engine
def greedy_epilogue(logits, *, use_kernel: bool = False, block_v: int = 2048):
    """logits: (B, V) f32 -> (token (B,) int32, logprob (B,) f32).

    The chosen token's logprob is ``max(logits) - logsumexp(logits)`` -- the
    full-vocab ``log_softmax`` is never materialized.
    """
    if use_kernel:
        return greedy_epilogue_fwd(logits, block_v=block_v,
                                   interpret=_interpret())
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    tok = jnp.argmax(x, axis=-1).astype(jnp.int32)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=-1))
    return tok, m - lse


# replint: traced -- jitted from the serving engine mixed step
def fused_lmhead_greedy(h, w, *, use_kernel: bool = False,
                        block_v: int = 0):
    """h: (..., d) hidden states; w: (d, V) lm-head weight.

    Returns (token (...,) int32, logprob (...,) f32) for the greedy argmax
    of ``h @ w`` without materializing the (..., V) logits tensor: the
    Pallas kernel streams vocab blocks of ``w`` through VMEM; the jnp path
    scans the same blocks carrying running (max, logsumexp, argmax) stats.
    ``block_v=0`` (or >= V) collapses the scan to a single fused
    matmul+epilogue -- the right default off-TPU, where XLA's fusion
    already avoids the second (B, V) intermediate.

    Leading dims are flattened, so the 1-token decode case (B, d) and the
    d-token verify case (B, T, d) share one implementation.
    """
    lead = h.shape[:-1]
    d = h.shape[-1]
    V = w.shape[1]
    hf = h.reshape(-1, d)
    if use_kernel:
        bv = block_v if block_v > 0 else 2048
        tok, lp = lmhead_epilogue_fwd(hf, w, block_v=bv,
                                      interpret=_interpret())
        return tok.reshape(lead), lp.reshape(lead)
    if block_v <= 0 or block_v >= V:
        logits = hf.astype(jnp.float32) @ w.astype(jnp.float32)
        tok, lp = greedy_epilogue(logits)
        return tok.reshape(lead), lp.reshape(lead)
    # streaming jnp fallback: pad W to whole blocks once, scan with running
    # stats -- peak activation is (N, block_v), never (N, V)
    nv = -(-V // block_v)
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, nv * block_v - V)))
    wb = wp.reshape(d, nv, block_v).transpose(1, 0, 2)        # (nv, d, bv)
    N = hf.shape[0]
    hf32 = hf.astype(jnp.float32)

    def body(carry, inp):
        i, wblk = inp
        m, lse_l, bv_run, bi_run = carry
        x = hf32 @ wblk                                       # (N, block_v)
        idx = i * block_v + jnp.arange(block_v)[None, :]
        x = jnp.where(idx < V, x, NEG_INF)
        bmax = x.max(axis=-1)
        barg = jnp.argmax(x, axis=-1).astype(jnp.int32)
        better = bmax > bv_run
        bv_run = jnp.where(better, bmax, bv_run)
        bi_run = jnp.where(better, i * block_v + barg, bi_run)
        m_cur = jnp.maximum(m, bmax)
        lse_l = lse_l * jnp.exp(m - m_cur) + jnp.exp(x - m_cur[:, None]).sum(-1)
        return (m_cur, lse_l, bv_run, bi_run), None

    init = (jnp.full((N,), NEG_INF, jnp.float32),
            jnp.zeros((N,), jnp.float32),
            jnp.full((N,), NEG_INF, jnp.float32),
            jnp.zeros((N,), jnp.int32))
    (m, lse_l, bv_run, bi_run), _ = jax.lax.scan(
        body, init, (jnp.arange(nv), wb))
    lse = m + jnp.log(jnp.maximum(lse_l, 1e-30))
    return bi_run.reshape(lead), (bv_run - lse).reshape(lead)


__all__ = ["greedy_epilogue", "fused_lmhead_greedy"]
