"""Fused greedy sampling/logprob epilogue for TPU decode.

The decode hot loop needs two scalars per batch row from the (B, V) logits:
the argmax token and that token's log-probability.  Doing this with
``log_softmax`` materializes a second (B, V) tensor in HBM just to gather one
element of it; on a 128k-vocab model that is the largest intermediate of the
whole decode step.  This kernel streams the vocab once through VMEM carrying a
running (max, logsumexp-accumulator, best-value, best-index) and emits the two
scalars directly -- the flash-attention trick applied to the sampler.

Tie-breaking matches ``jnp.argmax`` exactly (first maximal index wins): blocks
are visited in vocab order and a later block only takes over on a strictly
greater maximum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _epilogue_kernel(x_ref, tok_ref, lp_ref, m_scr, l_scr, bv_scr, bi_scr,
                     *, block_v: int, total_v: int):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        bv_scr[...] = jnp.full_like(bv_scr, NEG_INF)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    x = x_ref[...].astype(jnp.float32)                        # (1, block_v)
    # the last block may overhang the vocab: mask the padding lanes dead
    idx = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(idx < total_v, x, NEG_INF)
    bmax = x.max(axis=-1)                                     # (1,)
    barg = jnp.argmax(x, axis=-1).astype(jnp.int32)           # (1,) in-block
    # running argmax: strictly-greater keeps the first maximal index global
    better = bmax > bv_scr[...]
    bv_scr[...] = jnp.where(better, bmax, bv_scr[...])
    bi_scr[...] = jnp.where(better, vi * block_v + barg, bi_scr[...])
    # running logsumexp with rescaling
    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, bmax)
    l_scr[...] = (l_scr[...] * jnp.exp(m_prev - m_cur)
                  + jnp.exp(x - m_cur[:, None]).sum(axis=-1))
    m_scr[...] = m_cur

    @pl.when(vi == nv - 1)
    def _finalize():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        tok_ref[...] = bi_scr[...]
        lp_ref[...] = bv_scr[...] - lse


def greedy_epilogue_fwd(logits, *, block_v: int = 2048,
                        interpret: bool = False):
    """logits: (B, V) -> (token (B,) int32, logprob (B,) f32).

    One vocab pass; never materializes the normalized (B, V) log-probs.
    """
    B, V = logits.shape
    block_v = min(block_v, V)
    nv = pl.cdiv(V, block_v)              # last block masks its overhang

    kernel = functools.partial(_epilogue_kernel, block_v=block_v, total_v=V)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, nv),
        in_specs=[pl.BlockSpec((1, block_v), lambda b, vi: (b, vi))],
        out_specs=[pl.BlockSpec((1,), lambda b, vi: (b,)),
                   pl.BlockSpec((1,), lambda b, vi: (b,))],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.int32),
        ],
    )
    tok, lp = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.float32)],
        interpret=interpret,
    )(logits)
    return tok, lp


def _lmhead_epilogue_kernel(h_ref, w_ref, tok_ref, lp_ref,
                            m_scr, l_scr, bv_scr, bi_scr,
                            *, block_v: int, total_v: int):
    """Fused lm-head + greedy epilogue: the (1, block_v) logits tile is
    computed in-register from the hidden row and one vocab block of the
    weight matrix, then folded into the same running
    (max, logsumexp, best-value, best-index) stats as
    :func:`_epilogue_kernel` -- the (B, V) logits tensor never exists, not
    even as a kernel input."""
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        bv_scr[...] = jnp.full_like(bv_scr, NEG_INF)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    h = h_ref[...].astype(jnp.float32)                        # (1, d)
    w = w_ref[...].astype(jnp.float32)                        # (d, block_v)
    x = h @ w                                                 # (1, block_v)
    idx = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(idx < total_v, x, NEG_INF)
    bmax = x.max(axis=-1)
    barg = jnp.argmax(x, axis=-1).astype(jnp.int32)
    better = bmax > bv_scr[...]
    bv_scr[...] = jnp.where(better, bmax, bv_scr[...])
    bi_scr[...] = jnp.where(better, vi * block_v + barg, bi_scr[...])
    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, bmax)
    l_scr[...] = (l_scr[...] * jnp.exp(m_prev - m_cur)
                  + jnp.exp(x - m_cur[:, None]).sum(axis=-1))
    m_scr[...] = m_cur

    @pl.when(vi == nv - 1)
    def _finalize():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        tok_ref[...] = bi_scr[...]
        lp_ref[...] = bv_scr[...] - lse


def lmhead_epilogue_fwd(h, w, *, block_v: int = 2048,
                        interpret: bool = False):
    """h: (N, d) hidden rows; w: (d, V) lm-head weight.

    Returns (token (N,) int32, logprob (N,) f32) -- argmax of ``h @ w`` and
    its log-probability, streaming vocab blocks of ``w`` through VMEM so no
    (N, V) logits tensor is materialized.  ``N`` is whatever the caller
    flattened: B decode rows or B*T verify positions.
    """
    N, d = h.shape
    V = w.shape[1]
    block_v = min(block_v, V)
    nv = pl.cdiv(V, block_v)              # last block masks its overhang

    kernel = functools.partial(_lmhead_epilogue_kernel,
                               block_v=block_v, total_v=V)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(N, nv),
        in_specs=[pl.BlockSpec((1, d), lambda n, vi: (n, 0)),
                  pl.BlockSpec((d, block_v), lambda n, vi: (0, vi))],
        out_specs=[pl.BlockSpec((1,), lambda n, vi: (n,)),
                   pl.BlockSpec((1,), lambda n, vi: (n,))],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.int32),
        ],
    )
    tok, lp = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.float32)],
        interpret=interpret,
    )(h, w)
    return tok, lp
