"""Blockwise online-softmax attention (FlashAttention), Pallas TPU.

TPU adaptation notes (vs the CUDA original):
* the KV loop is a *grid dimension* (innermost, sequential on TPU) with fp32
  VMEM scratch carrying the running max / sum / accumulator between KV steps --
  the TPU analogue of warp-persistent register tiles;
* block shapes are MXU-aligned (multiples of 128 on the contracting dims);
* causal + sliding-window masking uses an in-block iota mask; the window is a
  *scalar-prefetch* operand so one compiled kernel serves every layer of a
  local/global interleaved stack (gemma3) under ``lax.scan``;
* GQA is expressed in the BlockSpec index maps (query head h reads KV head
  ``h // group``), so KV duplication never materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(w_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               *, block_q: int, block_k: int, causal: bool, sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    window = w_ref[0]

    # block-level skip: blocks entirely above the causal diagonal or entirely
    # outside the sliding window contribute nothing
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    live &= jnp.where(window > 0, k_start + block_k - 1 > q_start - window, True)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                           # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        mask &= jnp.where(window > 0, k_pos > q_pos - window, True)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, window, *, causal: bool = True,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D); window: (1,) int32 (<=0 = none).

    Returns (B, Hq, S, D).
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(S, block_k)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sm_scale=D ** -0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki, w: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, w: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, w: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki, w: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        interpret=interpret,
    )(window, q, k, v)
