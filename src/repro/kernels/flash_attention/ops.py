"""Jit-ready wrappers around the flash-attention Pallas kernel.

Model layers pass (B, S, H, D) activations; the kernel wants (B, H, S, D).
On CPU backends the kernel runs in interpret mode (same code path, Python
emulation) -- that is how the per-kernel allclose tests execute here.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
# replint: traced -- jitted from the serving engine
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 512, block_k: int = 512):
    """q/k/v: (B, S, H{q,kv}, D) -> (B, S, Hq, D).  Static window."""
    w = jnp.array([window if window else -1], jnp.int32)
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        w, causal=causal, block_q=block_q, block_k=block_k, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


# replint: traced -- jitted from the serving engine
def flash_attention_dyn(q, k, v, window, *, block_q: int = 512, block_k: int = 512):
    """Traced-window variant used inside ``lax.scan`` over heterogeneous layers.

    q/k/v: (B, S, H, D); window: scalar int32 (<=0 = full causal).
    """
    w = jnp.reshape(window, (1,)).astype(jnp.int32)
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        w, causal=True, block_q=min(block_q, q.shape[1]),
        block_k=min(block_k, q.shape[1]), interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


__all__ = ["flash_attention", "flash_attention_dyn"]
