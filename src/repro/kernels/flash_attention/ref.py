"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, window: int | None, *, causal: bool = True):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D).  Returns (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = qf.reshape(B, Hkv, group, S, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None and window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, D).astype(q.dtype)
