from repro.data.pipeline import DataConfig, TokenStream, request_stream

__all__ = ["DataConfig", "TokenStream", "request_stream"]
