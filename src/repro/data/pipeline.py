"""Deterministic sharded data pipeline.

Training: an infinite synthetic token stream (Zipf-distributed ids over a
Markov backbone so losses actually go down) that is *deterministically
resumable*: batch ``i`` depends only on (seed, i), so a restarted job at step
``s`` regenerates exactly the batches it would have seen -- the data-side half
of fault tolerance.  Sharding: each host slices its ``process_index`` rows.

Serving: a bursty request stream whose arrival intensity follows the paper's
match-trace structure (the LLM analogue of the tweet workload).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenStream:
    """Deterministic, seekable synthetic LM data."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        # fixed random Markov transition "hubs" make the stream learnable
        rng = np.random.default_rng(cfg.seed)
        self._hub = rng.integers(0, cfg.vocab, size=1024).astype(np.int32)

    def batch(self, index: int) -> dict:
        """Batch ``index`` (global step), host-local slice. {tokens, targets}."""
        cfg = self.cfg
        rows = []
        base = index * cfg.global_batch + self.host_id_offset
        for r in range(self.local_batch):
            rng = np.random.default_rng((cfg.seed, base + r))
            z = rng.zipf(1.4, size=cfg.seq_len).astype(np.int64)
            toks = (z % (cfg.vocab - 2)) + 1
            # splice hub n-grams for learnable structure
            for _ in range(cfg.seq_len // 64):
                p = int(rng.integers(0, cfg.seq_len - 8))
                h = int(rng.integers(0, 1016))
                toks[p : p + 8] = self._hub[h : h + 8]
            rows.append(toks.astype(np.int32))
        tokens = np.stack(rows)
        return {"tokens": tokens, "targets": tokens.copy()}

    @property
    def host_id_offset(self) -> int:
        return self.cfg.host_id * self.local_batch


def request_stream(*, n_requests: int, seed: int = 0, mean_prompt: int = 64,
                   mean_decode: int = 32, burst_times=(), burst_scale: float = 4.0,
                   horizon_s: float = 600.0):
    """Bursty serving workload: Poisson base + multiplicative bursts
    (the paper's Fig-4 structure mapped onto LLM requests).

    Yields (arrival_s, prompt_len, decode_len) sorted by arrival.
    """
    rng = np.random.default_rng(seed)
    n_sec = int(horizon_s)
    lam = np.ones(n_sec) * (n_requests / n_sec)
    t = np.arange(n_sec, dtype=np.float64)
    for b in burst_times:
        prof = np.where(t < b, np.exp(-((t - b) ** 2) / (2 * 20.0 ** 2)),
                        np.exp(-(t - b) / 60.0))
        lam = lam * (1.0 + (burst_scale - 1.0) * prof)
    lam *= n_requests / lam.sum()
    counts = rng.poisson(lam)
    out = []
    for sec, c in enumerate(counts):
        for _ in range(c):
            out.append((
                sec + rng.random(),
                max(int(rng.exponential(mean_prompt)), 4),
                max(int(rng.exponential(mean_decode)), 1),
            ))
    out.sort()
    return out


__all__ = ["DataConfig", "TokenStream", "request_stream"]
