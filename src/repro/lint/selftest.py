"""Fixture-corpus selftest: every rule fires on its ``*_fire.py`` fixture
and stays silent on the ``*_clean.py`` twin.

This is both a pytest target (tests/test_lint.py parametrizes over it) and
a CLI mode (``python -m repro.lint --selftest``) so scripts/check.sh can
prove the gate's teeth before trusting its silence on the real tree.
"""
from __future__ import annotations

from pathlib import Path

from .engine import lint_paths
from .rules import ALL_RULES

FIXTURE_DIR = "tests/lint_fixtures"

#: engine-emitted meta rules also have fixture pairs
SELFTEST_IDS = [r.id for r in ALL_RULES] + ["REP001", "REP002"]


def fixture_pair(rule_id: str, root: str | Path = ".") -> tuple[Path, Path]:
    base = Path(root) / FIXTURE_DIR
    return (base / f"{rule_id.lower()}_fire.py",
            base / f"{rule_id.lower()}_clean.py")


def check_rule(rule_id: str, root: str | Path = ".") -> list[str]:
    """Return a list of problems (empty == the rule's corpus is healthy)."""
    fire, clean = fixture_pair(rule_id, root)
    problems: list[str] = []
    if not fire.exists() or not clean.exists():
        return [f"{rule_id}: fixture pair missing under {FIXTURE_DIR}/"]

    fire_report = lint_paths([str(fire)], root=root, respect_scope=False,
                             include_fixtures=True)
    clean_report = lint_paths([str(clean)], root=root, respect_scope=False,
                              include_fixtures=True)

    if not any(f.rule == rule_id for f in fire_report.findings):
        problems.append(
            f"{rule_id}: did not fire on {fire.name} "
            f"(got: {[f.rule for f in fire_report.findings] or 'nothing'})")
    if any(f.rule == rule_id for f in clean_report.findings):
        lines = [str(f.line) for f in clean_report.findings
                 if f.rule == rule_id]
        problems.append(
            f"{rule_id}: fired on clean twin {clean.name} "
            f"(lines {', '.join(lines)})")
    return problems


def run_selftest(root: str | Path = ".", *, verbose: bool = True) -> int:
    failures = 0
    for rule_id in SELFTEST_IDS:
        problems = check_rule(rule_id, root)
        if problems:
            failures += 1
            for p in problems:
                print(f"FAIL {p}")
        elif verbose:
            print(f"ok   {rule_id}")
    if failures:
        print(f"selftest: {failures}/{len(SELFTEST_IDS)} rules unhealthy")
    elif verbose:
        print(f"selftest: all {len(SELFTEST_IDS)} rules fire on their "
              "fixtures and stay silent on the clean twins")
    return 1 if failures else 0


__all__ = ["SELFTEST_IDS", "fixture_pair", "check_rule", "run_selftest"]
