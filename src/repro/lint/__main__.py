"""CLI entry point: ``python -m repro.lint [paths ...]``.

Exit codes: 0 clean, 1 findings, 2 usage/internal error.
"""
from __future__ import annotations

import argparse
import sys

from .engine import lint_paths
from .rules import ALL_RULES, META_RULES
from .selftest import run_selftest


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="replint: trace-safety, Pallas and control-plane rules")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src tests "
                         "benchmarks)")
    ap.add_argument("--json", metavar="FILE",
                    help="write the JSON report to FILE")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE",
                    help="run only these rule ids/names (repeatable; "
                         "disables REP00x meta checks)")
    ap.add_argument("--no-scope", action="store_true",
                    help="ignore per-rule path scopes (lint everything "
                         "with every rule)")
    ap.add_argument("--include-fixtures", action="store_true",
                    help="also lint tests/lint_fixtures (excluded by "
                         "default; the corpus is full of violations on "
                         "purpose)")
    ap.add_argument("--selftest", action="store_true",
                    help="verify every rule fires on its fixture corpus "
                         "entry and stays silent on the clean twin")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--root", default=".",
                    help="repo root for relative paths (default: cwd)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-finding lines; print the summary "
                         "only")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            scope = ", ".join(rule.scope) if rule.scope else "all files"
            print(f"{rule.id}  {rule.name:20s} [{scope}]")
            print(f"        {rule.description}")
        for rid, name, desc in META_RULES:
            print(f"{rid}  {name:20s} [engine]")
            print(f"        {desc}")
        return 0

    if args.selftest:
        return run_selftest(args.root, verbose=not args.quiet)

    paths = args.paths or ["src", "tests", "benchmarks"]
    report = lint_paths(paths, root=args.root,
                        respect_scope=not args.no_scope,
                        include_fixtures=args.include_fixtures,
                        select=tuple(args.select) if args.select else None)

    if args.json:
        report.write_json(args.json)

    if not args.quiet:
        for f in report.findings:
            print(f"{f.location()} {f.rule} {f.name}: {f.message}")
    n = len(report.findings)
    print(f"replint: {report.n_files} files, {n} finding"
          f"{'' if n == 1 else 's'}, {len(report.suppressed)} suppressed")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
