"""Three-level staticness classifier for expressions in traced functions.

Inside a jit-reachable function the trace-safety rules must distinguish
values that are *trace-time Python* (shapes, config flags, loop-bound
constants) from values that are *tracers* (array arguments and anything
derived from them).  ``int(x.shape[1])`` is fine; ``int(logits)`` is a host
sync.  A binary verdict would drown the rules in false positives, so every
expression classifies to one of three levels:

* ``STATIC``  -- known trace-time Python (never a tracer);
* ``TRACED``  -- known (or presumed) tracer;
* ``UNKNOWN`` -- cannot tell; rules stay silent.

Rules only fire on ``TRACED``.  The environment maps local names to levels
and is built per function:

* parameters default to TRACED (a traced function's arguments are the
  tracers) **except**: ``self``/``cls``; parameters whose annotation names a
  static Python type (``int``, ``float``, ``bool``, ``str``, a ``*Config``
  class, ``Callable`` ...); and keyword-only parameters of *kernel*
  functions (Pallas kernels bind block sizes via ``functools.partial(...,
  block_k=...)``, so kwonly == compile-time constant by construction);
* closure variables inherit the enclosing function's environment, module
  level is STATIC;
* assignments propagate: ``y = x + 1`` is as traced as ``x``;
  ``n = x.shape[0]`` is STATIC regardless of ``x``.

Expressions that are static *regardless of their operands*: ``.shape`` /
``.dtype`` / ``.ndim`` attributes, ``len(...)``, ``x is None`` /
``x is not None`` comparisons, ``isinstance(...)``, string/None/number
literals.
"""
from __future__ import annotations

import ast

from .callgraph import FunctionInfo, ModuleGraph, dotted_name

STATIC = 0
UNKNOWN = 1
TRACED = 2

#: annotation names whose parameters are trace-time Python values
_STATIC_ANNOTATIONS = {
    "int", "float", "bool", "str", "bytes", "tuple", "list", "dict", "set",
    "type", "object", "Callable", "callable", "Sequence", "Mapping",
    "Optional", "Any", "None",
}

#: attribute accesses that always yield static metadata
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "itemsize"}

#: calls that always yield static values (metadata / type queries)
_STATIC_CALLS = {
    "len", "isinstance", "issubclass", "type", "id", "getattr", "hasattr",
    "range", "zip", "enumerate", "sorted", "min", "max", "abs", "round",
}

#: dotted calls that always yield tracers from any input
_TRACER_FACTORY_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.random.")

#: dotted calls that return host metadata even on tracers
_STATIC_DOTTED_CALLS = {
    "jax.numpy.ndim", "jax.numpy.shape", "jax.numpy.size",
    "jax.numpy.isscalar", "jax.numpy.result_type", "jax.numpy.dtype",
    "numpy.ndim", "numpy.shape", "numpy.size", "numpy.isscalar",
    "numpy.result_type", "numpy.dtype",
}


def _annotation_is_static(ann: ast.expr | None) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Constant):          # string annotation / None
        return (isinstance(ann.value, str)
                and _name_is_static(ann.value)) or ann.value is None
    if isinstance(ann, ast.Name):
        return _name_is_static(ann.id)
    if isinstance(ann, ast.Attribute):
        return _name_is_static(ann.attr)
    if isinstance(ann, ast.Subscript):          # Optional[int], list[int] ...
        return _annotation_is_static(ann.value)
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        # PEP 604 unions: static if any side is a static scalar type --
        # ``int | None`` parameters are config knobs, not tracers
        return (_annotation_is_static(ann.left)
                or _annotation_is_static(ann.right))
    return False


def _name_is_static(name: str) -> bool:
    if name in _STATIC_ANNOTATIONS:
        return True
    # config/spec dataclasses are hyperparameter bags, never tracers
    return name.endswith(("Config", "Spec", "Settings", "Options"))


class Env:
    """Chained name->level environment (function scope over closure scope)."""

    def __init__(self, parent: "Env | None" = None):
        self.parent = parent
        self.names: dict[str, int] = {}

    def get(self, name: str) -> int:
        env: Env | None = self
        while env is not None:
            if name in env.names:
                return env.names[name]
            env = env.parent
        return STATIC   # module level: imports, constants, classes

    def set(self, name: str, level: int) -> None:
        self.names[name] = level


def param_env(info: FunctionInfo, parent: Env | None = None) -> Env:
    """Seed an environment from a function's parameter list."""
    env = Env(parent)
    node = info.node
    args = node.args
    kernel = info.kernel_reachable

    def classify_param(a: ast.arg, *, kwonly: bool) -> int:
        if a.arg in ("self", "cls"):
            return STATIC
        if getattr(a, "annotation", None) is not None:
            return STATIC if _annotation_is_static(a.annotation) else TRACED
        if kwonly and kernel:
            return STATIC   # partial-bound block sizes / flags
        return TRACED

    for a in args.posonlyargs + args.args:
        env.set(a.arg, classify_param(a, kwonly=False))
    for a in args.kwonlyargs:
        env.set(a.arg, classify_param(a, kwonly=True))
    if args.vararg:
        env.set(args.vararg.arg, classify_param(args.vararg, kwonly=False))
    if args.kwarg:
        env.set(args.kwarg.arg, STATIC)   # **kwargs dict itself is host-side
    return env


def classify(node: ast.expr, env: Env, imports: dict[str, str]) -> int:
    """Classify an expression as STATIC / UNKNOWN / TRACED."""
    c = lambda n: classify(n, env, imports)   # noqa: E731

    if isinstance(node, ast.Constant):
        return STATIC
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return STATIC
        base = c(node.value)
        if base == STATIC:
            return STATIC      # cfg.moe, self.decode_steps, np.float32 ...
        return UNKNOWN         # tracer attribute? pytrees make this murky
    if isinstance(node, ast.Subscript):
        base = c(node.value)
        if base == STATIC and isinstance(node.value, ast.Attribute) \
                and node.value.attr in _STATIC_ATTRS:
            return STATIC      # x.shape[0]
        return base
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return STATIC      # ``x is None`` is a trace-time identity test
        return max(c(node.left), *(c(cmp) for cmp in node.comparators))
    if isinstance(node, ast.BoolOp):
        return max(c(v) for v in node.values)
    if isinstance(node, ast.BinOp):
        return max(c(node.left), c(node.right))
    if isinstance(node, ast.UnaryOp):
        return c(node.operand)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        if not node.elts:
            return STATIC
        return max(c(e) for e in node.elts)
    if isinstance(node, ast.Dict):
        vals = [c(v) for v in node.values if v is not None]
        return max(vals) if vals else STATIC
    if isinstance(node, ast.IfExp):
        return max(c(node.body), c(node.orelse))
    if isinstance(node, ast.Starred):
        return c(node.value)
    if isinstance(node, ast.JoinedStr):
        return STATIC          # the *string* is host; TRC103 checks contents
    if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
        return UNKNOWN
    if isinstance(node, ast.Call):
        name = dotted_name(node.func, imports)
        if name in _STATIC_CALLS:
            return STATIC
        if name is not None:
            if name in _STATIC_DOTTED_CALLS:
                return STATIC
            if name.startswith(_TRACER_FACTORY_PREFIXES):
                return TRACED
            if name in ("int", "float", "bool", "str", "tuple", "list",
                        "dict"):
                return STATIC  # result is host Python (TRC101 flags the call)
        if isinstance(node.func, ast.Attribute):
            # method on a value: x.astype(...), x.sum() keep x's level;
            # metadata-ish methods are static
            if node.func.attr in ("keys", "values", "items", "get", "copy"):
                return c(node.func.value)
            base = c(node.func.value)
            if base == TRACED:
                return TRACED
        return UNKNOWN
    return UNKNOWN


class EnvBuilder:
    """Walk a function's own statements in order, updating the environment.

    Callers hand ``visit_stmt`` each top-level statement *before* running
    their checks on it, so name levels reflect program order.  Nested
    function definitions are skipped -- they are separate graph nodes and
    get their own environment (seeded with this one as parent).
    """

    def __init__(self, env: Env, imports: dict[str, str]):
        self.env = env
        self.imports = imports

    def _bind_target(self, target: ast.expr, level: int) -> None:
        if isinstance(target, ast.Name):
            self.env.set(target.id, level)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind_target(el, level)
        elif isinstance(target, ast.Starred):
            self._bind_target(target.value, level)
        # attribute/subscript targets don't create local names

    def visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            level = classify(stmt.value, self.env, self.imports)
            for t in stmt.targets:
                self._bind_target(t, level)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            if _annotation_is_static(stmt.annotation):
                level = STATIC
            else:
                level = classify(stmt.value, self.env, self.imports)
            self._bind_target(stmt.target, level)
        elif isinstance(stmt, ast.AugAssign):
            level = max(classify(stmt.value, self.env, self.imports),
                        classify(stmt.target, self.env, self.imports)
                        if isinstance(stmt.target, ast.Name) else STATIC)
            self._bind_target(stmt.target, level)
        elif isinstance(stmt, ast.For):
            it = classify(stmt.iter, self.env, self.imports)
            self._bind_target(stmt.target, it)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                if item.optional_vars is not None:
                    self._bind_target(item.optional_vars, UNKNOWN)
        elif isinstance(stmt, (ast.Import, ast.ImportFrom)):
            for a in stmt.names:
                self.env.set(a.asname or a.name.split(".")[0], STATIC)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.env.set(stmt.name, STATIC)


def function_statements(node, *, into_bodies: bool = True):
    """Yield the function's own statements, not those of nested defs.

    With ``into_bodies`` the walk descends into if/for/while/try/with
    blocks (still skipping nested function/class bodies).
    """
    stack = list(node.body)
    while stack:
        stmt = stack.pop(0)
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        if into_bodies:
            for field_name in ("body", "orelse", "finalbody", "handlers"):
                block = getattr(stmt, field_name, None)
                if not block:
                    continue
                for sub in block:
                    if isinstance(sub, ast.ExceptHandler):
                        stack.extend(sub.body)
                    else:
                        stack.append(sub)


def walk_expressions(stmt: ast.stmt):
    """Yield expression nodes of a statement without entering nested defs
    or sub-statements (those come through ``function_statements``)."""
    blocks = {"body", "orelse", "finalbody", "handlers"}
    stack: list[ast.AST] = []
    for field_name, value in ast.iter_fields(stmt):
        if field_name in blocks and isinstance(stmt, (ast.If, ast.For,
                                                      ast.While, ast.Try,
                                                      ast.With)):
            continue
        if isinstance(value, ast.AST):
            stack.append(value)
        elif isinstance(value, list):
            stack.extend(v for v in value if isinstance(v, ast.AST))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


__all__ = ["STATIC", "UNKNOWN", "TRACED", "Env", "param_env", "classify",
           "EnvBuilder", "function_statements", "walk_expressions",
           "ModuleGraph"]
