"""replint -- project-specific static analysis for this repo.

Three rule families guard the properties the reproduction's numbers rest
on: the decode hot path must never silently sync to host (TRC1xx), Pallas
kernels must follow the ref discipline (PLK2xx), and the control plane must
stay deterministic and replayable (CPL3xx).  See DESIGN.md, "The
static-analysis gate".

Run it::

    PYTHONPATH=src python -m repro.lint src tests benchmarks
"""
from .engine import Finding, Report, lint_paths
from .rules import ALL_RULES, get_rule

__all__ = ["Finding", "Report", "lint_paths", "ALL_RULES", "get_rule"]
