"""replint engine: file discovery, suppressions, rule dispatch, reporting.

Usage (CLI lives in ``repro.lint.__main__``)::

    PYTHONPATH=src python -m repro.lint src tests benchmarks

Suppression syntax (comment on the offending line, or on a line of its own
directly above it)::

    x = int(tok)   # replint: disable=TRC101 -- host sync on purpose: <why>
    # replint: disable=TRC101,TRC103 -- debugging block, never jitted
    # replint: disable=ALL -- generated file

A reason string after ``--`` is mandatory; a reasonless suppression is
itself a finding (REP001), and a suppression that matches nothing is too
(REP002).  ``# replint: traced`` on a ``def`` line (or the line above)
marks a function as a cross-module trace root for the call graph.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

from .callgraph import ModuleGraph, build_graph, build_imports

_SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*disable\s*=\s*(?P<rules>[\w,\s-]+?)"
    r"(?:\s*--\s*(?P<reason>.*\S))?\s*$")
_TRACED_RE = re.compile(r"#\s*replint:\s*traced\b")

#: directory names never linted unless explicitly requested
EXCLUDED_DIRS = {"lint_fixtures", "__pycache__", ".git", "artifacts"}


@dataclass
class Finding:
    rule: str            # e.g. "TRC101"
    name: str            # e.g. "host-sync"
    path: str            # repo-relative posix path
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str | None = None

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_json(self) -> dict:
        out = {"rule": self.rule, "name": self.name, "path": self.path,
               "line": self.line, "col": self.col, "message": self.message}
        if self.suppressed:
            out["suppressed"] = True
            out["reason"] = self.reason
        return out


@dataclass
class Suppression:
    line: int                 # line the comment sits on
    rules: tuple[str, ...]    # rule ids/names, or ("ALL",)
    reason: str | None
    own_line: bool            # comment-only line (applies to the next line)
    used: bool = False

    def covers(self, finding_line: int) -> bool:
        if finding_line == self.line:
            return True
        return self.own_line and finding_line == self.line + 1

    def matches(self, rule_id: str, rule_name: str) -> bool:
        return ("ALL" in self.rules or rule_id in self.rules
                or rule_name in self.rules)


@dataclass
class ModuleContext:
    """Everything a rule needs about one file."""
    path: str                          # repo-relative posix
    tree: ast.Module
    source: str
    imports: dict[str, str]
    graph: ModuleGraph
    suppressions: list[Suppression]
    traced_lines: frozenset[int]


def parse_comments(source: str) -> tuple[list[Suppression], frozenset[int]]:
    suppressions: list[Suppression] = []
    traced: set[int] = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            line_no, col = tok.start
            if _TRACED_RE.search(tok.string):
                traced.add(line_no)
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                rules = tuple(r.strip() for r in m.group("rules").split(",")
                              if r.strip())
                suppressions.append(Suppression(
                    line=line_no, rules=rules, reason=m.group("reason"),
                    own_line=(col == 0 or tok.line[:col].strip() == "")))
    except tokenize.TokenError:
        pass
    return suppressions, frozenset(traced)


def build_context(path: Path, rel: str) -> ModuleContext | None:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    suppressions, traced = parse_comments(source)
    imports = build_imports(tree)
    graph = build_graph(tree, imports, traced)
    return ModuleContext(path=rel, tree=tree, source=source, imports=imports,
                         graph=graph, suppressions=suppressions,
                         traced_lines=traced)


def discover(paths: list[str], root: Path, *,
             include_fixtures: bool = False) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        candidate = (root / p) if not Path(p).is_absolute() else Path(p)
        if candidate.is_file() and candidate.suffix == ".py":
            files.append(candidate)
        elif candidate.is_dir():
            for f in sorted(candidate.rglob("*.py")):
                parts = set(f.parts)
                if not include_fixtures and parts & EXCLUDED_DIRS:
                    continue
                files.append(f)
    seen: set[Path] = set()
    out = []
    for f in files:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    n_files: int = 0

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0

    def to_json(self) -> dict:
        counts: dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "tool": "replint",
            "n_files": self.n_files,
            "n_findings": len(self.findings),
            "n_suppressed": len(self.suppressed),
            "counts": counts,
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [f.to_json() for f in self.suppressed],
        }

    def write_json(self, path: str | Path) -> None:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_json(), indent=2) + "\n")


def run_rules(ctx: ModuleContext, rules, *, respect_scope: bool = True,
              with_meta: bool = True) -> tuple[list[Finding], list[Finding]]:
    """Run ``rules`` over one module; returns (active, suppressed)."""
    raw: list[Finding] = []
    for rule in rules:
        if respect_scope and not rule.applies(ctx.path):
            continue
        raw.extend(rule.check(ctx))

    active: list[Finding] = []
    suppressed: list[Finding] = []
    for f in raw:
        hit = None
        for s in ctx.suppressions:
            if s.covers(f.line) and s.matches(f.rule, f.name):
                hit = s
                break
        if hit is not None:
            hit.used = True
            f.suppressed = True
            f.reason = hit.reason
            suppressed.append(f)
        else:
            active.append(f)

    if with_meta:
        for s in ctx.suppressions:
            if s.reason is None:
                active.append(Finding(
                    rule="REP001", name="suppress-no-reason", path=ctx.path,
                    line=s.line, col=0,
                    message=("suppression without a reason; write "
                             "'# replint: disable=%s -- <why>'"
                             % ",".join(s.rules))))
            if not s.used:
                active.append(Finding(
                    rule="REP002", name="unused-suppression", path=ctx.path,
                    line=s.line, col=0,
                    message=("suppression for %s matches no finding; "
                             "remove it" % ",".join(s.rules))))
    return active, suppressed


def lint_paths(paths: list[str], *, root: str | Path = ".",
               rules=None, respect_scope: bool = True,
               include_fixtures: bool = False,
               select: tuple[str, ...] | None = None) -> Report:
    from .rules import ALL_RULES
    root = Path(root).resolve()
    if rules is None:
        rules = ALL_RULES
    if select:
        wanted = set(select)
        rules = [r for r in rules if r.id in wanted or r.name in wanted]
    # meta findings (REP00x) only make sense on a full-rule run: a partial
    # run would report every unrelated suppression as "unused"
    with_meta = select is None

    report = Report()
    for f in discover(paths, root, include_fixtures=include_fixtures):
        try:
            rel = f.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        ctx = build_context(f, rel)
        if ctx is None:
            report.findings.append(Finding(
                rule="REP000", name="parse-error", path=rel, line=1, col=0,
                message="file could not be parsed"))
            continue
        report.n_files += 1
        active, suppressed = run_rules(ctx, rules,
                                       respect_scope=respect_scope,
                                       with_meta=with_meta)
        report.findings.extend(active)
        report.suppressed.extend(suppressed)
    report.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    report.suppressed.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return report


__all__ = ["Finding", "Suppression", "ModuleContext", "Report",
           "build_context", "discover", "lint_paths", "run_rules",
           "parse_comments"]
