"""Rule base class and registry plumbing."""
from __future__ import annotations

import re

from ..engine import Finding, ModuleContext


class Rule:
    """One named check.  Subclasses set ``id``/``name``/``description`` and
    implement ``check``; ``scope`` is a tuple of path-regex fragments the
    rule is limited to (empty = every file)."""

    id: str = "REP999"
    name: str = "unnamed"
    description: str = ""
    scope: tuple[str, ...] = ()

    def applies(self, path: str) -> bool:
        if not self.scope:
            return True
        return any(re.search(pat, path) for pat in self.scope)

    def check(self, ctx: ModuleContext) -> list[Finding]:   # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node, message: str) -> Finding:
        return Finding(rule=self.id, name=self.name, path=ctx.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


#: scope shared by the trace-safety family: the hot-path modules where a
#: silent host sync costs real throughput (serving engine, LM forward,
#: kernels).  Host-side driver/test code may sync freely.
TRACE_SCOPE = (r"src/repro/serving/", r"src/repro/models/",
               r"src/repro/kernels/")

#: scope for the control-plane determinism family.  Chaos drills are in
#: scope too: a drill that reads the wall clock or draws ambient entropy
#: cannot reproduce the byte-identical audit logs it exists to verify.
CONTROL_PLANE_SCOPE = (r"src/repro/core/chaos/",
                       r"src/repro/core/convergence/",
                       r"src/repro/core/scaling/")
