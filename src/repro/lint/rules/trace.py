"""Trace-safety rules (TRC1xx).

All three rules only examine functions the call graph marks jit-reachable
(jit roots, lax control-flow bodies, Pallas kernels, ``# replint: traced``
entry points) and only fire when the staticness classifier is *sure* the
offending operand is a tracer -- UNKNOWN stays silent by design: a lint
gate that cries wolf gets suppressed wholesale and protects nothing.
"""
from __future__ import annotations

import ast

from ..callgraph import dotted_name
from ..engine import Finding, ModuleContext
from ..staticness import (TRACED, Env, EnvBuilder, classify,
                          function_statements, param_env, walk_expressions)
from .base import TRACE_SCOPE, Rule

#: ``x.<attr>()`` methods that force a device->host sync
_SYNC_METHODS = {"item", "tolist", "block_until_ready", "__bool__",
                 "__float__", "__int__"}

#: dotted host-library calls that materialize their array argument
_HOST_CALLS = {
    "numpy.asarray", "numpy.array", "numpy.copy", "numpy.asanyarray",
    "numpy.ascontiguousarray",
    "jax.device_get",
}

#: builtins that coerce a tracer to a host scalar
_COERCIONS = {"int", "float", "bool", "complex"}

#: builtins/functions that stringify their arguments (TRC103)
_FORMATTERS = {"print", "str", "repr", "format"}


def _iter_traced_functions(ctx: ModuleContext):
    """Yield (info, env) for each jit-reachable function, with the
    environment seeded from params + enclosing scopes."""
    envs: dict[int, Env] = {}

    def env_for(info) -> Env:
        key = id(info.node)
        if key not in envs:
            parent = env_for(info.parent) if info.parent is not None else None
            envs[key] = param_env(info, parent)
        return envs[key]

    for info in ctx.graph.jit_reachable_functions():
        yield info, env_for(info)


def _scan(ctx: ModuleContext, on_stmt) -> list[Finding]:
    """Drive a statement-order walk over every traced function; ``on_stmt``
    gets (info, stmt, env) and returns findings for that statement."""
    out: list[Finding] = []
    for info, env in _iter_traced_functions(ctx):
        builder = EnvBuilder(env, ctx.imports)
        if isinstance(info.node, ast.Lambda):
            out.extend(on_stmt(info, ast.Expr(value=info.node.body), env))
            continue
        for stmt in function_statements(info.node):
            out.extend(on_stmt(info, stmt, env))
            builder.visit_stmt(stmt)
    return out


class HostSyncRule(Rule):
    id = "TRC101"
    name = "host-sync"
    description = ("no np.asarray/.item()/int()/float()/bool() on traced "
                   "values inside jit-reachable functions")
    scope = TRACE_SCOPE

    def check(self, ctx: ModuleContext) -> list[Finding]:
        def on_stmt(info, stmt, env):
            findings = []
            for node in walk_expressions(stmt):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func, ctx.imports)
                if name in _HOST_CALLS:
                    if any(classify(a, env, ctx.imports) == TRACED
                           for a in node.args):
                        findings.append(self.finding(
                            ctx, node,
                            f"{name.split('.')[-1]}() on a traced value in "
                            f"'{info.qualname}' forces a device->host sync"))
                elif name in _COERCIONS:
                    if any(classify(a, env, ctx.imports) == TRACED
                           for a in node.args):
                        findings.append(self.finding(
                            ctx, node,
                            f"{name}() on a traced value in "
                            f"'{info.qualname}' forces a device->host sync "
                            "(use astype/jnp casts instead)"))
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in _SYNC_METHODS
                      and classify(node.func.value, env,
                                   ctx.imports) == TRACED):
                    findings.append(self.finding(
                        ctx, node,
                        f".{node.func.attr}() on a traced value in "
                        f"'{info.qualname}' forces a device->host sync"))
            return findings
        return _scan(ctx, on_stmt)


class TracedBranchRule(Rule):
    id = "TRC102"
    name = "traced-branch"
    description = ("no Python if/while/for/assert on traced operands inside "
                   "jit-reachable functions (use lax.cond/select/while_loop)")
    scope = TRACE_SCOPE

    def check(self, ctx: ModuleContext) -> list[Finding]:
        def on_stmt(info, stmt, env):
            findings = []
            tests: list[tuple[ast.AST, str]] = []
            if isinstance(stmt, (ast.If, ast.While)):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                tests.append((stmt.test, kind))
            elif isinstance(stmt, ast.Assert):
                tests.append((stmt.test, "assert"))
            elif isinstance(stmt, ast.For):
                tests.append((stmt.iter, "for"))
            for node in walk_expressions(stmt):
                if isinstance(node, ast.IfExp):
                    tests.append((node.test, "conditional expression"))
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.GeneratorExp, ast.DictComp)):
                    for gen in node.generators:
                        tests.append((gen.iter, "comprehension"))
            for test, kind in tests:
                if classify(test, env, ctx.imports) == TRACED:
                    findings.append(self.finding(
                        ctx, test,
                        f"Python {kind} on a traced operand in "
                        f"'{info.qualname}'; concretizes the tracer -- use "
                        "lax.cond / jnp.where / lax.while_loop"))
            return findings
        return _scan(ctx, on_stmt)


class TracedFormatRule(Rule):
    id = "TRC103"
    name = "traced-format"
    description = ("no f-strings/print/str() of tracers inside jit-reachable "
                   "functions (stringifies the abstract value or syncs)")
    scope = TRACE_SCOPE

    def check(self, ctx: ModuleContext) -> list[Finding]:
        def on_stmt(info, stmt, env):
            findings = []
            for node in walk_expressions(stmt):
                if isinstance(node, ast.FormattedValue):
                    if classify(node.value, env, ctx.imports) == TRACED:
                        findings.append(self.finding(
                            ctx, node,
                            f"f-string interpolates a traced value in "
                            f"'{info.qualname}' (prints the abstract tracer, "
                            "not data; use jax.debug.print)"))
                elif isinstance(node, ast.Call):
                    name = dotted_name(node.func, ctx.imports)
                    if name in _FORMATTERS and any(
                            classify(a, env, ctx.imports) == TRACED
                            for a in node.args):
                        findings.append(self.finding(
                            ctx, node,
                            f"{name}() of a traced value in "
                            f"'{info.qualname}' (use jax.debug.print for "
                            "runtime values)"))
            return findings
        return _scan(ctx, on_stmt)


TRACE_RULES = [HostSyncRule(), TracedBranchRule(), TracedFormatRule()]
