"""Control-plane invariant rules (CPL3xx).

The convergence planner and the scaling controller must be deterministic
and replayable: every decision is a pure function of (observation, config,
seed) and the JSONL audit log replays bit-exact.  These rules mechanically
keep wall-clock reads, ambient RNG, unit confusion and out-of-band state
mutation out of ``core/convergence/`` and ``core/scaling/``.
"""
from __future__ import annotations

import ast

from ..callgraph import dotted_name
from ..engine import Finding, ModuleContext
from .base import CONTROL_PLANE_SCOPE, Rule

#: ambient-state calls banned from pure decision modules
_WALL_CLOCK = {
    "time.time", "time.monotonic", "time.perf_counter", "time.process_time",
    "time.time_ns", "time.monotonic_ns", "time.perf_counter_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow", "datetime.date.today",
    "datetime.now", "datetime.utcnow", "date.today",
}

#: module-level (unseeded, global-state) RNG entry points
_AMBIENT_RNG_MODULES = ("random.", "numpy.random.")
_AMBIENT_MISC = {"uuid.uuid4", "uuid.uuid1", "os.urandom", "secrets.token_hex",
                 "secrets.token_bytes", "secrets.randbelow"}

#: unit families inferred from name suffixes; arithmetic may not mix them
_UNIT_SUFFIXES = {
    "_s": "seconds", "_secs": "seconds", "_seconds": "seconds",
    "_ms": "milliseconds",
    "_steps": "steps", "_step": "steps",
    "_hours": "hours", "_unit_hours": "hours",
    "_bins": "bins",
}

class WallClockRule(Rule):
    id = "CPL301"
    name = "wall-clock"
    description = ("no time/random/datetime wall-clock or unseeded RNG in "
                   "core/convergence and core/scaling; decisions must be "
                   "pure functions of (observation, config, seed)")
    scope = CONTROL_PLANE_SCOPE

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func, ctx.imports)
            if name is None:
                continue
            if name in _WALL_CLOCK:
                findings.append(self.finding(
                    ctx, node,
                    f"{name}() reads the wall clock in a pure control-plane "
                    "module; take 'now' as a parameter so audit replay "
                    "stays bit-exact"))
            elif name in _AMBIENT_MISC:
                findings.append(self.finding(
                    ctx, node,
                    f"{name}() draws ambient entropy in a pure control-plane "
                    "module; derive ids/draws from the seeded rng"))
            elif name.startswith(_AMBIENT_RNG_MODULES):
                tail = name.split(".")[-1]
                if name.endswith(".default_rng") or tail in ("Generator",
                                                             "RandomState",
                                                             "Random",
                                                             "SeedSequence"):
                    # constructor: fine if and only if explicitly seeded
                    if not node.args and not node.keywords:
                        findings.append(self.finding(
                            ctx, node,
                            f"{name}() without a seed in a control-plane "
                            "module; pass an explicit seed for replayable "
                            "decisions"))
                else:
                    findings.append(self.finding(
                        ctx, node,
                        f"{name}() uses the global RNG in a control-plane "
                        "module; use a seeded np.random.default_rng(seed)"))
        return findings


class UnitMixRule(Rule):
    id = "CPL302"
    name = "unit-mix"
    description = ("additive arithmetic and comparisons may not mix names "
                   "with different unit suffixes (_s, _ms, _steps, "
                   "_unit_hours ...); multiply/divide to convert first")
    scope = CONTROL_PLANE_SCOPE

    def _unit_of(self, node: ast.expr) -> str | None:
        """Unit family of an expression, when inferable from a name."""
        if isinstance(node, ast.Name):
            return self._unit_of_name(node.id)
        if isinstance(node, ast.Attribute):
            return self._unit_of_name(node.attr)
        if isinstance(node, ast.UnaryOp):
            return self._unit_of(node.operand)
        if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                      (ast.Add, ast.Sub)):
            # additive chain keeps its operands' (single) unit
            left = self._unit_of(node.left)
            return left if left is not None else self._unit_of(node.right)
        return None   # literals, calls, mult/div results: unit-less here

    def _unit_of_name(self, name: str) -> str | None:
        for suffix in sorted(_UNIT_SUFFIXES, key=len, reverse=True):
            if name.endswith(suffix):
                return _UNIT_SUFFIXES[suffix]
        return None

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for node in ast.walk(ctx.tree):
            pairs: list[tuple[ast.expr, ast.expr]] = []
            if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                          (ast.Add, ast.Sub)):
                pairs.append((node.left, node.right))
            elif isinstance(node, ast.Compare):
                operands = [node.left, *node.comparators]
                pairs.extend(zip(operands, operands[1:]))
            elif isinstance(node, ast.AugAssign) and isinstance(
                    node.op, (ast.Add, ast.Sub)):
                pairs.append((node.target, node.value))
            for left, right in pairs:
                lu, ru = self._unit_of(left), self._unit_of(right)
                if lu is not None and ru is not None and lu != ru:
                    findings.append(self.finding(
                        ctx, node,
                        f"'{ast.unparse(left)}' ({lu}) combined with "
                        f"'{ast.unparse(right)}' ({ru}) without a unit "
                        "conversion; multiply/divide by the rate first"))
        return findings


class PrivateMutationRule(Rule):
    id = "CPL303"
    name = "private-mutation"
    description = ("underscore attributes of another object may not be "
                   "assigned or mutated from outside its class; go through "
                   "the public API (keeps CapacityPlan/DesiredGroup state "
                   "consistent with the audit log)")

    _MUTATORS = {"append", "extend", "insert", "pop", "remove", "clear",
                 "update", "add", "discard", "popleft", "appendleft",
                 "setdefault", "popitem", "sort"}

    def _owner_ok(self, value: ast.expr) -> bool:
        """Mutating ``self._x`` / ``cls._x`` (and their subscripts) is the
        class's own business; anything else is an outside write."""
        while isinstance(value, ast.Subscript):
            value = value.value
        return isinstance(value, ast.Name) and value.id in ("self", "cls")

    def _private_attr(self, node: ast.expr) -> ast.Attribute | None:
        """The ``<obj>._priv`` attribute access at the base of a target."""
        while isinstance(node, ast.Subscript):
            node = node.value
        if (isinstance(node, ast.Attribute) and node.attr.startswith("_")
                and not node.attr.startswith("__")):
            return node
        return None

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for node in ast.walk(ctx.tree):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = list(node.targets)
            elif isinstance(node, ast.Call) and isinstance(node.func,
                                                           ast.Attribute):
                if node.func.attr in self._MUTATORS:
                    attr = self._private_attr(node.func.value)
                    if attr is not None and not self._owner_ok(attr.value):
                        findings.append(self.finding(
                            ctx, node,
                            f"'.{node.func.attr}()' mutates private "
                            f"attribute '{ast.unparse(attr)}' from outside "
                            "its class; use the owning object's public API"))
                continue
            for t in targets:
                for base in self._target_bases(t):
                    attr = self._private_attr(base)
                    if attr is not None and not self._owner_ok(attr.value):
                        findings.append(self.finding(
                            ctx, node,
                            f"assignment to private attribute "
                            f"'{ast.unparse(attr)}' from outside its class; "
                            "use the owning object's public API"))
        return findings

    def _target_bases(self, t: ast.expr):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                yield from self._target_bases(e)
        elif isinstance(t, ast.Starred):
            yield from self._target_bases(t.value)
        else:
            yield t


CONTROL_PLANE_RULES = [WallClockRule(), UnitMixRule(), PrivateMutationRule()]
