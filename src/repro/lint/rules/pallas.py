"""Pallas kernel rules (PLK2xx).

These rules anchor on ``pl.pallas_call`` sites found by the call graph, so
they self-scope: a file with no pallas_call produces no work.  TPU Pallas
conventions assumed here (see the repo's kernels): kernels receive refs as
positional args, compile-time constants as ``functools.partial``-bound
keyword-only args, and index refs via ``[...]``/slices/``pl.ds``.
"""
from __future__ import annotations

import ast

from ..callgraph import dotted_name
from ..engine import Finding, ModuleContext
from ..staticness import TRACED, classify, param_env
from .base import Rule

#: pl helpers that produce valid ref indices
_INDEX_CALLS = {"ds", "dslice", "program_id", "num_programs", "multiple_of",
                "cdiv"}

_REF_SUFFIXES = ("_ref", "_scr", "_refs")


def _is_ref_name(name: str) -> bool:
    return name.endswith(_REF_SUFFIXES) or name in ("ref", "scratch")


def _kernel_param_names(info) -> set[str]:
    a = info.node.args
    return {p.arg for p in a.posonlyargs + a.args}


class KernelClosureRule(Rule):
    id = "PLK201"
    name = "kernel-closure"
    description = ("kernel functions must not capture traced arrays from an "
                   "enclosing scope; pass them as refs through pallas_call")

    def _defining_env(self, info):
        """Environment of a function's *defining* scope chain (closure
        variables resolve here, not at the pallas_call site)."""
        if info is None:
            return None
        return param_env(info, self._defining_env(info.parent))

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for outer, inner, kernel, scope in ctx.graph.pallas_sites:
            if kernel is None or kernel.parent is None:
                continue   # module-level kernel: its globals are static
            env = self._defining_env(kernel.parent)
            bound = set()
            node = kernel.node
            a = node.args
            for p in (a.posonlyargs + a.args + a.kwonlyargs):
                bound.add(p.arg)
            if a.vararg:
                bound.add(a.vararg.arg)
            if a.kwarg:
                bound.add(a.kwarg.arg)
            # names assigned inside the kernel are local, not captured
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    targets = (sub.targets if isinstance(sub, ast.Assign)
                               else [sub.target])
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                bound.add(n.id)
                elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if sub is not node:
                        bound.add(sub.name)
                elif isinstance(sub, ast.For):
                    for n in ast.walk(sub.target):
                        if isinstance(n, ast.Name):
                            bound.add(n.id)
                elif isinstance(sub, (ast.Lambda,)):
                    for p in sub.args.args + sub.args.kwonlyargs:
                        bound.add(p.arg)
            seen = set()
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Name) or sub.id in bound:
                    continue
                if sub.id in seen:
                    continue
                seen.add(sub.id)
                level = classify(ast.Name(id=sub.id, ctx=ast.Load()), env,
                                 ctx.imports)
                if level == TRACED:
                    findings.append(self.finding(
                        ctx, sub,
                        f"kernel '{kernel.qualname}' closes over traced "
                        f"value '{sub.id}' from "
                        f"'{kernel.parent.qualname}'; pass it through "
                        "pallas_call as a ref instead"))
        return findings


class RefIndexRule(Rule):
    id = "PLK202"
    name = "ref-index"
    description = ("refs may only be indexed with constants, slices, "
                   "pl.ds/pl.dslice and scalar index arithmetic -- no "
                   "data-dependent jnp expressions")

    def _index_ok(self, node: ast.expr, imports) -> bool:
        if isinstance(node, ast.Tuple):
            return all(self._index_ok(e, imports) for e in node.elts)
        if isinstance(node, ast.Constant):
            return True   # ints, None (open slice bounds), Ellipsis
        if isinstance(node, (ast.Name, ast.Attribute)):
            return True   # scalar locals / pl.program_id results
        if isinstance(node, ast.Slice):
            return all(p is None or self._index_ok(p, imports)
                       for p in (node.lower, node.upper, node.step))
        if isinstance(node, ast.UnaryOp):
            return self._index_ok(node.operand, imports)
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub, ast.Mult, ast.FloorDiv, ast.Mod)):
            return (self._index_ok(node.left, imports)
                    and self._index_ok(node.right, imports))
        if isinstance(node, ast.Call):
            name = dotted_name(node.func, imports)
            if name is None:
                return False
            last = name.rsplit(".", 1)[-1]
            return last in _INDEX_CALLS or name in ("len", "min", "max",
                                                    "int")
        return False

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for info in ctx.graph.kernel_functions():
            refs = {n for n in _kernel_param_names(info) if _is_ref_name(n)}
            if not refs:
                continue
            for sub in ast.walk(info.node):
                if not isinstance(sub, ast.Subscript):
                    continue
                if not (isinstance(sub.value, ast.Name)
                        and sub.value.id in refs):
                    continue
                if not self._index_ok(sub.slice, ctx.imports):
                    findings.append(self.finding(
                        ctx, sub,
                        f"ref '{sub.value.id}' in kernel '{info.qualname}' "
                        f"indexed with "
                        f"'{ast.unparse(sub.slice)}'; only slices, "
                        "constants, pl.ds and scalar arithmetic are legal "
                        "ref indices"))
        return findings


class RefAliasRule(Rule):
    id = "PLK203"
    name = "ref-alias"
    description = ("the same array must not be passed twice to one "
                   "pallas_call application (aliased input/output refs "
                   "race); use input_output_aliases for intentional donation")

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for outer, inner, kernel, scope in ctx.graph.pallas_sites:
            if outer is None:
                continue
            seen: dict[str, ast.expr] = {}
            for arg in outer.args:
                if isinstance(arg, ast.Starred):
                    continue
                if isinstance(arg, ast.Constant):
                    continue   # scalars can repeat freely
                key = ast.dump(arg)
                if key in seen:
                    findings.append(self.finding(
                        ctx, arg,
                        f"operand '{ast.unparse(arg)}' passed twice to the "
                        "same pallas_call; aliased refs make in-kernel "
                        "writes order-dependent (declare "
                        "input_output_aliases if donation is intended)"))
                else:
                    seen[key] = arg
        return findings


class GridDivisibilityRule(Rule):
    id = "PLK204"
    name = "grid-divisibility"
    description = ("where shapes and block sizes are literal, out_shape dims "
                   "must divide by the BlockSpec block and the grid must "
                   "tile them exactly")

    # -- tiny literal folder over the enclosing function ----------------------
    def _fold_env(self, scope) -> dict[str, int]:
        env: dict[str, int] = {}
        body = scope.node if scope is not None else None
        if body is None:
            return env
        for sub in ast.walk(body):
            if (isinstance(sub, ast.Assign) and len(sub.targets) == 1
                    and isinstance(sub.targets[0], ast.Name)):
                val = self._fold(sub.value, env)
                if val is not None:
                    env[sub.targets[0].id] = val
        return env

    def _fold(self, node: ast.expr, env: dict[str, int]) -> int | None:
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        if isinstance(node, ast.Name):
            return env.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self._fold(node.operand, env)
            return -v if v is not None else None
        if isinstance(node, ast.BinOp):
            l, r = self._fold(node.left, env), self._fold(node.right, env)
            if l is None or r is None:
                return None
            if isinstance(node.op, ast.Add):
                return l + r
            if isinstance(node.op, ast.Sub):
                return l - r
            if isinstance(node.op, ast.Mult):
                return l * r
            if isinstance(node.op, ast.FloorDiv) and r != 0:
                return l // r
            if isinstance(node.op, ast.Mod) and r != 0:
                return l % r
            return None
        if isinstance(node, ast.Call):
            name = dotted_name(node.func, {})
            vals = [self._fold(a, env) for a in node.args]
            if any(v is None for v in vals):
                return None
            if name in ("min", "max") and vals:
                return min(vals) if name == "min" else max(vals)
            if name is not None and name.rsplit(".", 1)[-1] == "cdiv" \
                    and len(vals) == 2 and vals[1] != 0:
                return -(-vals[0] // vals[1])
            return None
        return None

    def _dims(self, node: ast.expr | None, env) -> list[int | None]:
        if node is None or not isinstance(node, (ast.Tuple, ast.List)):
            return []
        return [self._fold(e, env) for e in node.elts]

    def _kwarg(self, call: ast.Call, name: str) -> ast.expr | None:
        for kw in call.keywords:
            if kw.arg == name:
                return kw.value
        return None

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for outer, inner, kernel, scope in ctx.graph.pallas_sites:
            env = self._fold_env(scope)

            grid_expr = self._kwarg(inner, "grid")
            out_spec_expr = self._kwarg(inner, "out_specs")
            out_shape_expr = self._kwarg(inner, "out_shape")
            # grid may live on a grid_spec constructed nearby
            gs = self._kwarg(inner, "grid_spec")
            if gs is not None and scope is not None:
                gs_call = None
                if isinstance(gs, ast.Call):
                    gs_call = gs
                elif isinstance(gs, ast.Name):
                    for sub in ast.walk(scope.node):
                        if (isinstance(sub, ast.Assign)
                                and len(sub.targets) == 1
                                and isinstance(sub.targets[0], ast.Name)
                                and sub.targets[0].id == gs.id
                                and isinstance(sub.value, ast.Call)):
                            gs_call = sub.value
                if gs_call is not None:
                    grid_expr = grid_expr or self._kwarg(gs_call, "grid")
                    out_spec_expr = out_spec_expr or self._kwarg(gs_call,
                                                                 "out_specs")

            grid = self._dims(grid_expr, env)

            # out_specs: a single BlockSpec or a tuple/list of them
            block_specs: list[ast.Call] = []
            def collect(spec):
                if isinstance(spec, ast.Call):
                    block_specs.append(spec)
                elif isinstance(spec, (ast.Tuple, ast.List)):
                    for e in spec.elts:
                        collect(e)
            collect(out_spec_expr)

            # out_shape: ShapeDtypeStruct((dims), dtype) or tuple/list
            shapes: list[list[int | None]] = []
            def collect_shape(sh):
                if isinstance(sh, ast.Call) and sh.args:
                    shapes.append(self._dims(sh.args[0], env))
                elif isinstance(sh, (ast.Tuple, ast.List)):
                    for e in sh.elts:
                        collect_shape(e)
            collect_shape(out_shape_expr)

            for i, spec in enumerate(block_specs):
                block = self._dims(spec.args[0] if spec.args else None, env)
                shape = shapes[i] if i < len(shapes) else []
                if len(block) != len(shape):
                    continue
                for d, (b, s) in enumerate(zip(block, shape)):
                    if b is None or s is None or b == 0:
                        continue
                    if s % b != 0:
                        findings.append(self.finding(
                            ctx, spec,
                            f"out_shape dim {d} = {s} is not divisible by "
                            f"BlockSpec block dim {b}; the trailing block "
                            "reads/writes out of bounds"))
                # grid * block must cover the shape when everything folds
                if grid and len(grid) == len(block):
                    for d, (g, b, s) in enumerate(zip(grid, block, shape)):
                        if None in (g, b, s) or b == 0 or s % b != 0:
                            continue
                        if g * b != s:
                            findings.append(self.finding(
                                ctx, spec,
                                f"grid dim {d} = {g} with block {b} tiles "
                                f"{g * b} elements but out_shape dim is {s}"))
        return findings


PALLAS_RULES = [KernelClosureRule(), RefIndexRule(), RefAliasRule(),
                GridDivisibilityRule()]
