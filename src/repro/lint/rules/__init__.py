"""replint rule registry.

Rule ids are grouped by family:

* ``TRC1xx`` trace-safety (host syncs, traced control flow, tracer printing)
* ``PLK2xx`` Pallas kernel rules (closures, ref indexing, aliasing, tiling)
* ``CPL3xx`` control-plane invariants (determinism, units, encapsulation)
* ``REP0xx`` meta (suppression hygiene) -- emitted by the engine itself
"""
from __future__ import annotations

from .base import Rule
from .controlplane import CONTROL_PLANE_RULES
from .pallas import PALLAS_RULES
from .trace import TRACE_RULES

#: every checkable rule, in id order
ALL_RULES: list[Rule] = sorted(
    TRACE_RULES + PALLAS_RULES + CONTROL_PLANE_RULES, key=lambda r: r.id)

#: engine-emitted meta rules, documented here so --list-rules shows them
META_RULES: list[tuple[str, str, str]] = [
    ("REP001", "suppress-no-reason",
     "every '# replint: disable=...' needs a '-- reason' string"),
    ("REP002", "unused-suppression",
     "a suppression that matches no finding must be removed"),
]


def get_rule(id_or_name: str) -> Rule | None:
    for rule in ALL_RULES:
        if rule.id == id_or_name or rule.name == id_or_name:
            return rule
    return None


__all__ = ["Rule", "ALL_RULES", "META_RULES", "get_rule"]
