"""Per-module call graph with jit- and kernel-reachability.

``replint`` rules need to know, for every function in a module, whether it
can run *inside a trace* -- under ``jax.jit``, as a ``lax.while_loop`` /
``lax.scan`` / ``lax.cond`` body, or as a Pallas kernel.  A host-sync that is
harmless in driver code silently de-optimizes (or raises) on the hot path,
so the trace-safety rules only fire on reachable functions.

The graph is deliberately *per module* (one file at a time): cross-module
calls are not resolved.  Functions that are traced entry points for *other*
modules (e.g. ``repro.models.lm.prefill``, jitted by the serving engine) are
annotated at the definition site with a ``# replint: traced`` comment on the
``def`` line or the line above, which makes them roots here.

Root discovery:

* decorators: ``@jax.jit``, ``@jit``, ``@partial(jax.jit, ...)``,
  ``@jax.checkpoint``, ``@jax.vmap`` ... (``TRACE_WRAPPERS``);
* call sites: ``jax.jit(f)``, ``jax.vmap(f)``, ``lax.while_loop(cond, body,
  ...)``, ``lax.scan(f, ...)``, ``lax.cond(p, t, f, ...)``,
  ``lax.fori_loop(lo, hi, body, ...)``, ``lax.switch(i, [f, g])``,
  ``lax.map(f, ...)`` -- positional function operands become roots;
* ``pl.pallas_call(kernel, ...)`` -- ``kernel`` becomes a *kernel* root
  (kernel-reachable implies jit-reachable);
* ``# replint: traced`` markers.

Propagation: inside a reachable function, every reference (call or bare
name) that resolves to a module-level function, an enclosing function's
nested def, a ``self.``/``cls.`` method of the enclosing class, a local
alias (``g = f`` or ``g = functools.partial(f, ...)``), or a lambda literal
marks that function reachable too.  Nested defs of a reachable function are
reachable (they execute in-trace).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

#: wrappers whose (first) functional argument runs traced
TRACE_WRAPPERS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.eval_shape", "jax.linearize",
    "jax.vjp", "jax.jvp",
    "jax.experimental.shard_map.shard_map",
}

#: control-flow primitives: which positional args are traced bodies
TRACE_BODY_ARGS = {
    "jax.lax.while_loop": (0, 1),
    "jax.lax.scan": (0,),
    "jax.lax.cond": (1, 2),
    "jax.lax.fori_loop": (2,),
    "jax.lax.map": (0,),
    "jax.lax.associative_scan": (0,),
    "jax.lax.custom_root": (0, 1, 2),
}

#: lax.switch(index, branches, *operands): every element of ``branches``
TRACE_BRANCHLIST_ARGS = {"jax.lax.switch": 1}

PALLAS_CALL = ("jax.experimental.pallas.pallas_call",)

PARTIAL = {"functools.partial", "partial"}

FuncNode = ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda


@dataclass
class FunctionInfo:
    node: FuncNode
    name: str
    qualname: str
    parent: "FunctionInfo | None" = None   # enclosing function, if nested
    class_name: str | None = None          # owning class, if a method
    jit_reachable: bool = False
    kernel_reachable: bool = False
    is_root: bool = False                  # explicitly rooted (not inherited)


@dataclass
class ModuleGraph:
    functions: dict[int, FunctionInfo] = field(default_factory=dict)
    module_funcs: dict[str, FunctionInfo] = field(default_factory=dict)
    classes: dict[str, dict[str, FunctionInfo]] = field(default_factory=dict)
    #: (outer_call, inner pallas_call Call, kernel FunctionInfo|None,
    #:  enclosing FunctionInfo|None).  ``outer_call`` is the
    #: ``pl.pallas_call(...)(*operands)`` application when present.
    pallas_sites: list[tuple] = field(default_factory=list)

    def info(self, node: FuncNode) -> FunctionInfo | None:
        return self.functions.get(id(node))

    def jit_reachable_functions(self) -> list[FunctionInfo]:
        return [f for f in self.functions.values() if f.jit_reachable]

    def kernel_functions(self) -> list[FunctionInfo]:
        return [f for f in self.functions.values() if f.kernel_reachable]


def dotted_name(node: ast.expr, imports: dict[str, str]) -> str | None:
    """Canonical dotted name of an expression, resolving import aliases.

    ``np.asarray`` -> ``numpy.asarray`` under ``import numpy as np``;
    ``pl.ds`` -> ``jax.experimental.pallas.ds``.  Returns None for anything
    that is not a plain dotted chain.
    """
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = imports.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def build_imports(tree: ast.Module) -> dict[str, str]:
    """Local name -> canonical dotted module/object path."""
    table: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                table[a.asname or a.name] = f"{node.module}.{a.name}"
    # normalize the common jax shorthands so rules can match one spelling
    for local, target in list(table.items()):
        if target == "jax.numpy":
            table[local] = "jax.numpy"
    return table


class _Collector(ast.NodeVisitor):
    """First pass: record every function/lambda with its scope context."""

    def __init__(self, graph: ModuleGraph):
        self.graph = graph
        self.func_stack: list[FunctionInfo] = []
        self.class_stack: list[str] = []

    def _add(self, node: FuncNode, name: str) -> FunctionInfo:
        parent = self.func_stack[-1] if self.func_stack else None
        cls = self.class_stack[-1] if self.class_stack and parent is None else (
            self.class_stack[-1] if self.class_stack else None)
        qual = ".".join(
            ([parent.qualname] if parent else [])
            + ([cls] if cls and not parent else []) + [name])
        info = FunctionInfo(node=node, name=name, qualname=qual,
                            parent=parent, class_name=cls)
        self.graph.functions[id(node)] = info
        if parent is None and not self.class_stack:
            self.graph.module_funcs[name] = info
        if self.class_stack and parent is None:
            self.graph.classes.setdefault(self.class_stack[-1], {})[name] = info
        return info

    def visit_ClassDef(self, node: ast.ClassDef):
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_func(self, node, name):
        info = self._add(node, name)
        self.func_stack.append(info)
        self.generic_visit(node)
        self.func_stack.pop()

    def visit_FunctionDef(self, node):
        self._visit_func(node, node.name)

    def visit_AsyncFunctionDef(self, node):
        self._visit_func(node, node.name)

    def visit_Lambda(self, node):
        self._visit_func(node, "<lambda>")


def _scope_chain(info: FunctionInfo | None) -> list[FunctionInfo]:
    out = []
    while info is not None:
        out.append(info)
        info = info.parent
    return out


class _Resolver:
    """Resolve a reference expression to a FunctionInfo, if possible."""

    def __init__(self, graph: ModuleGraph, imports: dict[str, str],
                 aliases: dict[int, dict[str, FunctionInfo]]):
        self.graph = graph
        self.imports = imports
        self.aliases = aliases  # per-function-id local name -> FunctionInfo

    def resolve(self, expr: ast.expr,
                scope: FunctionInfo | None) -> FunctionInfo | None:
        if isinstance(expr, ast.Lambda):
            return self.graph.info(expr)
        if isinstance(expr, ast.Call):
            fn = dotted_name(expr.func, self.imports)
            if fn in PARTIAL and expr.args:
                return self.resolve(expr.args[0], scope)
            if fn in TRACE_WRAPPERS and expr.args:
                return self.resolve(expr.args[0], scope)
            return None
        if isinstance(expr, ast.Name):
            for s in _scope_chain(scope):
                local = self.aliases.get(id(s.node), {})
                if expr.id in local:
                    return local[expr.id]
                # nested defs of an enclosing function
                for stmt in ast.walk(s.node):
                    if (isinstance(stmt, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                            and stmt.name == expr.id):
                        info = self.graph.info(stmt)
                        if info is not None and info.parent is s:
                            return info
            return self.graph.module_funcs.get(expr.id)
        if isinstance(expr, ast.Attribute):
            # self.method / cls.method within the enclosing class
            if (isinstance(expr.value, ast.Name)
                    and expr.value.id in ("self", "cls")):
                for s in _scope_chain(scope):
                    if s.class_name:
                        meth = self.graph.classes.get(s.class_name, {})
                        if expr.attr in meth:
                            return meth[expr.attr]
        return None


def _collect_aliases(graph: ModuleGraph, imports: dict[str, str]
                     ) -> dict[int, dict[str, FunctionInfo]]:
    """``g = f`` and ``g = functools.partial(f, ...)`` bindings per scope."""
    aliases: dict[int, dict[str, FunctionInfo]] = {}
    resolver = _Resolver(graph, imports, aliases)

    def scan(body_owner: FuncNode | ast.Module, scope: FunctionInfo | None):
        for node in ast.walk(body_owner):
            if not isinstance(node, ast.Assign):
                continue
            if len(node.targets) != 1 or not isinstance(node.targets[0],
                                                        ast.Name):
                continue
            target = resolver.resolve(node.value, scope)
            if target is not None:
                key = id(scope.node) if scope else 0
                aliases.setdefault(key, {})[node.targets[0].id] = target

    # two passes so an alias of an alias still resolves
    for _ in range(2):
        for info in graph.functions.values():
            scan(info.node, info)
    return aliases


def build_graph(tree: ast.Module, imports: dict[str, str],
                traced_lines: frozenset[int] = frozenset()) -> ModuleGraph:
    graph = ModuleGraph()
    _Collector(graph).visit(tree)
    aliases = _collect_aliases(graph, imports)
    resolver = _Resolver(graph, imports, aliases)

    # -- map every node to its enclosing function -------------------------------
    enclosing: dict[int, FunctionInfo | None] = {}

    def mark_scope(owner, scope):
        for child in ast.iter_child_nodes(owner):
            enclosing[id(child)] = scope
            child_scope = graph.info(child) if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef,
                        ast.Lambda)) else scope
            mark_scope(child, child_scope)

    mark_scope(tree, None)

    roots: list[FunctionInfo] = []
    kernel_roots: list[FunctionInfo] = []

    # -- decorator + marker roots ------------------------------------------------
    for info in graph.functions.values():
        node = info.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (node.lineno in traced_lines
                    or (node.lineno - 1) in traced_lines):
                roots.append(info)
            for dec in node.decorator_list:
                name = dotted_name(dec, imports)
                if name in TRACE_WRAPPERS or name == "jit":
                    roots.append(info)
                elif isinstance(dec, ast.Call):
                    cname = dotted_name(dec.func, imports)
                    if cname in TRACE_WRAPPERS or cname == "jit":
                        roots.append(info)
                    elif cname in PARTIAL and dec.args:
                        inner = dotted_name(dec.args[0], imports)
                        if inner in TRACE_WRAPPERS or inner == "jit":
                            roots.append(info)

    # -- call-site roots ----------------------------------------------------------
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted_name(node.func, imports)
        scope = enclosing.get(id(node))
        if fn in TRACE_WRAPPERS and node.args:
            target = resolver.resolve(node.args[0], scope)
            if target is not None:
                roots.append(target)
        elif fn in TRACE_BODY_ARGS:
            for i in TRACE_BODY_ARGS[fn]:
                if i < len(node.args):
                    target = resolver.resolve(node.args[i], scope)
                    if target is not None:
                        roots.append(target)
        elif fn in TRACE_BRANCHLIST_ARGS:
            i = TRACE_BRANCHLIST_ARGS[fn]
            if i < len(node.args) and isinstance(node.args[i],
                                                 (ast.List, ast.Tuple)):
                for el in node.args[i].elts:
                    target = resolver.resolve(el, scope)
                    if target is not None:
                        roots.append(target)
        elif fn is not None and (fn in PALLAS_CALL
                                 or fn.endswith("pallas.pallas_call")
                                 or fn == "pallas_call"):
            kernel = (resolver.resolve(node.args[0], scope)
                      if node.args else None)
            if kernel is not None:
                kernel_roots.append(kernel)
            graph.pallas_sites.append((None, node, kernel, scope))

    # attach the outer application call (pl.pallas_call(...)(operands))
    inner_ids = {id(site[1]) for site in graph.pallas_sites}
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and id(node.func) in inner_ids:
            for i, site in enumerate(graph.pallas_sites):
                if id(site[1]) == id(node.func):
                    graph.pallas_sites[i] = (node, site[1], site[2], site[3])

    # -- propagate ----------------------------------------------------------------
    def propagate(info: FunctionInfo, *, kernel: bool):
        stack = [info]
        while stack:
            cur = stack.pop()
            attr = "kernel_reachable" if kernel else "jit_reachable"
            if getattr(cur, attr):
                continue
            setattr(cur, attr, True)
            if kernel:
                cur.jit_reachable = True
            for node in ast.walk(cur.node):
                nxt = None
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not cur.node:
                    nxt = graph.info(node)
                    if nxt is not None and nxt.parent is not cur:
                        nxt = None          # handled by its own parent
                elif isinstance(node, (ast.Name, ast.Attribute)):
                    nxt = resolver.resolve(node, cur)
                if nxt is not None and not getattr(nxt, attr):
                    stack.append(nxt)

    for info in roots:
        info.is_root = True
        propagate(info, kernel=False)
    for info in kernel_roots:
        info.is_root = True
        propagate(info, kernel=True)
    return graph


__all__ = ["FunctionInfo", "ModuleGraph", "build_graph", "build_imports",
           "dotted_name", "TRACE_WRAPPERS", "TRACE_BODY_ARGS"]
