from repro.checkpoint.store import (
    CheckpointManager,
    load_checkpoint,
    restore_resharded,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "restore_resharded",
           "CheckpointManager"]
