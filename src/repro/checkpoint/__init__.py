from repro.checkpoint.store import (
    OK_SUFFIX,
    CheckpointManager,
    load_checkpoint,
    restore_resharded,
    save_checkpoint,
)

__all__ = ["OK_SUFFIX", "save_checkpoint", "load_checkpoint",
           "restore_resharded", "CheckpointManager"]
