"""Sharded checkpointing with reshard-on-restore.

Format: one ``.npz`` per checkpoint step holding every leaf (flattened tree
paths as keys) + a JSON sidecar with step metadata.  Saves go through a
temp-file rename so a crash mid-save never corrupts the latest checkpoint
(atomic on POSIX).  ``restore_resharded`` device_puts each leaf with the
NamedSharding derived for the *new* mesh -- this is the mechanism behind both
fault-tolerant restart at a different world size and the elastic serving
layer's replica scaling.

On a real multi-host pod each host writes its addressable shards and restore
uses ``jax.make_array_from_single_device_arrays``; the single-process fallback
(here) degenerates to full-array save/load with identical semantics.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading

import jax
import ml_dtypes
import numpy as np

SEP = "/"
_BF16 = "__bf16__"     # npz has no native bfloat16: stored as uint16 bit pattern
#: terminal marker written LAST by save_checkpoint: a checkpoint without it
#: was interrupted mid-save (crash between the npz rename and the sidecar,
#: or a foreign partial file) and must never be restored
OK_SUFFIX = ".ok"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:
            flat[key + _BF16] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _unflatten_into(template, flat: dict):
    def one(path, leaf):
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key + _BF16 in flat:
            arr = flat[key + _BF16].view(ml_dtypes.bfloat16)
        else:
            arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs "
                             f"model {leaf.shape}")
        return arr.astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(one, template)


def save_checkpoint(path: str, tree, *, step: int = 0, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    meta = {"step": step, **(extra or {})}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)
    # terminal marker: written only after the npz AND the sidecar are down,
    # so readers can distinguish a complete checkpoint from a torn one
    with open(path + OK_SUFFIX, "w") as f:
        f.write("ok\n")
    return path


def load_checkpoint(path: str, template):
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(template, flat)
    meta = {}
    if os.path.exists(path + ".meta.json"):
        meta = json.load(open(path + ".meta.json"))
    return tree, meta


def restore_resharded(path: str, template, shardings):
    """Load + device_put each leaf with the sharding for the NEW mesh."""
    tree, meta = load_checkpoint(path, template)
    tree = jax.device_put(tree, shardings)
    return tree, meta


class CheckpointManager:
    """Rotating checkpoint directory with async (thread) save option."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def latest(self) -> str | None:
        """Newest COMPLETE checkpoint: files missing their terminal marker
        (interrupted saves, torn copies) are skipped, so a crash mid-save
        falls back to the previous good checkpoint instead of restoring
        garbage."""
        cks = sorted(
            f for f in os.listdir(self.dir)
            if f.startswith("ckpt_") and f.endswith(".npz")
            and os.path.exists(os.path.join(self.dir, f + OK_SUFFIX)))
        return os.path.join(self.dir, cks[-1]) if cks else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree, step: int, extra: dict | None = None):
        # snapshot to host BEFORE returning control (so training can mutate
        # donated buffers); the file write happens on a background thread.
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        self.wait()

        def _write():
            save_checkpoint(self._path(step), host_tree, step=step, extra=extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self):
        cks = sorted(f for f in os.listdir(self.dir)
                     if f.startswith("ckpt_") and f.endswith(".npz"))
        for f in cks[: -self.keep]:
            for suffix in ("", ".meta.json", OK_SUFFIX):
                try:
                    os.remove(os.path.join(self.dir, f + suffix))
                except OSError:
                    pass

    def restore_latest(self, template, shardings=None):
        path = self.latest()
        if path is None:
            return None, {}
        if shardings is not None:
            return restore_resharded(path, template, shardings)
        return load_checkpoint(path, template)


__all__ = ["OK_SUFFIX", "save_checkpoint", "load_checkpoint",
           "restore_resharded", "CheckpointManager"]
