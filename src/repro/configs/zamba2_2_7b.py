"""zamba2-2.7b: 54L Mamba2 stack + ONE shared attention(+MLP) block applied
every 6th layer [arXiv:2411.15242]."""
from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, ssm=SSMConfig(d_state=64, head_dim=64, n_groups=1, expand=2),
    shared_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, expand=2, chunk=8),
    shared_attn_every=3, remat="none",
)
