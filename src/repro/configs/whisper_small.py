"""whisper-small: enc-dec, conv frontend stubbed (precomputed frame
embeddings) [arXiv:2212.04356]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, enc_len=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, enc_len=32, remat="none",
)
