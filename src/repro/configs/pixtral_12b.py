"""pixtral-12b: pixtral-ViT frontend (stubbed: precomputed patch embeddings)
+ mistral-nemo-style dense backbone [hf:mistralai/Pixtral-12B-2409]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=131072, head_dim=128, rope_theta=1_000_000.0,
    input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    input_mode="embeddings", remat="none",
)
