"""gemma3-4b: 5:1 local(1024-SWA):global interleave, 128k context, 256k vocab
[hf:google/gemma-3-4b-pt]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab=262144, head_dim=256, window=1024, global_every=6,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, window=8, global_every=3, remat="none",
)
