"""smollm-360m: llama-arch small [hf:HuggingFaceTB/SmolLM-360M]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560, vocab=49152,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=3, d_model=48, n_heads=3, n_kv_heads=1, d_ff=128, vocab=256,
    tie_embeddings=True, remat="none",
)
