"""qwen2.5-3b: GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-3B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    qkv_bias=True, remat="none",
)
