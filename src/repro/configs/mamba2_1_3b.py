"""mamba2-1.3b: attention-free SSD [arXiv:2405.21060]."""
from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, expand=2),
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
    ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, expand=2, chunk=8),
    remat="none",
)
