"""mixtral-8x22b: 8-expert top-2 MoE, SWA 4096 [arXiv:2401.04088]."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
    window=16, moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=4.0), remat="none",
)
