"""Architecture registry: one module per assigned arch (+ helpers).

``get_config(arch_id)`` -> full ModelConfig (exact published sizes)
``get_smoke_config(arch_id)`` -> reduced same-family config for CPU smoke tests
``SHAPES`` -> the four assigned input-shape sets
``input_specs(cfg, shape)`` -> ShapeDtypeStruct stand-ins for every model input
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCHS = [
    "zamba2-2.7b", "smollm-360m", "smollm-135m", "gemma3-4b", "qwen2.5-3b",
    "olmoe-1b-7b", "mixtral-8x22b", "whisper-small", "mamba2-1.3b", "pixtral-12b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _mod(arch: str):
    return importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def shape_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Is (arch x shape) a valid dry-run cell? (see DESIGN.md SSArch-applicability)"""
    sp = SHAPES[shape]
    if sp.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode is out of the assigned set"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, *, per_host: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step function implied by
    ``shape`` (train_step for train shapes, serve prefill/decode otherwise)."""
    sp = SHAPES[shape]
    B, S = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    d = cfg.d_model
    sds = jax.ShapeDtypeStruct
    if cfg.family in ("audio", "encdec"):
        enc = sds((B, cfg.enc_len, d), jnp.float32)
        if sp.kind == "train":
            return {"enc_embeds": enc, "tokens": sds((B, S), i32),
                    "targets": sds((B, S), i32)}
        if sp.kind == "prefill":
            return {"enc_embeds": enc, "tokens": sds((B, S), i32)}
        return {"token": sds((B, 1), i32)}           # decode
    if cfg.input_mode == "embeddings":
        if sp.kind == "train":
            return {"embeds": sds((B, S, d), jnp.float32),
                    "targets": sds((B, S), i32)}
        if sp.kind == "prefill":
            return {"embeds": sds((B, S, d), jnp.float32)}
        return {"token": sds((B, 1), i32)}
    if sp.kind == "train":
        return {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
    if sp.kind == "prefill":
        return {"tokens": sds((B, S), i32)}
    return {"token": sds((B, 1), i32)}


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_config", "get_smoke_config",
           "shape_supported", "input_specs"]
