"""Small statistics helpers shared by the simulator and the analysis benchmarks."""
from __future__ import annotations

import math

import numpy as np


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    """Pearson correlation coefficient (nan-safe)."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if x.size < 2 or y.size < 2:
        return float("nan")
    xc = x - x.mean()
    yc = y - y.mean()
    denom = math.sqrt(float(xc @ xc) * float(yc @ yc))
    if denom == 0.0:
        return float("nan")
    return float(xc @ yc) / denom


# Two-sided 97.5% normal quantile; the paper runs scenarios "until the length of the
# confidence interval with 95% confidence was smaller than 10% of the mean".
_Z975 = 1.959963984540054


def mean_confidence_interval(samples) -> tuple[float, float]:
    """Return (mean, full CI length) of the 95% normal-approx confidence interval."""
    a = np.asarray(samples, dtype=np.float64)
    if a.size == 0:
        return float("nan"), float("inf")
    m = float(a.mean())
    if a.size == 1:
        return m, float("inf")
    se = float(a.std(ddof=1)) / math.sqrt(a.size)
    return m, 2.0 * _Z975 * se


def ci_converged(samples, rel: float = 0.10) -> bool:
    """Paper's stopping rule: CI length < ``rel`` x mean (needs >= 2 samples)."""
    a = np.asarray(samples, dtype=np.float64)
    if a.size < 2:
        return False
    m, length = mean_confidence_interval(a)
    if m == 0.0:
        # Degenerate (e.g. zero violations in every repetition): converged.
        return float(a.std(ddof=1)) == 0.0
    return length < rel * abs(m)
