from repro.utils.stats import mean_confidence_interval, pearson
