"""Fault-tolerant training driver.

Single entry point for real runs and for the CPU-scale examples:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 200 --batch 8 --seq 128

Fault tolerance:
* checkpoint every ``--ckpt-every`` steps (async, atomic, rotating);
* on start, auto-resume from the latest checkpoint (params + optimizer + step);
* deterministic data: batch i depends only on (seed, i), so a restart replays
  the exact stream;
* ``--simulate-failure N`` kills the process at step N (exit 17); the outer
  supervisor loop (``--supervise``) restarts it, proving end-to-end
  checkpoint/restart.  On a real cluster the supervisor is the job scheduler;
  the in-process logic is identical.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def train(args) -> int:
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, get_smoke_config
    from repro.data import DataConfig, TokenStream
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init
    from repro.training import make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    params = model.init_params(jax.random.key(args.seed))
    opt_state = adamw_init(params)
    start_step = 0
    state_tmpl = {"params": params, "opt": opt_state}
    restored, meta = ckpt.restore_latest(state_tmpl)
    if restored is not None:
        params, opt_state = restored["params"], restored["opt"]
        start_step = int(meta.get("step", 0))
        print(f"[train] resumed from step {start_step}", flush=True)

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if args.simulate_failure >= 0 and step == args.simulate_failure:
            print(f"[train] SIMULATED FAILURE at step {step}", flush=True)
            os._exit(17)
        batch = data.batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                  f"({dt:.1f}s)", flush=True)
        if step > start_step and step % args.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt_state}, step=step + 1)
    ckpt.save({"params": params, "opt": opt_state}, step=args.steps)
    ckpt.wait()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})", flush=True)
    return 0


def supervise(argv: list[str], max_restarts: int = 5) -> int:
    """Heartbeat supervisor: restart the training subprocess on failure."""
    for attempt in range(max_restarts + 1):
        child = [sys.executable, "-m", "repro.launch.train"] + argv
        print(f"[supervisor] launch attempt {attempt}: {' '.join(child)}", flush=True)
        p = subprocess.run(child, env={**os.environ, "REPRO_SUPERVISED": "1"})
        if p.returncode == 0:
            print("[supervisor] run completed", flush=True)
            return 0
        print(f"[supervisor] child exited rc={p.returncode}; restarting "
              f"(node-failure recovery path)", flush=True)
        # after the first restart, stop injecting failures
        if "--simulate-failure" in argv:
            i = argv.index("--simulate-failure")
            argv = argv[:i] + argv[i + 2:]
    return 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--supervise", action="store_true")
    args, rest = ap.parse_known_args()

    if args.supervise and not os.environ.get("REPRO_SUPERVISED"):
        argv = [a for a in sys.argv[1:] if a != "--supervise"]
        sys.exit(supervise(argv))
    sys.exit(train(args))


if __name__ == "__main__":
    main()
