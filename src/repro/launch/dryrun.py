import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. constructs the step function implied by the shape (train_step for
     ``train_*``, prefill for ``prefill_*``, serve decode for ``decode_*``),
  3. jits it with explicit in/out shardings, lowers with ShapeDtypeStruct
     inputs (no allocation), compiles,
  4. records memory_analysis / cost_analysis / collective bytes to JSONL.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k \
      --mesh single --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, input_specs, shape_supported
from repro.distributed.hlo_analysis import collective_stats, roofline_terms
from repro.distributed.sharding import batch_sharding, cache_sharding, param_sharding
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def _abstract(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def build_cell(arch: str, shape: str, mesh, cfg_override=None):
    """Returns (jitted_fn, example_args_abstract) for the cell."""
    from repro.distributed import moe_ep
    moe_ep.set_ep_mesh(mesh)
    cfg = cfg_override or get_config(arch)
    model = build_model(cfg)
    sp = SHAPES[shape]
    specs = input_specs(cfg, shape)
    p_abs = model.abstract_params()
    p_sh = param_sharding(p_abs, mesh)

    if sp.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(model, opt_cfg)
        o_abs = jax.eval_shape(adamw_init, p_abs)
        o_sh = param_sharding(o_abs, mesh)
        b_sh = batch_sharding(specs, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        args = (p_abs, o_abs, specs)
    elif sp.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_len=sp.seq_len)
        c_abs = jax.eval_shape(lambda: model.init_cache(sp.global_batch, sp.seq_len))
        c_sh = cache_sharding(c_abs, cfg, mesh)
        b_sh = batch_sharding(specs, mesh)
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh),
                     out_shardings=(None, c_sh))
        args = (p_abs, specs)
    else:  # decode
        c_abs = jax.eval_shape(lambda: model.init_cache(sp.global_batch, sp.seq_len))
        c_sh = cache_sharding(c_abs, cfg, mesh)

        def serve_step(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos)

        b_sh = batch_sharding(specs, mesh)
        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, b_sh["token"], None),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,))
        args = (p_abs, c_abs, specs["token"],
                jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args


def run_cell(arch: str, shape: str, mesh_kind: str, *, keep_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why, wall_s=0.0)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        with mesh:
            fn, args = build_cell(arch, shape, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            try:
                mem = compiled.memory_analysis()
                mem_d = {
                    "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                } if mem is not None else None
            except Exception as e:
                mem_d = {"error": repr(e)}
            try:
                cost = compiled.cost_analysis()
                cost_d = {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "optimal_seconds")
                          if cost and k in cost}
            except Exception as e:
                cost, cost_d = None, {"error": repr(e)}
            hlo = compiled.as_text()
            coll = collective_stats(hlo)
            n_dev = mesh.size
            flops = (cost or {}).get("flops", 0.0) or 0.0
            hbm = (cost or {}).get("bytes accessed", 0.0) or 0.0
            terms = roofline_terms(flops, hbm, coll.total_bytes / n_dev)
            rec.update(
                status="ok",
                devices=n_dev,
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory=mem_d,
                cost=cost_d,
                collectives=coll.as_dict(),
                roofline=terms,
            )
            if keep_hlo:
                rec["hlo_len"] = len(hlo)
    except Exception as e:
        rec.update(status="error", error=repr(e),
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for mk in meshes:
                cells.append((a, s, mk))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    with open(args.out, "a") as f:
        for a, s, mk in cells:
            if (a, s, mk) in done:
                print(f"[skip-done] {a} {s} {mk}", flush=True)
                continue
            print(f"[cell] {a} {s} {mk} ...", flush=True)
            rec = run_cell(a, s, mk)
            f.write(json.dumps(rec) + "\n")
            f.flush()
            print(f"  -> {rec['status']} wall={rec.get('wall_s', 0)}s "
                  f"{rec.get('error', '')[:200]}", flush=True)


if __name__ == "__main__":
    main()
