"""Serving driver: continuous-batching engine under a bursty request stream,
with SLA accounting, straggler mitigation, and the scaling control plane
driving decode-slot elasticity.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 40 --sla 20 --policy target

The driver is a :class:`repro.core.scaling.ScalableBackend` over the *live*
:class:`~repro.serving.ServingEngine` (real JAX prefill/decode): the unit of
elasticity is a decode SLOT, provisioning delay models cache/compile warmup,
and the ``output_score`` SignalBus channel carries each request's
application-output signal -- the engine-computed running mean logprob of the
tokens actually generated, not a synthetic driver-side stand-in.  Any
registered policy (``--policy threshold``, ``target``, ...) can manage the
slot pool.

Straggler mitigation: a slot whose request has produced no token for
``--stall-steps`` engine steps (a stuck replica shard / preempted host in
production) is evicted and the request re-enqueued -- the serving analogue of
backup task dispatch.  The eviction path is exercised by
tests/test_serving_driver.py via a fault-injection hook.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core.scaling import (
    ControllerConfig,
    RunReport,
    ScalingController,
    SignalBus,
    make_policy,
)


class DrainTimeout(RuntimeError):
    """The virtual-time loop ran far past the horizon without draining."""


class ServeBackend:
    """ScalableBackend over a live ServingEngine (unit = decode slot).

    ``pools`` types the slot capacity (e.g. an on-demand pool plus a cheap
    preemptible one whose slots model borrowed capacity that can be revoked);
    ``sla`` adds per-request-class deadlines.  Both default to the legacy
    single-pool / flat-SLA configuration.
    """

    def __init__(self, eng, requests, *, sla_s: float, horizon_s: float,
                 policy=None, adapt_period_s: float = 5.0,
                 provision_delay_s: float = 3.0, app_window_s: float = 10.0,
                 starting_slots: int = 1, stall_steps: float = 50.0,
                 pools=None, sla=None, decode_steps: int = 1,
                 convergence: bool = False, faults=None, audit_path=None):
        self.eng = eng
        # tokens each slot advances per virtual second (one K-step device
        # loop per step); 1 keeps the classic one-token-per-second clock
        self.decode_steps = max(int(decode_steps), 1)
        self.requests = sorted(requests, key=lambda r: r.arrival_s)
        self.sla_s = sla_s
        self.sla = sla
        self.horizon_s = horizon_s
        self.stall_steps = stall_steps
        self.evictions = 0
        if policy is None:
            policy = make_policy("target")   # same default as the CLI path
        self.controller = ScalingController(
            policy,
            ControllerConfig(
                adapt_period_s=adapt_period_s,
                provision_delay_s=provision_delay_s,
                min_units=1,
                max_units=eng.cfg.max_batch,
                step_s=1.0,
                app_window_s=app_window_s,
                signal_channel="output_score",
                pools=pools,
                convergence=convergence,
                faults=faults,
                audit_path=audit_path,
            ),
            SignalBus(("output_score",), bin_s=1.0),
            starting_units=starting_slots,
        )

    def run(self) -> RunReport:
        eng, ctrl = self.eng, self.controller
        bus = ctrl.bus
        t = 0.0
        head = 0
        n_reported = 0                      # completed requests already on the bus
        last_progress: dict[int, tuple[int, float]] = {}
        units_hist: list[int] = []

        while head < len(self.requests) or eng.n_in_system:
            units = ctrl.on_step_start(t)
            eng.slot_limit = units
            new_arr = 0
            while head < len(self.requests) and self.requests[head].arrival_s <= t:
                eng.submit(self.requests[head])
                head += 1
                new_arr += 1
            served = eng.step(now=t, decode_steps=self.decode_steps)
                                       # slots that advanced, incl. ones that
                                       # finished this step (active is already
                                       # drained of them by now)
            # straggler mitigation: evict slots that stopped producing tokens
            for slot, req in list(eng.active.items()):
                n_out = len(req.output)
                if last_progress.get(req.rid, (-1, t))[0] == n_out:
                    if t - last_progress[req.rid][1] > self.stall_steps:
                        eng.evict(slot)          # backup dispatch
                        self.evictions += 1
                        last_progress.pop(req.rid)
                else:
                    last_progress[req.rid] = (n_out, t)
            # application-output signal (engine-computed mean decode logprob),
            # indexed by request arrival time (§V-B)
            fresh = eng.completed[n_reported:]
            if fresh:
                bus.record("output_score",
                           np.array([r.arrival_s for r in fresh]),
                           np.array([r.score for r in fresh]))
                for r in fresh:
                    last_progress.pop(r.rid, None)
                n_reported = len(eng.completed)
            units_hist.append(units)
            # served can exceed units right after a scale-in (old slots drain
            # out); clamp so utilization keeps its busy-fraction contract
            ctrl.note_step(min(1.0, served / max(units, 1)), new_arr)
            ctrl.maybe_adapt(time=t + 1.0, n_in_system=eng.n_in_system)
            t += 1.0
            if t > self.horizon_s + 10_000:
                raise DrainTimeout("serve backend failed to drain")

        units_arr = np.asarray(units_hist, dtype=np.int64)
        lat = np.array([r.done_s - r.arrival_s for r in eng.completed])
        classes = np.array(
            [f"p{r.request_class[0]}d{r.request_class[1]}" for r in eng.completed])
        return RunReport(
            backend="serve",
            workload=f"{len(self.requests)} requests",
            policy=ctrl.policy.describe(),
            sla_s=self.sla_s,
            latencies=lat,
            unit_seconds=float(units_arr.sum()),
            units_t=units_arr,
            n_decisions_up=ctrl.n_up,
            n_decisions_down=ctrl.n_down,
            unit_name="slot",
            decisions=ctrl.decision_log,
            sla=self.sla,
            classes=classes,
            extra={"evictions": self.evictions, "engine_steps": eng.step_count,
                   "prefill_occupancy": eng.prefill_occupancy},
            **ctrl.plan.report_kwargs(),
        )


def serve(args) -> int:
    from repro.configs import get_config, get_smoke_config
    from repro.data import request_stream
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(args.seed))
    serve_cfg = ServeConfig(max_batch=args.batch, max_len=args.max_len,
                            page_size=args.page_size,
                            decode_steps=args.decode_steps)

    stream = request_stream(n_requests=args.requests, seed=args.seed,
                            mean_prompt=args.mean_prompt,
                            mean_decode=args.mean_decode,
                            burst_times=(args.horizon * 0.5,),
                            horizon_s=args.horizon)
    reqs = []
    for i, (t, p, d) in enumerate(stream):
        # Request.score is left at its default: the ENGINE fills it with the
        # running mean logprob of the tokens it generates, which is what the
        # output_score channel records below.
        reqs.append(Request(
            rid=i, arrival_s=t,
            prompt=np.random.default_rng(i).integers(
                0, cfg.vocab, min(p, args.max_len // 2)).astype(np.int32),
            max_new_tokens=max(min(d, args.max_len // 4), 1)))

    from repro.core.scaling import available_policies
    # policies whose observation tiers are meaningful for the slot backend:
    # 'load' prices work in tweet-trace CPU cycles and 'scheduled' needs a
    # schedule, neither of which the CLI can supply
    supported = ("appdata", "target", "threshold")
    if args.policy:
        if args.policy not in available_policies():
            print(f"[serve] unknown policy {args.policy!r}; registered: "
                  f"{', '.join(available_policies())}", file=sys.stderr)
            return 2
        if args.policy not in supported:
            print(f"[serve] policy {args.policy!r} is not usable on the slot "
                  f"backend from the CLI; supported: {', '.join(supported)}",
                  file=sys.stderr)
            return 2
    policy = make_policy(args.policy) if args.policy else None

    if args.replicas > 1:
        # fleet mode: the unit of elasticity is a whole ENGINE, spawned from
        # a checkpoint with a measured provisioning delay and drained with
        # in-flight migration (see repro.serving.fleet)
        import os
        import tempfile

        from repro.checkpoint import save_checkpoint
        from repro.serving.fleet import ReplicaPool, FleetBackend
        ckpt_dir = tempfile.mkdtemp(prefix="fleet-ckpt-")
        ckpt = save_checkpoint(os.path.join(ckpt_dir, "ckpt_00000001.npz"),
                               params, step=0)
        pool = ReplicaPool(model, ckpt, serve_cfg)
        backend = FleetBackend(pool, reqs, sla_s=args.sla,
                               horizon_s=args.horizon, policy=policy,
                               starting_replicas=1,
                               max_replicas=args.replicas,
                               decode_steps=args.decode_steps,
                               audit_path=args.audit_path)
        t0 = time.time()
        try:
            rep = backend.run()
        except DrainTimeout:
            print("[serve] fleet failed to drain", file=sys.stderr)
            return 1
        measured = rep.pool_provision_delay_s.get("replica", 0.0)
        print(f"[serve] fleet completed {rep.n_done}/{len(reqs)} requests "
              f"({time.time() - t0:.1f}s wall) under {rep.policy}")
        print(f"[serve] latency mean {rep.mean_latency_s:.1f} "
              f"p99 {rep.p99_latency_s:.1f} (virtual s); "
              f"SLA({args.sla}s) violations {100 * rep.violation_rate:.2f}%; "
              f"replicas peak {rep.max_units}/{args.replicas}; "
              f"measured provisioning delay {measured:.2f}s")
        return 0

    eng = ServingEngine(model, params, serve_cfg)
    backend = ServeBackend(eng, reqs, sla_s=args.sla, horizon_s=args.horizon,
                           policy=policy, stall_steps=args.stall_steps,
                           decode_steps=args.decode_steps,
                           convergence=args.convergence,
                           audit_path=args.audit_path)
    t0 = time.time()
    try:
        rep = backend.run()
    except DrainTimeout:
        print("[serve] failed to drain", file=sys.stderr)
        return 1

    print(f"[serve] completed {rep.n_done}/{len(reqs)} requests in "
          f"{eng.step_count} steps ({time.time() - t0:.1f}s wall) "
          f"under {rep.policy}")
    print(f"[serve] latency mean {rep.mean_latency_s:.1f} "
          f"p99 {rep.p99_latency_s:.1f} (virtual s); "
          f"SLA({args.sla}s) violations {100 * rep.violation_rate:.2f}%; "
          f"slots peak {rep.max_units}/{args.batch}; "
          f"stragglers evicted {backend.evictions}; "
          f"prefill occupancy {eng.prefill_occupancy:.2f} "
          f"(page size {eng.kv.page_size if eng.paged else '-'})")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mean-prompt", type=int, default=16)
    ap.add_argument("--mean-decode", type=int, default=8)
    ap.add_argument("--horizon", type=float, default=60.0)
    ap.add_argument("--sla", type=float, default=20.0)
    ap.add_argument("--stall-steps", type=float, default=50.0)
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV page size (default: autotuned per backend, see "
                         "repro.kernels.decode_attention.autotune)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="ceiling on serving-engine replicas; > 1 switches to "
                         "fleet mode (repro.serving.fleet): starts at one "
                         "replica spawned from a checkpoint and lets the "
                         "convergence plane scale the fleet elastically, with "
                         "measured provisioning delays and drain-migration")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="tokens each slot advances per virtual second (one "
                         "K-step device loop per engine step); 1 keeps the "
                         "classic one-token-per-second virtual clock")
    ap.add_argument("--convergence", action="store_true",
                    help="drive slot capacity through the convergence control "
                         "plane (desired-state reconciliation; see "
                         "repro.core.convergence) instead of imperative deltas")
    ap.add_argument("--audit-path", default=None,
                    help="mirror the convergence audit log to this JSONL file")
    ap.add_argument("--policy", default=None,
                    help="registered policy name (default: the backend's "
                         "target-tracking rule; see repro.core.scaling)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    sys.exit(serve(args))


if __name__ == "__main__":
    main()
