"""Serving driver: continuous-batching engine under a bursty request stream,
with SLA accounting and straggler mitigation.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 40 --sla 20

Straggler mitigation: a slot whose request has produced no token for
``--stall-steps`` engine steps (a stuck replica shard / preempted host in
production) is evicted and the request re-enqueued -- the serving analogue of
backup task dispatch.  The eviction path is exercised by
tests/test_serving_driver.py via a fault-injection hook.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np


def serve(args) -> int:
    from repro.configs import get_config, get_smoke_config
    from repro.data import request_stream
    from repro.models import build_model
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(args.seed))
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=args.batch, max_len=args.max_len))

    stream = request_stream(n_requests=args.requests, seed=args.seed,
                            mean_prompt=args.mean_prompt,
                            mean_decode=args.mean_decode,
                            burst_times=(args.horizon * 0.5,),
                            horizon_s=args.horizon)
    reqs = [Request(rid=i, arrival_s=t,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab, min(p, args.max_len // 2)).astype(np.int32),
                    max_new_tokens=max(min(d, args.max_len // 4), 1))
            for i, (t, p, d) in enumerate(stream)]

    # virtual-time loop: 1 engine step == one decode tick
    t = 0.0
    head = 0
    last_progress = {}
    evictions = 0
    t0 = time.time()
    while head < len(reqs) or eng.n_in_system:
        while head < len(reqs) and reqs[head].arrival_s <= t:
            eng.submit(reqs[head])
            head += 1
        eng.step(now=t)
        # straggler mitigation: evict slots that stopped producing tokens
        for slot, req in list(eng.active.items()):
            n_out = len(req.output)
            if last_progress.get(req.rid, (-1, t))[0] == n_out:
                if t - last_progress[req.rid][1] > args.stall_steps:
                    eng.active.pop(slot)
                    req.output.clear()
                    eng.submit(req)          # backup dispatch
                    evictions += 1
                    last_progress.pop(req.rid)
            else:
                last_progress[req.rid] = (n_out, t)
        t += 1.0
        if t > args.horizon + 10_000:
            print("[serve] failed to drain", file=sys.stderr)
            return 1

    lat = np.array([r.done_s - r.arrival_s for r in eng.completed])
    viol = float(np.mean(lat > args.sla)) if lat.size else 0.0
    print(f"[serve] completed {len(eng.completed)}/{len(reqs)} requests in "
          f"{eng.step_count} steps ({time.time() - t0:.1f}s wall)")
    print(f"[serve] latency mean {lat.mean():.1f} p99 {np.quantile(lat, 0.99):.1f} "
          f"(virtual s); SLA({args.sla}s) violations {100 * viol:.2f}%; "
          f"stragglers evicted {evictions}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mean-prompt", type=int, default=16)
    ap.add_argument("--mean-decode", type=int, default=8)
    ap.add_argument("--horizon", type=float, default=60.0)
    ap.add_argument("--sla", type=float, default=20.0)
    ap.add_argument("--stall-steps", type=float, default=50.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    sys.exit(serve(args))


if __name__ == "__main__":
    main()
