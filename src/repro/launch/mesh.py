"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic serving re-meshes at varying DP degrees)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that carry the batch: ('pod', 'data') when 'pod' exists."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


__all__ = ["make_production_mesh", "make_mesh", "data_axes", "model_axis_size"]
