"""Tiny policy registry: name -> factory, so launchers, benchmarks and configs
can name policies (``--policy target``) without importing their classes.

`repro.core.autoscaler.policies` registers the built-ins at import time.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable

if TYPE_CHECKING:  # runtime import is deferred: policies.py imports this module
    from repro.core.autoscaler.base import Policy

_FACTORIES: dict[str, Callable[..., Policy]] = {}


def register_policy(name: str, factory: Callable[..., Policy] | None = None):
    """Register a policy factory.  Usable directly or as a class decorator:

        @register_policy("threshold")
        class ThresholdPolicy(Policy): ...
    """
    def _register(fn: Callable[..., Policy]):
        if name in _FACTORIES:
            raise ValueError(f"policy {name!r} already registered")
        _FACTORIES[name] = fn
        return fn

    if factory is not None:
        return _register(factory)
    return _register


def make_policy(name: str, **kwargs) -> Policy:
    """Instantiate a registered policy by name."""
    import repro.core.autoscaler.policies  # noqa: F401  (built-in registrations)
    if name not in _FACTORIES:
        raise KeyError(f"unknown policy {name!r}; known: {available_policies()}")
    return _FACTORIES[name](**kwargs)


def available_policies() -> tuple[str, ...]:
    import repro.core.autoscaler.policies  # noqa: F401
    return tuple(sorted(_FACTORIES))


__all__ = ["available_policies", "make_policy", "register_policy"]
