"""The scaling control plane: monitoring (SignalBus), decision/actuation
(ScalingController), and the backend/result contract (ScalableBackend,
RunReport) every scaled system shares.  See DESIGN.md."""
from repro.core.scaling.signals import DEFAULT_CHANNEL, SignalBus, WindowStats
from repro.core.scaling.controller import (
    ControllerConfig,
    DecisionRecord,
    ScalingController,
)
from repro.core.scaling.backend import RunReport, ScalableBackend, compare
from repro.core.scaling.registry import (
    available_policies,
    make_policy,
    register_policy,
)

__all__ = [
    "DEFAULT_CHANNEL", "SignalBus", "WindowStats",
    "ControllerConfig", "DecisionRecord", "ScalingController",
    "RunReport", "ScalableBackend", "compare",
    "available_policies", "make_policy", "register_policy",
]
