"""The scaling control plane: monitoring (SignalBus), decision/actuation
(ScalingController over a typed CapacityPlan of UnitPools), the shared
water-filling service core (ServiceProcess), and the backend/result contract
(ScalableBackend, RunReport with priced cost and per-class SLAs) every scaled
system shares.  See DESIGN.md."""
from repro.core.scaling.signals import DEFAULT_CHANNEL, SignalBus, WindowStats
from repro.core.scaling.capacity import (
    DEFAULT_POOL,
    CapacityPlan,
    PoolStats,
    RevocationEvent,
    Sla,
    UnitPool,
)
from repro.core.scaling.controller import (
    ControllerConfig,
    DecisionRecord,
    ScalingController,
)
from repro.core.scaling.service import ServiceProcess, StepResult, water_level
from repro.core.scaling.backend import RunReport, ScalableBackend, compare
from repro.core.scaling.registry import (
    available_policies,
    make_policy,
    register_policy,
)

__all__ = [
    "DEFAULT_CHANNEL", "SignalBus", "WindowStats",
    "DEFAULT_POOL", "CapacityPlan", "PoolStats", "RevocationEvent", "Sla",
    "UnitPool",
    "ControllerConfig", "DecisionRecord", "ScalingController",
    "ServiceProcess", "StepResult", "water_level",
    "RunReport", "ScalableBackend", "compare",
    "available_policies", "make_policy", "register_policy",
]
