"""The typed capacity model behind the actuation tier.

The paper's headline result is economic -- fewer SLA violations at fewer
resources -- but a scalar ``units: int`` cannot express the economics: real
fleets mix unit *kinds* with different prices, provisioning delays, and
reliability (on-demand vs spot/preemptible), and real SLAs are per request
class, not global.  This module types that out:

* :class:`UnitPool` -- one kind of capacity: a name, its provisioning delay,
  its price per unit-hour, floor/ceiling, and (for preemptible pools) a
  seeded revocation process (each live unit survives a step with probability
  ``exp(-revoke_rate * step_s)``; revocations land at step start, the DEPAS
  node-churn scenario).
* :class:`CapacityPlan` -- the live state over an *ordered* sequence of
  pools: per-pool live counts, per-pool pending queues (allocations inside
  their provisioning delay), per-pool unit-second meters, and the revocation
  log.  Downscale releases the most expensive capacity first, and within a
  pool cancels still-pending allocations (newest-first) before touching live
  units -- releasing a live unit while a pending one lands moments later is
  pure waste.
* :class:`Sla` -- the service-level spec: a default completion deadline plus
  per-request-class overrides, so a report can price violations per class.

A plan with a single on-demand pool is mechanically identical to the
pre-redesign scalar controller state (same landing, clamping and floor
behavior), which is what keeps the golden parity tests bit-for-bit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Iterator, Mapping, Sequence

import numpy as np

#: pool name used when a config does not say otherwise
DEFAULT_POOL = "on-demand"


@dataclass(frozen=True)
class UnitPool:
    """One kind of capacity ('unit' stays backend-defined: CPU / replica / slot)."""

    name: str
    provision_delay_s: float = 60.0
    cost_rate: float = 1.0            # price per unit-hour
    min_units: int = 0                # floor for *voluntary* release (revocation
                                      # is involuntary and ignores it)
    max_units: int = 4096
    starting_units: int | None = None  # None: plan-level default (first pool
                                       # gets the controller's starting_units)
    preemptible: bool = False
    revoke_rate: float = 0.0          # per-unit hazard, 1/s (0 = never revoked)
    revoke_seed: int = 0

    def __post_init__(self):
        if not self.name:
            raise ValueError("UnitPool needs a non-empty name")
        if self.provision_delay_s < 0.0:
            raise ValueError(f"provision_delay_s must be >= 0, got "
                             f"{self.provision_delay_s}")
        if self.cost_rate < 0.0:
            raise ValueError(f"cost_rate must be >= 0, got {self.cost_rate}")
        if not 0 <= self.min_units <= self.max_units:
            raise ValueError(f"need 0 <= min_units <= max_units, got "
                             f"[{self.min_units}, {self.max_units}]")
        if self.revoke_rate < 0.0:
            raise ValueError(f"revoke_rate must be >= 0, got {self.revoke_rate}")
        if self.revoke_rate > 0.0 and not self.preemptible:
            raise ValueError(f"pool {self.name!r} has revoke_rate > 0 but is "
                             f"not marked preemptible")


@dataclass(frozen=True)
class PoolStats:
    """Per-pool view a policy sees in ``Observation.pools``."""

    units: int
    pending: int
    cost_rate: float
    min_units: int = 0
    max_units: int = 4096
    preemptible: bool = False
    revoked: int = 0                  # cumulative revocations so far
    unhealthy: int = 0                # live units currently failing health checks
    lost: int = 0                     # cumulative units lost to injected faults
    overflow: int = 0                 # cumulative units refused by the ceiling

    @property
    def headroom(self) -> int:
        """Units this pool can still take (live + pending below the ceiling)."""
        return max(self.max_units - self.units - self.pending, 0)


@dataclass
class PoolMeters:
    """Cumulative per-pool accounting, the plan's conservation ledger.

    Two invariants hold under ANY interleaving of request/land/release/
    cancel/drain and injected faults (pinned by the property tests):

    * ``live  == starting + landed - released - revoked - lost``
    * ``pending == queued - landed - cancelled - overflow_landed``
    """

    queued: int = 0            # units actually queued by request()
    landed: int = 0            # pending units that became live
    cancelled: int = 0         # pending units cancelled before landing
    released: int = 0          # live units voluntarily released (incl. drains)
    revoked: int = 0           # preemptible revocations
    lost: int = 0              # injected unit-loss faults
    overflow_request: int = 0  # units refused at request() (no ceiling headroom)
    overflow_landed: int = 0   # units discarded at landing (ceiling clamp)

    @property
    def overflow(self) -> int:
        """Total units the ceiling turned away, at either end of the queue."""
        return self.overflow_request + self.overflow_landed


@dataclass(frozen=True)
class RevocationEvent:
    """``count`` preemptible units of ``pool`` revoked at step start ``time``."""

    time: float
    pool: str
    count: int


@dataclass(frozen=True)
class FaultEvent:
    """One injected-fault occurrence (``kind``: unit_loss / stuck_build /
    flap / heal) of ``count`` units in ``pool`` at ``time``."""

    time: float
    pool: str
    kind: str
    count: int


@dataclass(frozen=True)
class Sla:
    """Completion-deadline spec: a default plus per-request-class overrides."""

    default_s: float
    per_class: Mapping[str, float] = field(default_factory=dict)

    def __post_init__(self):
        if self.default_s <= 0.0:
            raise ValueError(f"default_s must be positive, got {self.default_s}")
        for cls, d in self.per_class.items():
            if d <= 0.0:
                raise ValueError(f"deadline for class {cls!r} must be positive, "
                                 f"got {d}")

    def deadline_s(self, request_class: str) -> float:
        return self.per_class.get(request_class, self.default_s)

    def deadlines(self, classes: np.ndarray) -> np.ndarray:
        """Vectorized per-item deadlines for an array of class labels."""
        if not self.per_class:
            return np.full(len(classes), self.default_s)
        lut = {c: self.deadline_s(c) for c in np.unique(classes)}
        return np.array([lut[c] for c in np.asarray(classes)], dtype=np.float64)


class _PoolState:
    """Mutable runtime state of one pool inside a CapacityPlan."""

    __slots__ = ("pool", "live", "pending", "stuck", "slow", "unhealthy",
                 "unit_seconds", "meters", "rng", "delay_override")

    def __init__(self, pool: UnitPool, live: int):
        self.pool = pool
        self.live = int(live)
        self.pending: list[tuple[float, int]] = []   # (available_at, count)
        # builds that will never land (injected stuck_build faults); they
        # occupy pending capacity -- and ceiling headroom -- until cancelled
        self.stuck: list[tuple[float, int]] = []     # (expected_at, count)
        # builds landing later than promised (provisioning brownouts):
        # (expected_at, ready_at, count) -- overdue relative to expected_at,
        # so the converger can observe the brownout, but they DO land
        self.slow: list[tuple[float, float, int]] = []
        self.unhealthy = 0
        self.unit_seconds = 0.0
        self.meters = PoolMeters()
        self.rng = np.random.default_rng(pool.revoke_seed)
        # measured provisioning delay (engine-backed pools calibrate this
        # from real spawn wall time); None means the configured value rules
        self.delay_override: float | None = None

    @property
    def delay_s(self) -> float:
        """Effective provisioning delay: measured when calibrated, else the
        configured ``UnitPool.provision_delay_s``."""
        return (self.delay_override if self.delay_override is not None
                else self.pool.provision_delay_s)

    @property
    def n_pending(self) -> int:
        return (sum(c for _, c in self.pending)
                + sum(c for _, c in self.stuck)
                + sum(c for _, _, c in self.slow))

    @property
    def revoked(self) -> int:
        return self.meters.revoked

    def cancel(self, count: int) -> int:
        """Cancel up to ``count`` pending builds: stuck ones first (they are
        worthless, oldest first so the most-overdue go), then browned-out
        builds newest-first (they land latest), then healthy pending
        newest-first (same order release() always used)."""
        left = int(count)
        while left > 0 and self.stuck:
            at, c = self.stuck[0]
            take = min(c, left)
            left -= take
            if take == c:
                self.stuck.pop(0)
            else:
                self.stuck[0] = (at, c - take)
        while left > 0 and self.slow:
            exp, rdy, c = self.slow[-1]
            take = min(c, left)
            left -= take
            if take == c:
                self.slow.pop()
            else:
                self.slow[-1] = (exp, rdy, c - take)
        while left > 0 and self.pending:
            at, c = self.pending[-1]
            take = min(c, left)
            left -= take
            if take == c:
                self.pending.pop()
            else:
                self.pending[-1] = (at, c - take)
        done = int(count) - left
        self.meters.cancelled += done
        return done


class CapacityPlan:
    """Live capacity across an ordered sequence of typed unit pools.

    The first pool is the *default* pool: scalar policy decisions map onto it,
    and it receives the controller's ``starting_units`` unless its
    ``starting_units`` field says otherwise.
    """

    def __init__(self, pools: Sequence[UnitPool], *, starting_units: int = 0,
                 faults=None):
        pools = tuple(pools)
        if not pools:
            raise ValueError("CapacityPlan needs at least one UnitPool")
        names = [p.name for p in pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        self.pools = pools
        self.default_pool = pools[0].name
        # fault injector (see repro.core.convergence.faults) -- duck-typed so
        # this module stays import-cycle free: needs .reset(), .stuck_builds()
        # and .step_draws()
        self._faults = faults
        self._state: dict[str, _PoolState] = {}
        self.revocations: list[RevocationEvent] = []
        self.fault_events: list[FaultEvent] = []
        self.reset(starting_units)

    # -- lifecycle ------------------------------------------------------------------
    def reset(self, starting_units: int = 0) -> None:
        self._state = {}
        for i, p in enumerate(self.pools):
            live = p.starting_units if p.starting_units is not None else (
                starting_units if i == 0 else 0)
            self._state[p.name] = _PoolState(p, live)
        self.revocations = []
        self.fault_events = []
        if self._faults is not None:
            self._faults.reset()

    # -- totals ---------------------------------------------------------------------
    @property
    def total_live(self) -> int:
        return sum(s.live for s in self._state.values())

    @property
    def total_pending(self) -> int:
        return sum(s.n_pending for s in self._state.values())

    @property
    def n_revoked(self) -> int:
        return sum(s.revoked for s in self._state.values())

    def live_of(self, name: str) -> int:
        return self._state[name].live

    def pending_of(self, name: str) -> int:
        return self._state[name].n_pending

    def __iter__(self) -> Iterator[UnitPool]:
        return iter(self.pools)

    # -- per-step protocol ----------------------------------------------------------
    def land(self, now: float, step_s: float = 1.0) -> int:
        """Start one step: land provisioned units whose delay elapsed (clamped
        to the pool ceiling, excess counted in the ``overflow`` meter), apply
        revocations for preemptible pools and any injected faults, then meter
        this step's unit-seconds.  Returns total usable units."""
        for st in self._state.values():
            if st.pending:
                ready = sum(c for at, c in st.pending if at <= now)
                if ready:
                    admit = min(ready, max(st.pool.max_units - st.live, 0))
                    if admit < ready:
                        st.meters.overflow_landed += ready - admit
                    st.live += admit
                    st.meters.landed += admit
                    st.pending = [p for p in st.pending if p[0] > now]
            if st.slow:
                ready = sum(c for _, rdy, c in st.slow if rdy <= now)
                if ready:
                    admit = min(ready, max(st.pool.max_units - st.live, 0))
                    if admit < ready:
                        st.meters.overflow_landed += ready - admit
                    st.live += admit
                    st.meters.landed += admit
                    st.slow = [e for e in st.slow if e[1] > now]
            if st.pool.revoke_rate > 0.0 and st.live > 0:
                p_rev = -math.expm1(-st.pool.revoke_rate * step_s)
                k = int(st.rng.binomial(st.live, p_rev))
                if k:
                    st.live -= k
                    st.meters.revoked += k
                    st.unhealthy = min(st.unhealthy, st.live)
                    self.revocations.append(
                        RevocationEvent(time=now, pool=st.pool.name, count=k))
            if self._faults is not None:
                self._apply_faults(st, now, step_s)
            st.unit_seconds += st.live * step_s
        return self.total_live

    def _apply_faults(self, st: _PoolState, now: float, step_s: float) -> None:
        lost, flapped, healed = self._faults.step_draws(
            st.pool.name, st.live, st.unhealthy, now, step_s)
        if lost:
            st.live -= lost
            st.meters.lost += lost
            st.unhealthy = min(st.unhealthy, st.live)
            self.fault_events.append(
                FaultEvent(time=now, pool=st.pool.name, kind="unit_loss",
                           count=lost))
        if flapped:
            st.unhealthy = min(st.live, st.unhealthy + flapped)
            self.fault_events.append(
                FaultEvent(time=now, pool=st.pool.name, kind="flap",
                           count=flapped))
        if healed:
            healed = min(healed, st.unhealthy)
            if healed:
                st.unhealthy -= healed
                self.fault_events.append(
                    FaultEvent(time=now, pool=st.pool.name, kind="heal",
                               count=healed))
        # correlated multi-unit loss (AZ-scale event): drawn once per step
        # across pools, applied after the independent unit_loss draws so
        # their RNG streams stay aligned with corr-free runs
        corr_fn = getattr(self._faults, "corr_loss", None)
        if corr_fn is not None:
            corr = min(int(corr_fn(st.pool.name, st.live, now, step_s)),
                       st.live)
            if corr:
                st.live -= corr
                st.meters.lost += corr
                st.unhealthy = min(st.unhealthy, st.live)
                self.fault_events.append(
                    FaultEvent(time=now, pool=st.pool.name, kind="corr_loss",
                               count=corr))

    # -- actuation ------------------------------------------------------------------
    def request(self, name: str, count: int, now: float) -> int:
        """Queue units of ``name`` behind its provisioning delay, clamped to
        the pool's remaining ceiling headroom (``max_units - live - pending``);
        refused units are counted in the ``overflow`` meter.  Returns the
        count actually queued."""
        if count <= 0:
            return 0
        st = self._state.get(name)
        if st is None:
            raise ValueError(f"unknown pool {name!r}; plan pools: "
                             f"{[p.name for p in self.pools]}")
        count = int(count)
        queued = min(count, max(st.pool.max_units - st.live - st.n_pending, 0))
        if queued < count:
            st.meters.overflow_request += count - queued
        if queued <= 0:
            return 0
        at = now + st.delay_s
        stuck = (self._faults.stuck_builds(st.pool.name, queued, now)
                 if self._faults is not None else 0)
        if stuck:
            st.stuck.append((at, stuck))
            self.fault_events.append(
                FaultEvent(time=now, pool=st.pool.name, kind="stuck_build",
                           count=stuck))
        healthy = queued - stuck
        if healthy:
            factor_fn = getattr(self._faults, "delay_factor", None) \
                if self._faults is not None else None
            factor = float(factor_fn(st.pool.name, now)) if factor_fn else 1.0
            if factor > 1.0:
                # provisioning brownout: the build WILL land, but later than
                # promised; overdue detection keys off the expected time
                st.slow.append((at, now + st.delay_s * factor, healthy))
                self.fault_events.append(
                    FaultEvent(time=now, pool=st.pool.name, kind="brownout",
                               count=healthy))
            else:
                st.pending.append((at, healthy))
        st.meters.queued += queued
        return queued

    def releasable(self) -> int:
        """Units a voluntary release could currently reclaim: all pending plus
        live capacity above each pool's floor."""
        return sum(s.n_pending + max(s.live - s.pool.min_units, 0)
                   for s in self._state.values())

    def _release_order(self) -> list[_PoolState]:
        # most expensive first; among equal prices, later-declared pools go
        # first so the default pool is the last to shrink
        return sorted(self._state.values(),
                      key=lambda s: (s.pool.cost_rate,
                                     self.pools.index(s.pool)),
                      reverse=True)

    def release_plan(self, count: int) -> list[tuple[str, str, int]]:
        """Decompose a voluntary release of up to ``count`` units into ordered
        ``("cancel" | "drain", pool, n)`` operations WITHOUT mutating state.

        Executing the returned operations through :meth:`cancel_pending` /
        :meth:`drain` (in order) is mechanically identical to
        :meth:`release` -- same pool order, same queue semantics -- which is
        what lets the imperative controller actuate through a
        :class:`~repro.core.convergence.converger.StepExecutor` (and thus
        drive real replica fleets) without perturbing the golden behavior.
        """
        ops: list[tuple[str, str, int]] = []
        left = int(count)
        order = self._release_order()
        for st in order:                       # pass 1: cancel pending
            take = min(left, st.n_pending)
            if take > 0:
                ops.append(("cancel", st.pool.name, take))
                left -= take
        for st in order:                       # pass 2: release live
            take = min(left, max(st.live - st.pool.min_units, 0))
            if take > 0:
                ops.append(("drain", st.pool.name, take))
                left -= take
        return ops

    def release(self, count: int) -> dict[str, int]:
        """Voluntarily release up to ``count`` units, most expensive capacity
        first: pass 1 cancels pending allocations (newest-first within each
        pool), pass 2 releases live units above each pool's floor.  Returns
        the per-pool released counts (sum <= count)."""
        out: dict[str, int] = {}
        left = int(count)
        order = self._release_order()
        for st in order:                       # pass 1: cancel pending
            if left > 0 and (st.pending or st.stuck or st.slow):
                take = st.cancel(left)
                left -= take
                if take:
                    out[st.pool.name] = out.get(st.pool.name, 0) + take
        for st in order:                       # pass 2: release live
            take = min(left, max(st.live - st.pool.min_units, 0))
            if take > 0:
                st.live -= take
                st.unhealthy = max(st.unhealthy - take, 0)   # drain sick first
                st.meters.released += take
                left -= take
                out[st.pool.name] = out.get(st.pool.name, 0) + take
        return out

    # -- convergence primitives -----------------------------------------------------
    def cancel_pending(self, name: str, count: int) -> int:
        """Cancel up to ``count`` pending builds of ``name`` (stuck builds
        first, then healthy pending newest-first).  Returns the count
        actually cancelled."""
        if count <= 0:
            return 0
        return self._pool(name).cancel(count)

    def drain(self, name: str, count: int) -> int:
        """Voluntarily drain up to ``count`` live units of ``name``,
        respecting the pool floor; unhealthy units go first.  Returns the
        count actually drained."""
        if count <= 0:
            return 0
        st = self._pool(name)
        take = min(int(count), max(st.live - st.pool.min_units, 0))
        if take > 0:
            st.live -= take
            st.unhealthy = max(st.unhealthy - take, 0)
            st.meters.released += take
        return take

    def replace_unhealthy(self, name: str, count: int, now: float, *,
                          queue_replacements: bool = True) -> tuple[int, int]:
        """Tear down up to ``count`` unhealthy live units of ``name`` and
        queue replacements behind the provisioning delay (the fleet briefly
        dips, exactly as a real instance failure would).  Returns
        ``(drained, queued)``.

        ``queue_replacements=False`` tears down only: an engine-backed
        executor books each replacement itself (via :meth:`request` after a
        measured spawn, or :meth:`queue_stuck` after a failed one)."""
        st = self._pool(name)
        k = min(int(count), st.unhealthy)
        if k <= 0:
            return 0, 0
        st.live -= k
        st.unhealthy -= k
        st.meters.released += k
        queued = self.request(name, k, now) if queue_replacements else 0
        return k, queued

    # -- engine-measured actuation (used by fleet step executors) --------------------
    def calibrate_delay(self, name: str, seconds: float) -> None:
        """Record a *measured* provisioning delay for ``name`` (real spawn
        wall time: checkpoint load + remesh + compile + probe decode).
        Latest measurement wins -- the first spawn pays jit compilation,
        later ones reuse the cache, and the plan should price the current
        reality, not the configured guess."""
        if seconds < 0.0:
            raise ValueError(f"measured delay must be >= 0, got {seconds}")
        self._pool(name).delay_override = float(seconds)

    def queue_stuck(self, name: str, count: int, now: float) -> int:
        """Record ``count`` builds of ``name`` that started but will never
        land -- a real spawn failure observed by an executor, as opposed to
        an injected stuck_build fault.  The converger's overdue-timeout /
        cancel / retry machinery applies identically."""
        if count <= 0:
            return 0
        st = self._pool(name)
        count = int(count)
        st.stuck.append((now + st.delay_s, count))
        st.meters.queued += count
        self.fault_events.append(
            FaultEvent(time=now, pool=st.pool.name, kind="stuck_build",
                       count=count))
        return count

    def mark_lost(self, name: str, count: int, now: float) -> int:
        """Remove up to ``count`` live units of ``name`` that an executor
        observed dead (replica process killed out from under us) -- the
        measured counterpart of an injected unit_loss fault."""
        st = self._pool(name)
        k = min(int(count), st.live)
        if k <= 0:
            return 0
        st.live -= k
        st.meters.lost += k
        st.unhealthy = min(st.unhealthy, st.live)
        self.fault_events.append(
            FaultEvent(time=now, pool=st.pool.name, kind="unit_loss", count=k))
        return k

    def set_unhealthy(self, name: str, count: int) -> None:
        """Sync the unhealthy gauge of ``name`` from an executor's real
        health checks (clamped to the live count)."""
        st = self._pool(name)
        st.unhealthy = min(max(int(count), 0), st.live)

    def overdue_pending(self, name: str, now: float, timeout_s: float) -> int:
        """Builds of ``name`` whose expected landing is more than
        ``timeout_s`` overdue -- the observable symptom of a stuck build."""
        st = self._pool(name)
        return (sum(c for at, c in st.stuck if now >= at + timeout_s)
                + sum(c for at, c in st.pending if now >= at + timeout_s)
                + sum(c for exp, _, c in st.slow if now >= exp + timeout_s))

    def _pool(self, name: str) -> _PoolState:
        st = self._state.get(name)
        if st is None:
            raise ValueError(f"unknown pool {name!r}; plan pools: "
                             f"{[p.name for p in self.pools]}")
        return st

    # -- observation / accounting ---------------------------------------------------
    def stats(self) -> dict[str, PoolStats]:
        return {
            name: PoolStats(units=st.live, pending=st.n_pending,
                            cost_rate=st.pool.cost_rate,
                            min_units=st.pool.min_units,
                            max_units=st.pool.max_units,
                            preemptible=st.pool.preemptible,
                            revoked=st.revoked,
                            unhealthy=st.unhealthy,
                            lost=st.meters.lost,
                            overflow=st.meters.overflow)
            for name, st in self._state.items()
        }

    def meters(self) -> dict[str, PoolMeters]:
        """Copies of the per-pool conservation ledgers (see PoolMeters)."""
        return {name: replace(st.meters) for name, st in self._state.items()}

    def unit_seconds_by_pool(self) -> dict[str, float]:
        return {name: st.unit_seconds for name, st in self._state.items()}

    def cost(self) -> float:
        """Priced capacity consumed so far (sum of unit-hours x pool rate)."""
        return sum(st.unit_seconds / 3600.0 * st.pool.cost_rate
                   for st in self._state.values())

    def report_kwargs(self) -> dict:
        """RunReport constructor kwargs carrying the plan's priced accounting."""
        return {
            "pool_unit_seconds": self.unit_seconds_by_pool(),
            "pool_cost_rates": {p.name: p.cost_rate for p in self.pools},
            "n_revocations": self.n_revoked,
            # measured provisioning delays only -- a pool appears here iff an
            # executor calibrated it from a real spawn (configured guesses
            # stay out of the report)
            "pool_provision_delay_s": {
                name: st.delay_override
                for name, st in self._state.items()
                if st.delay_override is not None},
        }


__all__ = ["DEFAULT_POOL", "CapacityPlan", "FaultEvent", "PoolMeters",
           "PoolStats", "RevocationEvent", "Sla", "UnitPool"]
