"""ScalableBackend protocol + the shared RunReport result schema.

A backend is anything that serves work with a scalable pool of units and lets
a :class:`~repro.core.scaling.controller.ScalingController` drive the pool:
the tweet simulator (`repro.core.simulator.Engine`), the elastic replica
fleet (`repro.core.elastic.ElasticCluster`), and the live serving driver
(`repro.launch.serve.ServeBackend`).  They all return a RunReport, so
benchmarks and examples compare policies across backends with one code path.

RunReport also supports ``report["key"]`` lookups over its summary dict so
pre-redesign call sites that consumed the ElasticCluster result dict keep
working unchanged.

The capacity redesign adds the *priced* view: per-pool unit-seconds and cost
rates (filled from ``CapacityPlan.report_kwargs()``) roll up into ``cost``,
and an optional :class:`~repro.core.scaling.capacity.Sla` spec plus per-item
``classes`` labels yield per-request-class violation rates and the
worst-class breakdown -- the paper's economics (SLA violations vs money
spent) made first-class in every backend's report.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping, Protocol, runtime_checkable

import numpy as np

from repro.core.scaling.capacity import Sla
from repro.core.scaling.controller import DecisionRecord


@dataclass
class RunReport:
    """Per-run outputs every backend reports in the same shape."""

    backend: str                  # "simulator" | "elastic" | "serve" | ...
    workload: str                 # trace / stream identifier
    policy: str                   # policy.describe()
    sla_s: float
    latencies: np.ndarray         # per-item completion latency, seconds
    unit_seconds: float           # integral of usable units over time
    units_t: np.ndarray           # usable units per step
    n_decisions_up: int = 0
    n_decisions_down: int = 0
    unit_name: str = "unit"       # what one unit is (cpu / replica / slot)
    decisions: list[DecisionRecord] = field(default_factory=list)
    extra: dict[str, Any] = field(default_factory=dict)   # backend-specific rows
    sla: Sla | None = None        # per-class deadline spec (None: flat sla_s)
    classes: np.ndarray | None = None   # per-item request-class labels, aligned
                                        # with ``latencies``
    pool_unit_seconds: dict[str, float] = field(default_factory=dict)
    pool_cost_rates: dict[str, float] = field(default_factory=dict)
    n_revocations: int = 0
    # measured provisioning delay per pool (only pools an executor calibrated
    # from a real spawn appear; configured guesses never show up here)
    pool_provision_delay_s: dict[str, float] = field(default_factory=dict)
    _summary_cache: dict[str, Any] | None = field(
        default=None, init=False, repr=False, compare=False)

    # -- derived metrics -------------------------------------------------------------
    @property
    def n_done(self) -> int:
        return int(self.latencies.size)

    def _deadlines(self) -> np.ndarray | float:
        """Per-item deadline array (per-class Sla + labels) or the flat SLA."""
        if self.sla is None:
            return self.sla_s
        if self.classes is not None and self.sla.per_class:
            return self.sla.deadlines(self.classes)
        return self.sla.default_s

    @property
    def violation_rate(self) -> float:
        if self.latencies.size == 0:
            return 0.0
        return float(np.mean(self.latencies > self._deadlines()))

    def violation_rate_by_class(self) -> dict[str, float]:
        """Violation rate per request class (empty when classes are unknown)."""
        if self.classes is None or self.latencies.size == 0:
            return {}
        cls = np.asarray(self.classes)
        out = {}
        for c in np.unique(cls):
            m = cls == c
            thr = self.sla.deadline_s(str(c)) if self.sla is not None else self.sla_s
            out[str(c)] = float(np.mean(self.latencies[m] > thr))
        return out

    @property
    def worst_class(self) -> tuple[str, float] | None:
        """(request class, violation rate) of the worst-served class."""
        by_cls = self.violation_rate_by_class()
        if not by_cls:
            return None
        name = max(by_cls, key=by_cls.get)
        return name, by_cls[name]

    @property
    def cost(self) -> float:
        """Priced capacity: sum over pools of unit-hours x cost_rate.  Without
        pool accounting (a legacy single-pool backend), one unit-hour costs
        1.0 so ``cost == unit_hours``."""
        if self.pool_unit_seconds:
            return sum(us / 3600.0 * self.pool_cost_rates.get(name, 1.0)
                       for name, us in self.pool_unit_seconds.items())
        return self.unit_hours

    @property
    def mean_latency_s(self) -> float:
        return float(self.latencies.mean()) if self.latencies.size else 0.0

    @property
    def p99_latency_s(self) -> float:
        return float(np.quantile(self.latencies, 0.99)) if self.latencies.size else 0.0

    @property
    def unit_hours(self) -> float:
        return self.unit_seconds / 3600.0

    @property
    def max_units(self) -> int:
        return int(self.units_t.max()) if self.units_t.size else 0

    def summary(self) -> dict[str, Any]:
        # reports are effectively immutable after construction; cache so the
        # mapping shim doesn't recompute quantiles on every lookup
        if self._summary_cache is not None:
            return dict(self._summary_cache)
        out = {
            "backend": self.backend,
            "workload": self.workload,
            "policy": self.policy,
            "n_done": self.n_done,
            "violation_rate": self.violation_rate,
            "violation_pct": 100.0 * self.violation_rate,
            "mean_latency_s": self.mean_latency_s,
            "p99_latency_s": self.p99_latency_s,
            f"{self.unit_name}_hours": self.unit_hours,
            "max_units": self.max_units,
            f"max_{self.unit_name}s": self.max_units,
            "n_scale_ups": self.n_decisions_up,
            "n_scale_downs": self.n_decisions_down,
            "cost": self.cost,
        }
        by_cls = self.violation_rate_by_class()
        if by_cls:
            for cls, rate in sorted(by_cls.items()):
                out[f"viol_pct.{cls}"] = 100.0 * rate
            worst, worst_rate = self.worst_class
            out["worst_class"] = worst
            out["worst_class_viol_pct"] = 100.0 * worst_rate
        if len(self.pool_unit_seconds) > 1 or self.n_revocations:
            for name, us in sorted(self.pool_unit_seconds.items()):
                out[f"unit_hours.{name}"] = us / 3600.0
            out["n_revocations"] = self.n_revocations
        for name, d in sorted(self.pool_provision_delay_s.items()):
            out[f"measured_delay_s.{name}"] = d
        out.update(self.extra)
        self._summary_cache = out
        return dict(out)

    # -- mapping shim (legacy result-dict call sites) ---------------------------------
    def __getitem__(self, key: str) -> Any:
        return self.summary()[key]

    def __contains__(self, key: str) -> bool:
        return key in self.summary()

    def keys(self) -> Iterator[str]:
        return iter(self.summary().keys())


@runtime_checkable
class ScalableBackend(Protocol):
    """Anything a ScalingController can scale: run one workload, report one
    RunReport.  Backends construct their controller themselves (they know
    their unit semantics, step size, and signal channels)."""

    def run(self) -> RunReport: ...


def compare(reports: Mapping[str, RunReport]) -> list[dict[str, Any]]:
    """Flatten named reports into comparable summary rows (one code path for
    benchmarks/ and examples/ across backends)."""
    rows = []
    for name, rep in reports.items():
        row = {"name": name}
        row.update(rep.summary())
        rows.append(row)
    return rows


__all__ = ["RunReport", "ScalableBackend", "compare"]
