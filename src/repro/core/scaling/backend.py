"""ScalableBackend protocol + the shared RunReport result schema.

A backend is anything that serves work with a scalable pool of units and lets
a :class:`~repro.core.scaling.controller.ScalingController` drive the pool:
the tweet simulator (`repro.core.simulator.Engine`), the elastic replica
fleet (`repro.core.elastic.ElasticCluster`), and the live serving driver
(`repro.launch.serve.ServeBackend`).  They all return a RunReport, so
benchmarks and examples compare policies across backends with one code path.

RunReport also supports ``report["key"]`` lookups over its summary dict so
pre-redesign call sites that consumed the ElasticCluster result dict keep
working unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping, Protocol, runtime_checkable

import numpy as np

from repro.core.scaling.controller import DecisionRecord


@dataclass
class RunReport:
    """Per-run outputs every backend reports in the same shape."""

    backend: str                  # "simulator" | "elastic" | "serve" | ...
    workload: str                 # trace / stream identifier
    policy: str                   # policy.describe()
    sla_s: float
    latencies: np.ndarray         # per-item completion latency, seconds
    unit_seconds: float           # integral of usable units over time
    units_t: np.ndarray           # usable units per step
    n_decisions_up: int = 0
    n_decisions_down: int = 0
    unit_name: str = "unit"       # what one unit is (cpu / replica / slot)
    decisions: list[DecisionRecord] = field(default_factory=list)
    extra: dict[str, Any] = field(default_factory=dict)   # backend-specific rows
    _summary_cache: dict[str, Any] | None = field(
        default=None, init=False, repr=False, compare=False)

    # -- derived metrics -------------------------------------------------------------
    @property
    def n_done(self) -> int:
        return int(self.latencies.size)

    @property
    def violation_rate(self) -> float:
        if self.latencies.size == 0:
            return 0.0
        return float(np.mean(self.latencies > self.sla_s))

    @property
    def mean_latency_s(self) -> float:
        return float(self.latencies.mean()) if self.latencies.size else 0.0

    @property
    def p99_latency_s(self) -> float:
        return float(np.quantile(self.latencies, 0.99)) if self.latencies.size else 0.0

    @property
    def unit_hours(self) -> float:
        return self.unit_seconds / 3600.0

    @property
    def max_units(self) -> int:
        return int(self.units_t.max()) if self.units_t.size else 0

    def summary(self) -> dict[str, Any]:
        # reports are effectively immutable after construction; cache so the
        # mapping shim doesn't recompute quantiles on every lookup
        if self._summary_cache is not None:
            return dict(self._summary_cache)
        out = {
            "backend": self.backend,
            "workload": self.workload,
            "policy": self.policy,
            "n_done": self.n_done,
            "violation_rate": self.violation_rate,
            "violation_pct": 100.0 * self.violation_rate,
            "mean_latency_s": self.mean_latency_s,
            "p99_latency_s": self.p99_latency_s,
            f"{self.unit_name}_hours": self.unit_hours,
            "max_units": self.max_units,
            f"max_{self.unit_name}s": self.max_units,
            "n_scale_ups": self.n_decisions_up,
            "n_scale_downs": self.n_decisions_down,
        }
        out.update(self.extra)
        self._summary_cache = out
        return dict(out)

    # -- mapping shim (legacy result-dict call sites) ---------------------------------
    def __getitem__(self, key: str) -> Any:
        return self.summary()[key]

    def __contains__(self, key: str) -> bool:
        return key in self.summary()

    def keys(self) -> Iterator[str]:
        return iter(self.summary().keys())


@runtime_checkable
class ScalableBackend(Protocol):
    """Anything a ScalingController can scale: run one workload, report one
    RunReport.  Backends construct their controller themselves (they know
    their unit semantics, step size, and signal channels)."""

    def run(self) -> RunReport: ...


def compare(reports: Mapping[str, RunReport]) -> list[dict[str, Any]]:
    """Flatten named reports into comparable summary rows (one code path for
    benchmarks/ and examples/ across backends)."""
    rows = []
    for name, rep in reports.items():
        row = {"name": name}
        row.update(rep.summary())
        rows.append(row)
    return rows


__all__ = ["RunReport", "ScalableBackend", "compare"]
