"""The shared processor-sharing service core: exact water-filling, vectorized.

The paper's Algorithm 1 distributes the capacity of one simulation step
egalitarianly among all in-flight items, redistributing each finished item's
excess to the still-hungry ones.  That per-item loop is mathematically exact
*water-filling*: find the level ``tau`` such that
``sum(min(rem_i, tau)) == capacity``; every item then consumes
``min(rem_i, tau)`` units of work.  This module implements the water-filling
directly, vectorized, and is the ONE service model every scaled backend runs
on (the tweet simulator `Engine`, the elastic replica fleet `ElasticCluster`)
-- policy/backend comparisons are only meaningful when the service process
underneath them is identical (cf. the auto-scaling taxonomies,
arXiv:1609.09224 and arXiv:1808.02254).

Mechanics (all O(L + k) per step, no Python loops over in-flight items):

* the in-flight set is a struct-of-arrays sorted by remaining work
  (ascending), with arbitrary *payload columns* (post time, score, request
  index, any signal channel) carried through the same permutation;
* after a step every survivor has ``rem_i - tau`` left, which *preserves the
  order*, so only new arrivals need merging in (``searchsorted`` + insert);
* the finished items are exactly a *prefix* of the sorted array
  (``rem_i <= tau``), so completion handling is a slice;
* consumed work is exactly ``min(demand, capacity)`` -- water-filling wastes
  nothing -- and the busy fraction is defined from work actually consumed,
  not from pre-step demand.

Bit-identical outcome to the paper's loop (property-tested against the
literal Algorithm 1 in tests/test_simulator.py), ~1000x faster -- this is
what makes 100k+-request streams and the 4.3M-tweet Spain trace cheap.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

import numpy as np


def water_level(rem_sorted: np.ndarray, capacity: float) -> tuple[float, int]:
    """Find (tau, n_finished) s.t. sum(min(rem_i, tau)) == capacity.

    ``rem_sorted`` ascending.  Returns n_finished = number of prefix elements
    with rem_i <= tau (they complete this step).  If total demand <= capacity,
    everything finishes (tau = inf).
    """
    L = rem_sorted.shape[0]
    if L == 0:
        return np.inf, 0
    csum = np.cumsum(rem_sorted)
    if csum[-1] <= capacity:
        return np.inf, L
    # With k items finished (the k smallest), the rest each get
    #   tau_k = (capacity - csum[k-1]) / (L - k),   feasible iff rem[k] > tau_k >= rem[k-1]
    # Find smallest k where rem_sorted[k] * (L - k) + csum[k-1] > capacity.
    lhs = rem_sorted * (L - np.arange(L)) + np.concatenate(([0.0], csum[:-1]))
    k = int(np.searchsorted(lhs > capacity, True))
    prev = csum[k - 1] if k > 0 else 0.0
    tau = (capacity - prev) / (L - k)
    return float(tau), k


@dataclass(frozen=True)
class StepResult:
    """Outcome of one service step."""

    tau: float                         # water level (inf when everything drained)
    demand: float                      # total remaining work before the step
    consumed: float                    # work served, measured (== min(demand,
                                       # capacity) by the conservation invariant)
    busy: float                        # min(demand, capacity) / capacity -- equals
                                       # consumed/capacity up to float rounding; kept
                                       # in this exact form for bit-parity with the
                                       # seed simulator (0 when capacity == 0)
    finished: dict[str, np.ndarray]    # payload columns of the finished prefix
    n_finished: int


class ServiceProcess:
    """Sorted struct-of-arrays in-flight set under exact processor sharing.

    ``columns`` declares the per-item payload carried alongside the remaining
    work: either a name -> dtype mapping or a plain sequence of names
    (float64).  ``admit`` merges arrivals in; ``step`` water-fills one step of
    capacity and returns the finished items' payload columns.
    """

    def __init__(self, columns: Mapping[str, np.dtype] | tuple = ()):
        if not isinstance(columns, Mapping):
            columns = {name: np.float64 for name in columns}
        self.rem = np.empty(0, dtype=np.float64)
        self.cols: dict[str, np.ndarray] = {
            name: np.empty(0, dtype=dt) for name, dt in columns.items()}

    def __len__(self) -> int:
        return int(self.rem.shape[0])

    @property
    def demand(self) -> float:
        """Total remaining work of the in-flight set."""
        return float(self.rem.sum())

    def admit(self, rem, **cols) -> dict[str, np.ndarray] | None:
        """Merge arrivals into the sorted set (stable in arrival order).

        Zero-demand items never enter the set: they complete instantly and
        their payload columns are returned (None when there are none), in
        arrival order -- the caller records them as finished this step.
        """
        rem = np.asarray(rem, dtype=np.float64)
        if set(cols) != set(self.cols):
            raise ValueError(
                f"payload columns {sorted(cols)} do not match the declared "
                f"columns {sorted(self.cols)}")
        cols = {name: np.asarray(cols[name]) for name in self.cols}
        instant = None
        zero = rem <= 0.0
        if zero.any():
            idx = np.nonzero(zero)[0]
            instant = {name: c[idx] for name, c in cols.items()}
            keep = ~zero
            rem = rem[keep]
            cols = {name: c[keep] for name, c in cols.items()}
        if rem.size:
            order = np.argsort(rem, kind="stable")
            rem = rem[order]
            pos = np.searchsorted(self.rem, rem)
            self.rem = np.insert(self.rem, pos, rem)
            for name, c in cols.items():
                self.cols[name] = np.insert(self.cols[name], pos, c[order])
        return instant

    def step(self, capacity: float) -> StepResult:
        """Serve one step: distribute ``capacity`` by exact water-filling.

        ``consumed`` is *measured* from the work actually served (each
        finished item drank its whole remainder, each survivor drank exactly
        ``tau``), not defined as ``min(demand, capacity)`` -- so the
        conservation invariant ``consumed == min(demand, capacity)`` asserted
        by the tests has teeth against regressions in the water-level math.
        ``busy`` is demand clipped at capacity, over capacity: equal to the
        consumed fraction by the same invariant (so an idle tail of the step
        never reads as busy), but computed in exactly the seed simulator's
        float form so the golden parity tests stay bit-for-bit.
        """
        if self.rem.shape[0] == 0:
            return StepResult(tau=np.inf, demand=0.0, consumed=0.0, busy=0.0,
                              finished={n: c[:0] for n, c in self.cols.items()},
                              n_finished=0)
        demand = float(self.rem.sum())
        tau, k = water_level(self.rem, capacity)
        fin_work = float(self.rem[:k].sum())
        finished = {name: c[:k] for name, c in self.cols.items()}
        if k:
            self.rem = self.rem[k:]
            for name in self.cols:
                self.cols[name] = self.cols[name][k:]
        if np.isfinite(tau):
            if self.rem.shape[0] > 0:
                self.rem = self.rem - tau
            consumed = fin_work + tau * self.rem.shape[0]
        else:
            consumed = demand
        busy = min(demand, capacity) / capacity if capacity > 0 else 0.0
        return StepResult(tau=float(tau), demand=demand, consumed=consumed,
                          busy=busy, finished=finished, n_finished=k)


__all__ = ["ServiceProcess", "StepResult", "water_level"]
