"""SignalBus: the monitoring tier of the scaling control plane.

A vectorized windowed aggregator over *named signal channels*.  Each channel
is a pair of per-bin arrays (value sum, sample count) binned at ``bin_s``
resolution.  Samples are indexed by the time the *item was posted*, not the
time its processing finished (§V-B: "it is not the time the tweet is done
being processed that is used ... but the tweets post time"), so a burst of
old items completing late cannot masquerade as a fresh signal rise.

Window means are computed over half-open bin ranges ``[hi - w, hi)`` with the
previous window ``[hi - 2w, hi - w)`` alongside, which is exactly the pair the
paper's appdata detector compares.  The bin arrays grow on demand (unknown
horizons, e.g. a live serving fleet) or can be capped with ``horizon_bins``
(the simulator's fixed-duration traces, where the seed engine clamped both
recording and querying at the trace end).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping

import numpy as np

#: channel name used when a backend does not say otherwise
DEFAULT_CHANNEL = "sentiment"


@dataclass(frozen=True)
class WindowStats:
    """Mean/count of one signal channel over the current and previous window."""

    mean: float = 0.0
    count: int = 0
    prev_mean: float = 0.0
    prev_count: int = 0

    @property
    def rise(self) -> float:
        """Absolute window-over-window rise of the mean."""
        return self.mean - self.prev_mean

    @property
    def relative_rise(self) -> float:
        """Rise relative to the previous window's *level* (0 if no baseline).

        The baseline magnitude is ``abs(prev_mean)`` so a negative baseline
        (paper polarity lives in [-1, 1]) still yields a positive relative
        rise when the mean moves up -- a ``prev_mean > 0`` guard would
        silently report 0 and the appdata trigger could never fire.
        """
        if abs(self.prev_mean) > 1e-6:
            return (self.mean - self.prev_mean) / abs(self.prev_mean)
        return 0.0


class SignalBus:
    """Per-second-binned accumulator for named application-signal channels."""

    def __init__(
        self,
        channels: Iterable[str] = (DEFAULT_CHANNEL,),
        *,
        bin_s: float = 1.0,
        horizon_bins: int | None = None,
    ):
        self.bin_s = float(bin_s)
        self.horizon_bins = horizon_bins
        self._sum: dict[str, np.ndarray] = {}
        self._cnt: dict[str, np.ndarray] = {}
        for name in channels:
            self.add_channel(name)

    # -- channel management ---------------------------------------------------------
    @property
    def channels(self) -> tuple[str, ...]:
        return tuple(self._sum)

    def add_channel(self, name: str) -> None:
        if name not in self._sum:
            n = self.horizon_bins if self.horizon_bins is not None else 256
            self._sum[name] = np.zeros(n, dtype=np.float64)
            self._cnt[name] = np.zeros(n, dtype=np.int64)

    def reset(self) -> None:
        for name in self._sum:
            self._sum[name][:] = 0.0
            self._cnt[name][:] = 0

    # -- recording ------------------------------------------------------------------
    def _bins_of(self, times: np.ndarray) -> np.ndarray:
        b = (np.asarray(times, dtype=np.float64) / self.bin_s).astype(np.int64)
        if self.horizon_bins is not None:
            b = np.minimum(b, self.horizon_bins - 1)
        return np.maximum(b, 0)

    def _ensure(self, name: str, hi_bin: int) -> None:
        cur = self._sum[name].shape[0]
        if hi_bin < cur:
            return
        new = max(hi_bin + 1, 2 * cur)
        if self.horizon_bins is not None:
            new = min(new, self.horizon_bins)
        self._sum[name] = np.concatenate(
            [self._sum[name], np.zeros(new - cur, dtype=np.float64)])
        self._cnt[name] = np.concatenate(
            [self._cnt[name], np.zeros(new - cur, dtype=np.int64)])

    def record(self, channel: str, times, values) -> None:
        """Vectorized: add ``values[i]`` at post time ``times[i]``."""
        times = np.asarray(times, dtype=np.float64)
        if times.size == 0:
            return
        if channel not in self._sum:
            self.add_channel(channel)
        b = self._bins_of(times)
        self._ensure(channel, int(b.max()))
        np.add.at(self._sum[channel], b, np.asarray(values, dtype=np.float64))
        np.add.at(self._cnt[channel], b, 1)

    def record_one(self, channel: str, time: float, value: float) -> None:
        self.record(channel, np.array([time]), np.array([value]))

    # -- window queries --------------------------------------------------------------
    def _clamp_hi(self, hi_bin: int) -> int:
        if self.horizon_bins is not None:
            hi_bin = min(hi_bin, self.horizon_bins)
        return max(hi_bin, 0)

    def window_stats(self, channel: str, hi_bin: int, window_bins: int) -> WindowStats:
        """Stats over ``[hi - w, hi)`` and ``[hi - 2w, hi - w)`` (bins clamped >= 0).

        Uses direct slice sums (numpy pairwise reduction), bit-identical to the
        window means the seed simulator computed inline.
        """
        s, c = self._sum[channel], self._cnt[channel]
        # clamp only by the declared horizon, NOT the allocated length: bins the
        # arrays never grew to are implicitly zero, and clamping to the array
        # length would silently slide the window back onto stale data
        hi = self._clamp_hi(hi_bin)
        w = int(window_bins)
        lo1, hi1 = max(hi - w, 0), hi
        lo0, hi0 = max(hi - 2 * w, 0), max(hi - w, 0)
        c1 = int(c[lo1:hi1].sum())
        c0 = int(c[lo0:hi0].sum())
        m1 = float(s[lo1:hi1].sum() / c1) if c1 else 0.0
        m0 = float(s[lo0:hi0].sum() / c0) if c0 else 0.0
        return WindowStats(mean=m1, count=c1, prev_mean=m0, prev_count=c0)

    def snapshot(self, hi_bin: int, window_bins: int) -> Mapping[str, WindowStats]:
        """WindowStats for every channel at the same window edge."""
        return {name: self.window_stats(name, hi_bin, window_bins)
                for name in self._sum}

    def cumulative(self, channel: str) -> tuple[np.ndarray, np.ndarray]:
        """(cumsum of value sums, cumsum of counts) with a leading 0 -- O(1)
        window sums for offline analysis over many window sizes."""
        s = np.concatenate(([0.0], np.cumsum(self._sum[channel])))
        c = np.concatenate(([0], np.cumsum(self._cnt[channel])))
        return s, c


__all__ = ["DEFAULT_CHANNEL", "SignalBus", "WindowStats"]
