"""ScalingController: the decision/actuation tier of the scaling control plane.

Owns, exactly once, the controller mechanics the paper fixes in Table III --
the adaptation cadence, the resource-provisioning delay queue, the
1-unit-at-a-time downscale cap, and the unit floor/ceiling -- plus the window
accounting (busy fraction, arrival rate) that backs each Observation.  Both
simulation backends (`repro.core.simulator.Engine`,
`repro.core.elastic.ElasticCluster`) and the live serving driver
(`repro.launch.serve`) drive their step loop through this object; policies
never see anything but an :class:`Observation`.

Capacity is a typed :class:`~repro.core.scaling.capacity.CapacityPlan`: an
ordered set of :class:`UnitPool`\\ s, each with its own provisioning delay,
price, floor/ceiling, and (for preemptible pools) a seeded revocation
process.  A config without explicit ``pools`` gets a single on-demand pool
synthesized from the legacy scalar knobs -- mechanically identical to the
pre-redesign controller, which the golden parity tests pin bit-for-bit.
Table III mechanics apply per pool; voluntary downscale releases the most
expensive capacity first and cancels still-pending allocations (newest-first)
before touching live units.

Per-step protocol (one call each, in order):

    units = ctrl.on_step_start(now)        # provisioned units arriving <= now
    ... backend serves one step with `units` ...
    ctrl.note_step(busy_fraction, new_arrivals)
    rec = ctrl.maybe_adapt(time=.., n_in_system=..)   # None off-cadence
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping

import numpy as np

from repro.core.scaling.capacity import DEFAULT_POOL, CapacityPlan, UnitPool
from repro.core.scaling.signals import DEFAULT_CHANNEL, SignalBus

if TYPE_CHECKING:  # runtime import is deferred: autoscaler imports this package
    from repro.core.autoscaler.base import Decision, Observation, Policy
    from repro.core.convergence.audit import AuditLog
    from repro.core.convergence.converger import Converger, ConvergerConfig
    from repro.core.convergence.faults import FaultSpec
    from repro.core.convergence.groups import ScalingGroup


@dataclass(frozen=True)
class ControllerConfig:
    """Table III knobs, backend-agnostic (a 'unit' is a CPU, a replica, or a
    decode slot -- whatever the backend scales).

    ``pools`` types out the capacity: an ordered tuple of :class:`UnitPool`.
    When None, a single on-demand pool is synthesized from the scalar
    ``provision_delay_s`` / ``min_units`` / ``max_units`` knobs (the legacy
    configuration every existing backend uses).
    """

    adapt_period_s: float = 60.0
    provision_delay_s: float = 60.0
    min_units: int = 1
    max_units: int = 4096
    downscale_cap: int = 1           # "Downscaling is limited to a single CPU"
    step_s: float = 1.0
    app_window_s: float = 120.0      # window for the application-signal tier
    signal_channel: str = DEFAULT_CHANNEL   # channel mirrored into the legacy
                                            # Observation.app_* fields
    pools: tuple[UnitPool, ...] | None = None
    # -- convergence plane (see repro.core.convergence) -------------------------
    convergence: bool = False        # reconcile toward desired state instead of
                                     # actuating imperative deltas directly
    converge: "ConvergerConfig | None" = None   # timeouts/retries/backoff knobs
    faults: "tuple[FaultSpec, ...] | None" = None   # seeded fault injection
                                                    # threaded through the plan,
                                                    # or a pre-built duck-typed
                                                    # injector (ScriptedFaults)
    group: "ScalingGroup | None" = None   # scaling-group pools + scheduled and
                                          # webhook desired-state floors
    audit_path: str | None = None    # mirror the audit log to a JSONL file

    def __post_init__(self):
        if self.step_s <= 0.0:
            raise ValueError(f"step_s must be positive, got {self.step_s}")
        for name in ("adapt_period_s", "app_window_s"):
            value = getattr(self, name)
            n = value / self.step_s
            if n < 1.0 or abs(n - round(n)) > 1e-9:
                raise ValueError(
                    f"{name}={value} must be a positive integer multiple of "
                    f"step_s={self.step_s} (got {n} steps); fractional periods "
                    f"would silently truncate the adaptation cadence")

    @property
    def period_steps(self) -> int:
        return int(round(self.adapt_period_s / self.step_s))

    @property
    def window_bins(self) -> int:
        return int(round(self.app_window_s / self.step_s))

    def make_plan(self, starting_units: int) -> CapacityPlan:
        pools = self.pools
        if pools is None and self.group is not None:
            pools = self.group.pools
        if pools is None:
            pools = (UnitPool(DEFAULT_POOL,
                              provision_delay_s=self.provision_delay_s,
                              min_units=self.min_units,
                              max_units=self.max_units),)
        injector = None
        if self.faults:
            if hasattr(self.faults, "step_draws"):
                # a pre-built duck-typed injector (e.g. ScriptedFaults for
                # deterministic chaos drills) passes through as-is
                injector = self.faults
            else:
                from repro.core.convergence.faults import FaultInjector
                injector = FaultInjector(self.faults)
        return CapacityPlan(pools, starting_units=starting_units,
                            faults=injector)


@dataclass(frozen=True)
class DecisionRecord:
    """One adaptation tick: what the policy asked for and what was actuated."""

    time: float
    requested: int        # raw policy delta (net, over all pools)
    applied: int          # queued (if > 0) or released/cancelled now (if < 0)
    reason: str
    units: int            # usable units right after the tick
    pending: int          # units still inside the provisioning delay
    pool_deltas: Mapping[str, int] = field(default_factory=dict)
    # per-pool applied breakdown (queued > 0, released/cancelled < 0)


class ScalingController:
    """Single control plane shared by every ScalableBackend."""

    def __init__(
        self,
        policy: Policy,
        cfg: ControllerConfig,
        bus: SignalBus | None = None,
        *,
        starting_units: int = 1,
        executor_factory=None,
    ):
        self.policy = policy
        self.cfg = cfg
        self.bus = bus if bus is not None else SignalBus((cfg.signal_channel,),
                                                         bin_s=cfg.step_s)
        # convergence-mode step executor: called as executor_factory(plan)
        # on every reset (reset() rebuilds the plan, so the executor must be
        # rebound to the new one).  None = the converger's default
        # PlanExecutor, i.e. steps mutate plan counters (pre-fleet behavior).
        self._executor_factory = executor_factory
        self.reset(starting_units)

    # -- lifecycle ------------------------------------------------------------------
    def reset(self, starting_units: int | None = None) -> None:
        if starting_units is not None:
            self._start_units = starting_units
        self.plan: CapacityPlan = self.cfg.make_plan(self._start_units)
        self.decision_log: list[DecisionRecord] = []
        self.n_up = 0
        self.n_down = 0
        self._steps = 0
        self._win_busy: list[float] = []
        self._win_arrivals = 0
        self.audit: AuditLog | None = None
        self._converger: Converger | None = None
        # the actuation seam: BOTH modes actuate through a StepExecutor, so
        # an engine-backed executor (real replica spawns/drains) serves as
        # the imperative baseline too.  The default PlanExecutor mutates plan
        # counters exactly as the pre-seam controller did (golden-pinned).
        from repro.core.convergence.converger import PlanExecutor
        self._executor = (self._executor_factory(self.plan)
                          if self._executor_factory is not None
                          else PlanExecutor(self.plan))
        if self.cfg.convergence:
            # deferred: repro.core.convergence imports this package
            from repro.core.convergence.audit import AuditLog
            from repro.core.convergence.converger import Converger
            self.audit = AuditLog(self.cfg.audit_path)
            self.audit.append(0.0, "init",
                              pools={p.name: self.plan.live_of(p.name)
                                     for p in self.plan.pools})
            self._converger = Converger(self.plan, self.cfg.converge,
                                        audit=self.audit,
                                        executor=self._executor)
        if self.cfg.group is not None:
            self.cfg.group.reset()
        self.policy.reset()

    @property
    def units(self) -> int:
        return self.plan.total_live

    @property
    def n_pending(self) -> int:
        return self.plan.total_pending

    # -- per-step protocol ----------------------------------------------------------
    def on_step_start(self, now: float) -> int:
        """Land provisioned units whose delay has elapsed, apply revocations
        for preemptible pools, meter per-pool unit-seconds; return usable
        units.  In convergence mode the converger then reconciles toward the
        desired state, so healing (relaunching lost units, cancelling stuck
        builds) starts the step a fault becomes observable -- on a converged
        fleet it plans zero steps and this is the imperative path exactly."""
        units = self.plan.land(now, self.cfg.step_s)
        if self._converger is not None and self._converger.desired is not None:
            outcomes = self._converger.converge(now)
            if outcomes:
                self._absorb(outcomes)
                units = self.plan.total_live
        return units

    def note_step(self, busy_fraction: float, new_arrivals: int) -> None:
        """Accumulate the infrastructure/system window for the next Observation."""
        self._win_busy.append(float(busy_fraction))
        self._win_arrivals += int(new_arrivals)
        self._steps += 1

    def should_adapt(self) -> bool:
        return self._steps % self.cfg.period_steps == 0

    def observe(self, *, time: float, n_in_system: int) -> Observation:
        """Build the three-tier Observation at the current window edge."""
        from repro.core.autoscaler.base import Observation
        signals = self.bus.snapshot(self._steps, self.cfg.window_bins)
        primary = signals.get(self.cfg.signal_channel)
        return Observation(
            time=time,
            n_units=self.units,
            n_pending=self.n_pending,
            utilization=float(np.mean(self._win_busy)) if self._win_busy else 0.0,
            n_in_system=int(n_in_system),
            input_rate=self._win_arrivals / self.cfg.adapt_period_s,
            app_window_mean=primary.mean if primary else 0.0,
            app_prev_window_mean=primary.prev_mean if primary else 0.0,
            app_window_count=primary.count if primary else 0,
            signals=signals,
            pools=self.plan.stats(),
        )

    def maybe_adapt(self, *, time: float, n_in_system: int) -> DecisionRecord | None:
        """On-cadence: observe -> decide -> actuate under Table III mechanics.

        Upscale queues into each targeted pool behind its provisioning delay.
        Downscale is capped at ``downscale_cap`` units per tick (net, over all
        pools) and released by the plan: most expensive capacity first,
        cancelling still-pending allocations before live units -- releasing a
        live unit while a pending one lands a step later would actuate the
        opposite of what the policy asked for.
        """
        if not self.should_adapt():
            return None
        obs = self.observe(time=time, n_in_system=n_in_system)
        d: Decision = self.policy.decide(obs)
        deltas = d.pool_deltas(self.plan.default_pool)
        if self._converger is not None:
            return self._adapt_convergence(d, deltas, time)
        applied_pools: dict[str, int] = {}
        # release BEFORE queueing this tick's upscales: a mixed per-pool
        # decision (e.g. {"spot": +3, "od": -1}) must never have its release
        # pass cancel the allocation it queued a moment earlier (a scalar
        # decision is never both signs, so ordering cannot affect the legacy
        # single-pool behavior)
        down_req = -sum(dd for dd in deltas.values() if dd < 0)
        if down_req > 0 and self.plan.releasable() > 0:
            self.n_down += 1
            want = min(self.cfg.downscale_cap, down_req)
            # the plan decomposes the release (expensive-first, cancel before
            # drain); the executor actuates each op -- identical to the old
            # plan.release() with the default executor, real teardowns with
            # an engine-backed one
            for op, name, cnt in self.plan.release_plan(want):
                c = (self._executor.cancel_pending(name, cnt, time)
                     if op == "cancel" else
                     self._executor.drain(name, cnt, time))
                if c:
                    applied_pools[name] = applied_pools.get(name, 0) - c
        for name, dd in deltas.items():
            if dd > 0:
                queued = self._executor.launch(name, dd, time)
                if queued:
                    applied_pools[name] = applied_pools.get(name, 0) + queued
        if any(dd > 0 for dd in applied_pools.values()):
            self.n_up += 1
        rec = DecisionRecord(time=time, requested=int(d.total),
                             applied=sum(applied_pools.values()),
                             reason=d.reason, units=self.units,
                             pending=self.n_pending,
                             pool_deltas=applied_pools)
        self.decision_log.append(rec)
        self._win_busy = []
        self._win_arrivals = 0
        return rec

    # -- convergence mode -----------------------------------------------------------
    def _adapt_convergence(self, d: Decision, deltas: Mapping[str, int],
                           time: float) -> DecisionRecord:
        """Fold the policy decision into the desired state and converge.

        `derive_desired` applies the imperative actuation semantics (ceiling
        clamp, per-tick downscale cap, expensive-first distribution) to the
        *targets*, so with no faults the emitted steps are exactly what the
        imperative path would have done -- the golden parity tests pin this.
        """
        from repro.core.convergence.desired import derive_desired
        desired = derive_desired(self._converger.desired, self.plan.stats(),
                                 deltas, downscale_cap=self.cfg.downscale_cap)
        if self.cfg.group is not None:
            desired = self.cfg.group.overlay(desired, time)
        self._converger.set_desired(desired, time, reason=d.reason)
        applied_pools = self._absorb(self._converger.converge(time))
        rec = DecisionRecord(time=time, requested=int(d.total),
                             applied=sum(applied_pools.values()),
                             reason=d.reason, units=self.units,
                             pending=self.n_pending,
                             pool_deltas=applied_pools)
        self.decision_log.append(rec)
        self._win_busy = []
        self._win_arrivals = 0
        return rec

    def _absorb(self, outcomes) -> dict[str, int]:
        """Fold converger step outcomes into the up/down counters and a
        per-pool applied breakdown (launches positive, cancels and drains
        negative; replacements are capacity-neutral).  Cancellations of
        *stuck* builds are fault cleanup, not policy downscale, so they do
        not count as a down decision."""
        applied_pools: dict[str, int] = {}
        queued_any = released_any = False
        for o in outcomes:
            kind = type(o.step).__name__
            pool = o.step.pool
            if kind == "LaunchUnit":
                applied_pools[pool] = applied_pools.get(pool, 0) + o.applied
                queued_any |= o.applied > 0
            elif kind == "CancelPending":
                applied_pools[pool] = applied_pools.get(pool, 0) - o.applied
                if o.step.reason != "stuck":
                    released_any |= o.applied > 0
            elif kind == "DrainUnit":
                applied_pools[pool] = applied_pools.get(pool, 0) - o.applied
                released_any |= o.applied > 0
            elif kind == "ReplaceUnhealthy":
                applied_pools[pool] = (applied_pools.get(pool, 0)
                                       - o.applied + o.queued)
        if queued_any:
            self.n_up += 1
        if released_any:
            self.n_down += 1
        return applied_pools

    def fire_webhook(self, name: str, now: float):
        """Arm a scaling-group webhook.  Its floors hold for the trigger's
        window; in convergence mode they land on the desired state NOW --
        bumping the generation and superseding any in-flight retry backoff
        on the targeted pools -- so an operator floor raised mid-incident is
        honored at the next converge pass, not the next adaptation tick.
        (Imperative mode keeps the legacy semantics: floors apply from the
        next tick via the group overlay / webhook policy.)"""
        if self.cfg.group is None:
            raise ValueError("no scaling group configured on this controller")
        trig = self.cfg.group.fire(name, now)
        if self.audit is not None:
            self.audit.append(now, "webhook", name=name,
                              targets=dict(trig.targets), hold_s=trig.hold_s)
        if self._converger is not None and self._converger.desired is not None:
            desired = self.cfg.group.overlay(self._converger.desired, now)
            self._converger.set_desired(desired, now,
                                        reason=f"webhook:{name}",
                                        refresh=trig.targets.keys())
        return trig


__all__ = ["ControllerConfig", "DecisionRecord", "ScalingController"]
