"""ScalingController: the decision/actuation tier of the scaling control plane.

Owns, exactly once, the controller mechanics the paper fixes in Table III --
the adaptation cadence, the resource-provisioning delay queue, the
1-unit-at-a-time downscale cap, and the unit floor/ceiling -- plus the window
accounting (busy fraction, arrival rate) that backs each Observation.  Both
simulation backends (`repro.core.simulator.Engine`,
`repro.core.elastic.ElasticCluster`) and the live serving driver
(`repro.launch.serve`) drive their step loop through this object; policies
never see anything but an :class:`Observation`.

Per-step protocol (one call each, in order):

    units = ctrl.on_step_start(now)        # provisioned units arriving <= now
    ... backend serves one step with `units` ...
    ctrl.note_step(busy_fraction, new_arrivals)
    rec = ctrl.maybe_adapt(time=.., n_in_system=..)   # None off-cadence
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

from repro.core.scaling.signals import DEFAULT_CHANNEL, SignalBus

if TYPE_CHECKING:  # runtime import is deferred: autoscaler imports this package
    from repro.core.autoscaler.base import Decision, Observation, Policy


@dataclass(frozen=True)
class ControllerConfig:
    """Table III knobs, backend-agnostic (a 'unit' is a CPU, a replica, or a
    decode slot -- whatever the backend scales)."""

    adapt_period_s: float = 60.0
    provision_delay_s: float = 60.0
    min_units: int = 1
    max_units: int = 4096
    downscale_cap: int = 1           # "Downscaling is limited to a single CPU"
    step_s: float = 1.0
    app_window_s: float = 120.0      # window for the application-signal tier
    signal_channel: str = DEFAULT_CHANNEL   # channel mirrored into the legacy
                                            # Observation.app_* fields

    @property
    def period_steps(self) -> int:
        return int(self.adapt_period_s / self.step_s)

    @property
    def window_bins(self) -> int:
        return int(self.app_window_s / self.step_s)


@dataclass(frozen=True)
class DecisionRecord:
    """One adaptation tick: what the policy asked for and what was actuated."""

    time: float
    requested: int        # raw policy delta
    applied: int          # queued (if > 0) or released now (if < 0)
    reason: str
    units: int            # usable units right after the tick
    pending: int          # units still inside the provisioning delay


class ScalingController:
    """Single control plane shared by every ScalableBackend."""

    def __init__(
        self,
        policy: Policy,
        cfg: ControllerConfig,
        bus: SignalBus | None = None,
        *,
        starting_units: int = 1,
    ):
        self.policy = policy
        self.cfg = cfg
        self.bus = bus if bus is not None else SignalBus((cfg.signal_channel,),
                                                         bin_s=cfg.step_s)
        self.reset(starting_units)

    # -- lifecycle ------------------------------------------------------------------
    def reset(self, starting_units: int | None = None) -> None:
        if starting_units is not None:
            self._start_units = starting_units
        self.units: int = self._start_units
        self.pending: list[tuple[float, int]] = []   # (available_at, count)
        self.decision_log: list[DecisionRecord] = []
        self.n_up = 0
        self.n_down = 0
        self._steps = 0
        self._win_busy: list[float] = []
        self._win_arrivals = 0
        self.policy.reset()

    @property
    def n_pending(self) -> int:
        return sum(c for _, c in self.pending)

    # -- per-step protocol ----------------------------------------------------------
    def on_step_start(self, now: float) -> int:
        """Land provisioned units whose delay has elapsed; return usable units."""
        if self.pending:
            ready = sum(c for at, c in self.pending if at <= now)
            if ready:
                self.units = min(self.units + ready, self.cfg.max_units)
                self.pending = [p for p in self.pending if p[0] > now]
        return self.units

    def note_step(self, busy_fraction: float, new_arrivals: int) -> None:
        """Accumulate the infrastructure/system window for the next Observation."""
        self._win_busy.append(float(busy_fraction))
        self._win_arrivals += int(new_arrivals)
        self._steps += 1

    def should_adapt(self) -> bool:
        return self._steps % self.cfg.period_steps == 0

    def observe(self, *, time: float, n_in_system: int) -> Observation:
        """Build the three-tier Observation at the current window edge."""
        from repro.core.autoscaler.base import Observation
        signals = self.bus.snapshot(self._steps, self.cfg.window_bins)
        primary = signals.get(self.cfg.signal_channel)
        return Observation(
            time=time,
            n_units=self.units,
            n_pending=self.n_pending,
            utilization=float(np.mean(self._win_busy)) if self._win_busy else 0.0,
            n_in_system=int(n_in_system),
            input_rate=self._win_arrivals / self.cfg.adapt_period_s,
            app_window_mean=primary.mean if primary else 0.0,
            app_prev_window_mean=primary.prev_mean if primary else 0.0,
            app_window_count=primary.count if primary else 0,
            signals=signals,
        )

    def maybe_adapt(self, *, time: float, n_in_system: int) -> DecisionRecord | None:
        """On-cadence: observe -> decide -> actuate under Table III mechanics."""
        if not self.should_adapt():
            return None
        obs = self.observe(time=time, n_in_system=n_in_system)
        d: Decision = self.policy.decide(obs)
        applied = 0
        if d.delta > 0:
            self.n_up += 1
            applied = int(d.delta)
            self.pending.append((time + self.cfg.provision_delay_s, applied))
        elif d.delta < 0 and self.units > self.cfg.min_units:
            self.n_down += 1
            applied = -min(self.cfg.downscale_cap, -int(d.delta),
                           self.units - self.cfg.min_units)
            self.units += applied
        rec = DecisionRecord(time=time, requested=int(d.delta), applied=applied,
                             reason=d.reason, units=self.units,
                             pending=self.n_pending)
        self.decision_log.append(rec)
        self._win_busy = []
        self._win_arrivals = 0
        return rec


__all__ = ["ControllerConfig", "DecisionRecord", "ScalingController"]
