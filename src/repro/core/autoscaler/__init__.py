from repro.core.autoscaler.base import CompositePolicy, Decision, Observation, Policy
from repro.core.autoscaler.policies import (
    AppDataPolicy,
    CheapestFirstRouter,
    LoadPolicy,
    ScheduledPolicy,
    TargetTrackingPolicy,
    ThresholdPolicy,
    WebhookPolicy,
)

__all__ = [
    "CompositePolicy", "Decision", "Observation", "Policy",
    "AppDataPolicy", "CheapestFirstRouter", "LoadPolicy", "ScheduledPolicy",
    "TargetTrackingPolicy", "ThresholdPolicy", "WebhookPolicy",
]
