"""Auto-scaling policy interface shared by every scaling backend (tweet
simulator, elastic LLM-serving fleet, live serving driver).

A policy sees an :class:`Observation` once per adaptation period and returns a
:class:`Decision`.  The *controller* (`repro.core.scaling.ScalingController`)
owns the mechanics the paper fixes in Table III: the 60 s adaptation
frequency, the 60 s resource-provisioning delay, the 1-unit-at-a-time
downscale limit, and the >= 1 resource floor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping

if TYPE_CHECKING:  # no runtime import: scaling.controller imports this module
    from repro.core.scaling.signals import WindowStats


@dataclass(frozen=True)
class Observation:
    """What a policy may look at.  Three tiers, per the paper's taxonomy:

    * infrastructure level -- ``utilization``;
    * system level -- ``n_in_system`` (queue + in service), ``input_rate``;
    * application level -- ``signals``: windowed stats per *named channel* of
      data produced by the application itself (sentiment of processed tweets,
      score of generated answers, any user channel).

    The ``app_*`` fields are the pre-redesign single-channel view; the
    controller keeps them mirrored to its primary channel so existing policies
    keep working.  New policies should read ``signal(channel)``.
    """

    time: float
    n_units: int                      # currently usable resources (CPUs / replicas)
    n_pending: int                    # allocated, still inside the provisioning delay
    utilization: float                # mean busy fraction over the last adapt window
    n_in_system: int
    input_rate: float                 # arrivals/s over the last adapt window
    app_window_mean: float = 0.0      # mean app-signal, last window (post-time indexed)
    app_prev_window_mean: float = 0.0  # mean app-signal, window before that
    app_window_count: int = 0         # how many signal samples backed app_window_mean
    signals: Mapping[str, WindowStats] = field(default_factory=dict)

    def signal(self, channel: str | None = None) -> WindowStats:
        """Windowed stats for a named channel; ``None`` selects the backend's
        primary channel (equivalently, the legacy ``app_*`` fields).

        Channels register lazily on their first recorded sample, so a channel
        with no data yet — or a misspelled name — reads as empty stats
        (``count == 0``) rather than raising; signal-driven policies should
        treat ``count`` below their sample floor as "no evidence"."""
        from repro.core.scaling.signals import WindowStats
        if channel is not None:
            if channel in self.signals:
                return self.signals[channel]
            return WindowStats()
        return WindowStats(mean=self.app_window_mean,
                           count=self.app_window_count,
                           prev_mean=self.app_prev_window_mean)


@dataclass(frozen=True)
class Decision:
    """delta > 0 allocates (subject to provisioning delay); delta < 0 releases."""

    delta: int = 0
    reason: str = ""

    def __add__(self, other: "Decision") -> "Decision":
        reason = ";".join(r for r in (self.reason, other.reason) if r)
        return Decision(self.delta + other.delta, reason)


class Policy:
    """Base class.  Policies are stateful (e.g. edge detection) but cheap."""

    name = "base"

    def reset(self) -> None:  # called once per simulation run
        pass

    def decide(self, obs: Observation) -> Decision:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class CompositePolicy(Policy):
    """Run several policies side by side (paper: appdata "runs alongside the load
    algorithm").  Upscale requests add; downscale is capped at -1 by the controller."""

    name = "composite"

    def __init__(self, policies: list[Policy]):
        self.policies = list(policies)

    def reset(self) -> None:
        for p in self.policies:
            p.reset()

    def decide(self, obs: Observation) -> Decision:
        total = Decision()
        for p in self.policies:
            d = p.decide(obs)
            # A positive vote from any sub-policy wins over another's -1 release.
            if d.delta > 0 and total.delta < 0:
                total = dataclasses.replace(total, delta=0)
            if total.delta > 0 and d.delta < 0:
                d = dataclasses.replace(d, delta=0)
            total = total + d
        return total

    def describe(self) -> str:
        return "+".join(p.describe() for p in self.policies)
