"""Auto-scaling policy interface shared by the tweet simulator (paper repro) and the
elastic LLM-serving runtime (`repro.core.elastic`).

A policy sees an :class:`Observation` once per adaptation period and returns a
:class:`Decision`.  The *controller* (simulator engine or replica manager) owns the
mechanics the paper fixes in Table III: the 60 s adaptation frequency, the 60 s
resource-provisioning delay, the 1-unit-at-a-time downscale limit, and the >= 1
resource floor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class Observation:
    """What a policy may look at.  Three tiers, per the paper's taxonomy:

    * infrastructure level -- ``utilization``;
    * system level -- ``n_in_system`` (queue + in service), ``input_rate``;
    * application level -- the sentiment-window means (data produced *by* the app).
    """

    time: float
    n_units: int                      # currently usable resources (CPUs / replicas)
    n_pending: int                    # allocated, still inside the provisioning delay
    utilization: float                # mean busy fraction over the last adapt window
    n_in_system: int
    input_rate: float                 # arrivals/s over the last adapt window
    app_window_mean: float            # mean app-signal, last window (post-time indexed)
    app_prev_window_mean: float       # mean app-signal, window before that
    app_window_count: int             # how many signal samples backed app_window_mean


@dataclass(frozen=True)
class Decision:
    """delta > 0 allocates (subject to provisioning delay); delta < 0 releases."""

    delta: int = 0
    reason: str = ""

    def __add__(self, other: "Decision") -> "Decision":
        reason = ";".join(r for r in (self.reason, other.reason) if r)
        return Decision(self.delta + other.delta, reason)


class Policy:
    """Base class.  Policies are stateful (e.g. edge detection) but cheap."""

    name = "base"

    def reset(self) -> None:  # called once per simulation run
        pass

    def decide(self, obs: Observation) -> Decision:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class CompositePolicy(Policy):
    """Run several policies side by side (paper: appdata "runs alongside the load
    algorithm").  Upscale requests add; downscale is capped at -1 by the controller."""

    name = "composite"

    def __init__(self, policies: list[Policy]):
        self.policies = list(policies)

    def reset(self) -> None:
        for p in self.policies:
            p.reset()

    def decide(self, obs: Observation) -> Decision:
        total = Decision()
        for p in self.policies:
            d = p.decide(obs)
            # A positive vote from any sub-policy wins over another's -1 release.
            if d.delta > 0 and total.delta < 0:
                total = dataclasses.replace(total, delta=0)
            if total.delta > 0 and d.delta < 0:
                d = dataclasses.replace(d, delta=0)
            total = total + d
        return total

    def describe(self) -> str:
        return "+".join(p.describe() for p in self.policies)
