"""Auto-scaling policy interface shared by every scaling backend (tweet
simulator, elastic LLM-serving fleet, live serving driver).

A policy sees an :class:`Observation` once per adaptation period and returns a
:class:`Decision`.  The *controller* (`repro.core.scaling.ScalingController`)
owns the mechanics the paper fixes in Table III: the 60 s adaptation
frequency, the 60 s resource-provisioning delay, the 1-unit-at-a-time
downscale limit, and the >= 1 resource floor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping

if TYPE_CHECKING:  # no runtime import: scaling.controller imports this module
    from repro.core.scaling.capacity import PoolStats
    from repro.core.scaling.signals import WindowStats


@dataclass(frozen=True)
class Observation:
    """What a policy may look at.  Three tiers, per the paper's taxonomy:

    * infrastructure level -- ``utilization``;
    * system level -- ``n_in_system`` (queue + in service), ``input_rate``;
    * application level -- ``signals``: windowed stats per *named channel* of
      data produced by the application itself (sentiment of processed tweets,
      score of generated answers, any user channel).

    The ``app_*`` fields are the pre-redesign single-channel view; the
    controller keeps them mirrored to its primary channel so existing policies
    keep working.  New policies should read ``signal(channel)``.
    """

    time: float
    n_units: int                      # currently usable resources (CPUs / replicas)
    n_pending: int                    # allocated, still inside the provisioning delay
    utilization: float                # mean busy fraction over the last adapt window
    n_in_system: int
    input_rate: float                 # arrivals/s over the last adapt window
    app_window_mean: float = 0.0      # mean app-signal, last window (post-time indexed)
    app_prev_window_mean: float = 0.0  # mean app-signal, window before that
    app_window_count: int = 0         # how many signal samples backed app_window_mean
    signals: Mapping[str, WindowStats] = field(default_factory=dict)
    pools: Mapping[str, PoolStats] = field(default_factory=dict)
    # ``pools``: per-pool capacity view (live/pending/price/preemptible) when the
    # controller runs a typed CapacityPlan; n_units/n_pending stay the totals.

    def signal(self, channel: str | None = None) -> WindowStats:
        """Windowed stats for a named channel; ``None`` selects the backend's
        primary channel (equivalently, the legacy ``app_*`` fields).

        Channels register lazily on their first recorded sample, so a channel
        with no data yet — or a misspelled name — reads as empty stats
        (``count == 0``) rather than raising; signal-driven policies should
        treat ``count`` below their sample floor as "no evidence"."""
        from repro.core.scaling.signals import WindowStats
        if channel is not None:
            if channel in self.signals:
                return self.signals[channel]
            return WindowStats()
        return WindowStats(mean=self.app_window_mean,
                           count=self.app_window_count,
                           prev_mean=self.app_prev_window_mean)


@dataclass(frozen=True)
class Decision:
    """delta > 0 allocates (subject to provisioning delay); delta < 0 releases.

    The scalar ``delta`` targets the controller's *default* pool.  A
    pool-aware policy may instead set ``pools`` to per-pool deltas (e.g.
    ``{"spot": +4}``); when ``pools`` is not None it is authoritative and
    ``delta`` is ignored.  ``total`` is the net delta either way -- the sign
    every scalar consumer (composition, veto logic) keys on.
    """

    delta: int = 0
    reason: str = ""
    pools: Mapping[str | None, int] | None = None

    @property
    def total(self) -> int:
        if self.pools is not None:
            return sum(self.pools.values())
        return self.delta

    def pool_deltas(self, default_pool: str) -> dict[str, int]:
        """Per-pool deltas with the scalar form mapped onto ``default_pool``.
        (A ``None`` key -- produced when composition merges a scalar vote with
        pool-targeted ones -- also resolves to the default pool.)"""
        if self.pools is None:
            return {default_pool: int(self.delta)} if self.delta else {}
        out: dict[str, int] = {}
        for name, d in self.pools.items():
            key = default_pool if name is None else name
            out[key] = out.get(key, 0) + int(d)
        return {k: v for k, v in out.items() if v != 0}

    def __add__(self, other: "Decision") -> "Decision":
        reason = ";".join(r for r in (self.reason, other.reason) if r)
        if self.pools is None and other.pools is None:
            return Decision(self.delta + other.delta, reason)
        # merge in pool space; scalar sides keep targeting the default pool,
        # represented by the None key until the controller resolves it
        merged: dict[str | None, int] = {}
        for d in (self, other):
            items = (d.pools.items() if d.pools is not None
                     else ((None, d.delta),) if d.delta else ())
            for name, dd in items:
                merged[name] = merged.get(name, 0) + int(dd)
        merged = {k: v for k, v in merged.items() if v != 0}
        if set(merged) <= {None}:
            return Decision(merged.get(None, 0), reason)
        return Decision(0, reason, pools=merged)


class Policy:
    """Base class.  Policies are stateful (e.g. edge detection) but cheap."""

    name = "base"

    def reset(self) -> None:  # called once per simulation run
        pass

    def decide(self, obs: Observation) -> Decision:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class CompositePolicy(Policy):
    """Run several policies side by side (paper: appdata "runs alongside the load
    algorithm").  Upscale requests add; downscale is capped at -1 by the controller."""

    name = "composite"

    def __init__(self, policies: list[Policy]):
        self.policies = list(policies)

    def reset(self) -> None:
        for p in self.policies:
            p.reset()

    def decide(self, obs: Observation) -> Decision:
        total = Decision()
        for p in self.policies:
            d = p.decide(obs)
            # A positive vote from any sub-policy wins over another's -1 release.
            if d.total > 0 and total.total < 0:
                total = dataclasses.replace(total, delta=0, pools=None)
            if total.total > 0 and d.total < 0:
                d = dataclasses.replace(d, delta=0, pools=None)
            total = total + d
        return total

    def describe(self) -> str:
        return "+".join(p.describe() for p in self.policies)
