"""The paper's three auto-scaling algorithms (§IV-C).

* :class:`ThresholdPolicy` -- the classic infrastructure-metric baseline: +1 unit when
  mean CPU usage exceeds the threshold, -1 when it drops below 50%.
* :class:`LoadPolicy` -- a-priori knowledge of the service-demand distributions:
  estimates the time to drain everything currently in the system from a configurable
  quantile of the per-class Weibulls; scales *multiplicatively*
  (``units' = ceil(units * expectedDelay / SLA)``), releases one unit at a time when
  the estimate falls below SLA/2.
* :class:`AppDataPolicy` -- the application-data trigger: compares the mean sentiment
  score of the last 120 s window (tweets indexed by *post time* -- §V-B stresses this)
  with the window before; a rise >= 0.5 allocates a fixed number of extra units.
"""
from __future__ import annotations

import math

from repro.core.autoscaler.base import Decision, Observation, Policy
from repro.core.scaling.registry import register_policy
from repro.core.simulator.distributions import ServiceModel


class ThresholdPolicy(Policy):
    """CPU-usage threshold rule (§IV-C "threshold algorithm")."""

    name = "threshold"

    def __init__(self, upper: float = 0.9, lower: float = 0.5):
        if not 0.0 < upper <= 1.0:
            raise ValueError(f"threshold must be in (0, 1], got {upper}")
        self.upper = upper
        self.lower = lower

    def decide(self, obs: Observation) -> Decision:
        if obs.utilization > self.upper:
            return Decision(+1, f"util {obs.utilization:.2f} > {self.upper:.2f}")
        if obs.utilization < self.lower and obs.n_units > 1:
            return Decision(-1, f"util {obs.utilization:.2f} < {self.lower:.2f}")
        return Decision()

    def describe(self) -> str:
        return f"threshold({int(self.upper * 100)}%)"


class LoadPolicy(Policy):
    """A-priori load model (§IV-C "load algorithm").

    ``expectedDelay`` = time to process all tweets currently in the system, assuming
    every one of them demands the ``quantile``-level service of the a-priori class
    mixture and the available units are shared egalitarianly:

        expectedDelay = n_in_system * quantile_cycles / (units * freq_hz)

    Upscale when it exceeds the SLA, by the paper's multiplicative rule; downscale by
    exactly one unit when it falls below half the SLA.
    """

    name = "load"

    def __init__(
        self,
        service_model: ServiceModel,
        *,
        quantile: float = 0.99999,
        sla_s: float = 300.0,
        freq_hz: float = 2.0e9,
        count_pending: bool = True,
    ):
        self.sm = service_model
        self.quantile = quantile
        self.sla_s = sla_s
        self.freq_hz = freq_hz
        self.count_pending = count_pending
        self._q_cycles = service_model.quantile_cycles(quantile)
        self._mean_cycles = service_model.mean_cycles()

    def expected_delay(self, n_in_system: int, units: int, *, pessimistic: bool = True) -> float:
        """Drain-time estimate for everything in the system.

        ``pessimistic=True`` prices every tweet at the class-weighted ``quantile``
        service demand (the paper's early-reaction knob: "the higher the quantile
        the more pessimistic the model is and more likely it is to react before the
        SLA is really violated").  ``pessimistic=False`` prices at the mean, which
        is what the *size* of the allocation is computed from -- this is the
        reading under which the paper's own published costs are reproducible: load
        cost sits at the throughput bound and is nearly quantile-invariant (2.76
        CPU-h across every quantile on England, "cost differences for different
        quantiles is insignificant"), which is impossible if the allocation size
        itself scaled with the ~1.6-4.7x quantile inflation.  The quantile still
        costs slightly more via the earlier trigger and the later release, matching
        "a higher quantile will also spend more resources".  See DESIGN.md
        (Deviations).
        """
        if units <= 0:
            return math.inf
        per = self._q_cycles if pessimistic else self._mean_cycles
        return n_in_system * per / (units * self.freq_hz)

    def decide(self, obs: Observation) -> Decision:
        units = obs.n_units + (obs.n_pending if self.count_pending else 0)
        exp_q = self.expected_delay(obs.n_in_system, units)
        if exp_q > self.sla_s:
            exp_mean = self.expected_delay(obs.n_in_system, units, pessimistic=False)
            target = math.ceil(units * exp_mean / self.sla_s)
            delta = max(target - obs.n_units - obs.n_pending, 1)
            return Decision(delta, f"expectedDelay {exp_q:.0f}s > SLA")
        if exp_q < 0.5 * self.sla_s and obs.n_units > 1:
            return Decision(-1, f"expectedDelay {exp_q:.0f}s < SLA/2")
        return Decision()

    def describe(self) -> str:
        return f"load(q={self.quantile:g})"


class AppDataPolicy(Policy):
    """Application-data peak detector (§IV-C "appdata algorithm").

    Only ever *adds* units ("only deals with peaks, is oblivious to ordinary increases
    of traffic and runs alongside the load algorithm").  Edge-triggered: a sustained
    high window fires once, not on every 60 s evaluation while it stays high.
    """

    name = "appdata"

    def __init__(self, *, jump: float = 0.5, extra_units: int = 1,
                 min_samples: int = 20, relative: bool = True,
                 channel: str | None = None):
        """``jump``: required window-mean rise.  ``relative=True`` (default) reads
        the paper's "increases by 0.5 or more" as a 50% *relative* rise -- with
        scores bounded in [0,1] and a typical level above 0.4 (Fig 2), an absolute
        +0.5 jump from the running level is close to unreachable, so the relative
        reading is the one that can have produced the paper's results.
        ``relative=False`` gives the literal absolute-difference trigger.
        ``channel`` names the SignalBus channel to watch; ``None`` (default)
        watches the backend's primary channel.  See DESIGN.md (Deviations)."""
        self.jump = jump
        self.extra_units = extra_units
        self.min_samples = min_samples
        self.relative = relative
        self.channel = channel
        self._armed = True

    def reset(self) -> None:
        self._armed = True

    def decide(self, obs: Observation) -> Decision:
        st = obs.signal(self.channel)
        if st.count < self.min_samples:
            return Decision()
        rise = st.relative_rise if self.relative else st.rise
        if rise >= self.jump:
            if self._armed:
                self._armed = False
                label = self.channel or "signal"
                return Decision(self.extra_units,
                                f"{label} +{rise:.2f} >= {self.jump:.2f}")
            return Decision()
        self._armed = True
        return Decision()

    def describe(self) -> str:
        ch = f",{self.channel}" if self.channel else ""
        return f"appdata(+{self.extra_units}{ch})"


class CheapestFirstRouter(Policy):
    """Route an inner policy's upscale votes into the cheapest capacity first.

    The inner policy stays pool-blind (it votes a scalar delta from its usual
    observation tiers); this wrapper re-expresses a positive vote as per-pool
    deltas, filling pools in ascending ``cost_rate`` order up to each pool's
    headroom (live + pending below its ceiling) and spilling the remainder
    into the next-cheapest pool.  Downscale votes pass through untouched --
    the controller already releases the most expensive capacity first, so the
    pair yields buy-cheap / sell-expensive behavior over e.g. a (spot,
    on-demand) pool pair.  Without a typed capacity plan (``obs.pools``
    empty) it is the identity wrapper.
    """

    name = "cheapest-first"

    def __init__(self, inner: Policy):
        self.inner = inner

    def reset(self) -> None:
        self.inner.reset()

    def decide(self, obs: Observation) -> Decision:
        d = self.inner.decide(obs)
        if d.pools is not None or d.total <= 0 or not obs.pools:
            return d
        remaining = d.total
        deltas: dict[str, int] = {}
        by_price = sorted(obs.pools.items(), key=lambda kv: kv[1].cost_rate)
        for pool_name, ps in by_price:
            take = min(remaining, ps.headroom)
            if take > 0:
                deltas[pool_name] = take
                remaining -= take
            if remaining == 0:
                break
        if remaining > 0 and by_price:
            # every pool at its ceiling: leave the excess on the cheapest pool
            # (landing clamps it), preserving the vote's magnitude in the log
            name0 = by_price[0][0]
            deltas[name0] = deltas.get(name0, 0) + remaining
        return Decision(0, d.reason, pools=deltas)

    def describe(self) -> str:
        return f"cheapest({self.inner.describe()})"


class TargetTrackingPolicy(Policy):
    """ASG-style target tracking (SNIPPETS: "Target tracking (e.g., 50% CPU)").

    Keeps a metric near ``target`` by solving for the capacity that would put
    it there under linear scaling:  ``desired = ceil(capacity * metric /
    target)``.  Tracks ``utilization`` by default; ``metric="in_system"``
    tracks items-in-system per unit; ``metric="signal"`` tracks a named
    application channel's window mean.  A dead band around the target prevents
    flapping, and scale-in honours an optional cooldown.
    """

    name = "target"

    def __init__(self, *, target: float = 0.5, metric: str = "utilization",
                 channel: str | None = None, deadband: float = 0.1,
                 cooldown_s: float = 0.0):
        if target <= 0.0:
            raise ValueError(f"target must be positive, got {target}")
        if metric not in ("utilization", "in_system", "signal"):
            raise ValueError(f"unknown metric {metric!r}")
        self.target = target
        self.metric = metric
        self.channel = channel
        self.deadband = deadband
        self.cooldown_s = cooldown_s
        self._last_action_t = -math.inf

    def reset(self) -> None:
        self._last_action_t = -math.inf

    def _current(self, obs: Observation) -> float:
        if self.metric == "utilization":
            return obs.utilization
        if self.metric == "in_system":
            cap = max(obs.n_units + obs.n_pending, 1)
            return obs.n_in_system / cap
        return obs.signal(self.channel).mean

    def decide(self, obs: Observation) -> Decision:
        cur = self._current(obs)
        if abs(cur - self.target) <= self.deadband * self.target:
            return Decision()
        capacity = obs.n_units + obs.n_pending
        # utilization is produced by the LIVE units only, so the implied load is
        # n_units * cur; scaling pending capacity by it would re-request units
        # that are already provisioning, compounding every tick the delay spans.
        # The other metrics are already normalized over full capacity.
        basis = obs.n_units if self.metric == "utilization" else capacity
        desired = max(math.ceil(basis * cur / self.target), 1)
        delta = desired - capacity
        if delta > 0:
            self._last_action_t = obs.time
            return Decision(delta, f"{self.metric} {cur:.2f} -> target {self.target:.2f}")
        # scale in only when the metric itself is low: pending capacity queued
        # by a co-composed policy can push capacity past desired while the
        # live units are still running hot
        if delta < 0 and cur < self.target and obs.n_units > 1:
            if obs.time - self._last_action_t < self.cooldown_s:
                return Decision()
            self._last_action_t = obs.time
            return Decision(-1, f"{self.metric} {cur:.2f} below target")
        return Decision()

    def describe(self) -> str:
        return f"target({self.metric}={self.target:g})"


class ScheduledPolicy(Policy):
    """ASG "scheduled actions": hold a capacity floor during known windows
    (match kickoff, product launch, nightly batch).  Pre-provisions *ahead* of
    each window by the provisioning delay so the floor is usable when the
    window opens; outside windows it stays silent, composing with reactive
    policies in a :class:`CompositePolicy`.
    """

    name = "scheduled"

    def __init__(self, schedule: list[tuple[float, float, int]], *,
                 lead_s: float = 60.0):
        """``schedule``: (start_s, end_s, min_units) entries; ``lead_s``: how
        far ahead of a window start to request capacity (set this to the
        backend's provisioning delay)."""
        self.schedule = sorted(schedule)
        self.lead_s = lead_s

    def _floor(self, t: float) -> int:
        floor = 0
        for start, end, units in self.schedule:
            if start - self.lead_s <= t < end:
                floor = max(floor, units)
        return floor

    def decide(self, obs: Observation) -> Decision:
        floor = self._floor(obs.time)
        have = obs.n_units + obs.n_pending
        if have < floor:
            return Decision(floor - have, f"scheduled floor {floor}")
        return Decision()

    def describe(self) -> str:
        return f"scheduled({len(self.schedule)} windows)"


class WebhookPolicy(Policy):
    """Event-triggered capacity floors: ``fire(name, now)`` arms a named
    trigger whose floor holds for its ``hold_s`` window (an external alert --
    a breaking-news detector, a deploy hook -- asking for capacity *now*).

    The imperative-mode counterpart of a scaling group's webhook
    desired-state changes (see :mod:`repro.core.convergence.groups`); an
    optional ``schedule`` folds :class:`ScheduledPolicy`-style windows into
    the same floor, so ``ScalingGroup.as_policy()`` can express both.
    Outside active holds it stays silent, composing with reactive policies in
    a :class:`CompositePolicy`.
    """

    name = "webhook"

    def __init__(self, triggers: dict[str, tuple[int, float]], *,
                 schedule: tuple[tuple[float, float, int], ...] = (),
                 lead_s: float = 0.0):
        """``triggers``: name -> (min_units, hold_s); ``schedule``: optional
        (start_s, end_s, min_units) windows active without any firing."""
        self.triggers = dict(triggers)
        self.schedule = ScheduledPolicy(list(schedule), lead_s=lead_s) \
            if schedule else None
        self._fired: list[tuple[float, int, float]] = []  # (t0, units, hold_s)

    def reset(self) -> None:
        self._fired = []
        if self.schedule is not None:
            self.schedule.reset()

    def fire(self, name: str, now: float) -> None:
        if name not in self.triggers:
            raise ValueError(f"unknown webhook {name!r}; registered: "
                             f"{sorted(self.triggers)}")
        units, hold_s = self.triggers[name]
        self._fired.append((float(now), int(units), float(hold_s)))

    def _floor(self, t: float) -> int:
        floor = 0
        for t0, units, hold_s in self._fired:
            if t0 <= t < t0 + hold_s:
                floor = max(floor, units)
        if self.schedule is not None:
            floor = max(floor, self.schedule._floor(t))
        return floor

    def decide(self, obs: Observation) -> Decision:
        floor = self._floor(obs.time)
        have = obs.n_units + obs.n_pending
        if have < floor:
            return Decision(floor - have, f"webhook floor {floor}")
        return Decision()

    def describe(self) -> str:
        return f"webhook({len(self.triggers)} triggers)"


# -- registry: name -> factory, so launchers/benchmarks can name policies ------------
def _scheduled_factory(**kw):
    if "schedule" not in kw:
        raise ValueError(
            "policy 'scheduled' needs schedule=[(start_s, end_s, min_units), ...]")
    return ScheduledPolicy(kw.pop("schedule"), **kw)


register_policy("threshold", ThresholdPolicy)
register_policy("load",
                lambda **kw: LoadPolicy(kw.pop("service_model", ServiceModel()), **kw))
register_policy("appdata", AppDataPolicy)
register_policy("target", TargetTrackingPolicy)
def _webhook_factory(**kw):
    if "triggers" not in kw:
        raise ValueError(
            "policy 'webhook' needs triggers={name: (min_units, hold_s), ...}")
    return WebhookPolicy(kw.pop("triggers"), **kw)


register_policy("scheduled", _scheduled_factory)
register_policy("webhook", _webhook_factory)
