"""Application-signal analysis (paper §III-A): the evidence base for `appdata`.

* :func:`lag_correlation_table` -- Table I: Pearson correlation of per-minute mean
  sentiment with tweet volume at lags 0..10 minutes.
* :func:`windowed_variation` -- Fig 3's "sentiment variation" series: difference of
  consecutive window means.
* :func:`burst_lead_report` -- measures how far ahead of each ground-truth burst the
  variation signal fires (the 1-2 minute early warning the paper exploits).
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator.workload import Trace
from repro.utils.stats import pearson


def ema(x: np.ndarray, alpha: float) -> np.ndarray:
    """Exponential moving average (the paper smooths the sentiment series)."""
    x = np.asarray(x, dtype=np.float64)
    out = np.empty_like(x)
    acc = x[0] if x.size else 0.0
    for i, v in enumerate(x):
        if np.isnan(v):
            v = acc
        acc = alpha * v + (1.0 - alpha) * acc
        out[i] = acc
    return out


def lag_correlation_table(trace: Trace, max_lag_min: int = 10, ema_alpha: float = 0.35):
    """Pearson(sentiment @ minute t, volume @ minute t+lag) for lag = 0..max_lag.

    Reproduces Table I: ~0.79 at lag 0 decaying slowly to ~0.70 at lag 10.
    """
    sent, vol = trace.minute_series()
    # fill sparse minutes, smooth like the paper ("an exponential moving average is used")
    sent = ema(np.nan_to_num(sent, nan=float(np.nanmean(sent))), ema_alpha)
    rows = []
    for lag in range(max_lag_min + 1):
        s = sent[: sent.size - lag] if lag else sent
        v = vol[lag:]
        rows.append((lag, pearson(s, v)))
    return rows


def windowed_variation(trace: Trace, window_s: float = 120.0,
                       relative: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """(times, variation): difference (or relative rise, ``relative=True``) between
    the mean sentiment of consecutive windows of ``window_s``, indexed by tweet post
    time -- the appdata trigger's view.
    """
    w = int(window_s)
    n = trace.duration
    bins = np.minimum(trace.post_time.astype(np.int64), n - 1)
    s_sum = np.bincount(bins, weights=trace.sentiment.astype(np.float64), minlength=n)
    s_cnt = np.bincount(bins, minlength=n)
    csum, ccnt = np.cumsum(s_sum), np.cumsum(s_cnt)

    def wmean(hi):  # mean over [hi-w, hi)
        hi = np.asarray(hi)
        lo = np.maximum(hi - w, 0)
        tot = csum[hi - 1] - np.where(lo > 0, csum[lo - 1], 0.0)
        cnt = ccnt[hi - 1] - np.where(lo > 0, ccnt[lo - 1], 0)
        return np.where(cnt > 0, tot / np.maximum(cnt, 1), 0.0)

    times = np.arange(2 * w, n, 60)
    m1, m0 = wmean(times), wmean(times - w)
    if relative:
        var = np.where(m0 > 1e-6, m1 / np.maximum(m0, 1e-6) - 1.0, 0.0)
    else:
        var = m1 - m0
    return times.astype(np.float64), var


def burst_lead_report(trace: Trace, *, jump: float = 0.5, window_s: float = 120.0) -> dict:
    """How well does the sentiment-variation trigger anticipate real bursts?

    A burst counts as *detected* if the relative window-mean rise crosses ``jump``
    within [onset - 240 s, onset + 60 s].  Leads are onset - first-crossing
    (positive = early warning).  Crossings far from any burst are false positives
    (Fig 3 shows "some false positives and a false negative").
    """
    times, var = windowed_variation(trace, window_s, relative=True)
    fire = times[np.nonzero((var >= jump) & (np.concatenate(([0.0], var[:-1])) < jump))[0]]
    leads, detected = [], 0
    for onset in trace.burst_times:
        near = fire[(fire >= onset - 240.0) & (fire <= onset + 60.0)]
        if near.size:
            detected += 1
            leads.append(float(onset - near[0]))
    n_fp = int(sum(1 for f in fire
                   if not any(abs(f - o) <= 300.0 for o in trace.burst_times)))
    return {
        "n_bursts": int(trace.burst_times.size),
        "n_detected": detected,
        "mean_lead_s": float(np.mean(leads)) if leads else float("nan"),
        "n_false_positives": n_fp,
        "n_fires": int(fire.size),
    }


__all__ = ["ema", "lag_correlation_table", "windowed_variation", "burst_lead_report"]
