from repro.core.signals.analysis import (
    burst_lead_report,
    ema,
    lag_correlation_table,
    windowed_variation,
)

__all__ = ["ema", "lag_correlation_table", "windowed_variation", "burst_lead_report"]
