"""The real mechanism behind replica elasticity: mesh rebuild + parameter
resharding.

Scale-out on TPU means: bring up a new slice, rebuild the device mesh at the
new DP degree, and re-place parameters under the shardings derived for the new
mesh.  With jax arrays this is a ``device_put`` of the old (possibly
differently-laid-out) arrays onto the new NamedShardings -- XLA moves only the
bytes that must move.  Fault-handling uses the same path: on a lost slice,
rebuild the mesh over the survivors and restore from the latest checkpoint
(`repro.checkpoint`).
"""
from __future__ import annotations

import dataclasses
import time

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import param_sharding


def elastic_remesh_plan(n_devices: int, *, model_parallel: int) -> tuple[int, int]:
    """(dp, tp) for the new world size; dp absorbs the change."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by tp={model_parallel}")
    return n_devices // model_parallel, model_parallel


def remesh_params(params, new_mesh: Mesh):
    """Re-place ``params`` for ``new_mesh`` under the standard sharding rules."""
    abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    new_sh = param_sharding(abstract, new_mesh)
    return jax.device_put(params, new_sh)


def scale_replicas(params, *, devices, model_parallel: int,
                   axis_names=("data", "model")) -> tuple:
    """Build a mesh over ``devices`` at the widest DP degree and re-place
    params.  Returns (new_mesh, params_on_new_mesh)."""
    dp, tp = elastic_remesh_plan(len(devices), model_parallel=model_parallel)
    import numpy as np
    dev_grid = np.asarray(devices).reshape(dp, tp)
    new_mesh = Mesh(dev_grid, axis_names)
    return new_mesh, remesh_params(params, new_mesh)


def measure_provision_delay(model, params, *, devices, model_parallel: int,
                            probe_batch: int = 2, probe_len: int = 16):
    """Measure the wall-clock cost of ONE elastic transition -- mesh rebuild +
    parameter re-placement + first forward on the new mesh (compile/warmup).

    This is the live analogue of ``ClusterConfig.provision_delay_s``: what a
    replica actually costs to bring up, measured on the serving path instead
    of assumed.  Returns ``(seconds, new_mesh, params_on_new_mesh)`` so a
    sweep can chain transitions on the re-placed params.
    """
    import numpy as np
    t0 = time.perf_counter()
    mesh, params = scale_replicas(params, devices=devices,
                                  model_parallel=model_parallel)
    with mesh:
        logits, _ = jax.jit(model.forward)(
            params, {"tokens": np.zeros((probe_batch, probe_len), np.int32)})
        jax.block_until_ready(logits)
    return time.perf_counter() - t0, mesh, params


def provisioned_cluster_config(base, measured_s: float, *,
                               floor_s: float = 1.0):
    """A copy of ``base`` (an elastic ``ClusterConfig``) whose
    ``provision_delay_s`` is the measured remesh cost instead of the
    assumed default -- the ROADMAP "live-backend depth" wiring."""
    return dataclasses.replace(base,
                               provision_delay_s=max(float(measured_s),
                                                     floor_s))


__all__ = ["elastic_remesh_plan", "remesh_params", "scale_replicas",
           "measure_provision_delay", "provisioned_cluster_config"]
