"""The real mechanism behind replica elasticity: mesh rebuild + parameter
resharding.

Scale-out on TPU means: bring up a new slice, rebuild the device mesh at the
new DP degree, and re-place parameters under the shardings derived for the new
mesh.  With jax arrays this is a ``device_put`` of the old (possibly
differently-laid-out) arrays onto the new NamedShardings -- XLA moves only the
bytes that must move.  Fault-handling uses the same path: on a lost slice,
rebuild the mesh over the survivors and restore from the latest checkpoint
(`repro.checkpoint`).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import param_sharding
from repro.launch.mesh import make_mesh


def elastic_remesh_plan(n_devices: int, *, model_parallel: int) -> tuple[int, int]:
    """(dp, tp) for the new world size; dp absorbs the change."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by tp={model_parallel}")
    return n_devices // model_parallel, model_parallel


def remesh_params(params, new_mesh: Mesh):
    """Re-place ``params`` for ``new_mesh`` under the standard sharding rules."""
    abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    new_sh = param_sharding(abstract, new_mesh)
    return jax.device_put(params, new_sh)


def scale_replicas(params, *, devices, model_parallel: int,
                   axis_names=("data", "model")) -> tuple:
    """Build a mesh over ``devices`` at the widest DP degree and re-place
    params.  Returns (new_mesh, params_on_new_mesh)."""
    dp, tp = elastic_remesh_plan(len(devices), model_parallel=model_parallel)
    import numpy as np
    dev_grid = np.asarray(devices).reshape(dp, tp)
    new_mesh = Mesh(dev_grid, axis_names)
    return new_mesh, remesh_params(params, new_mesh)


__all__ = ["elastic_remesh_plan", "remesh_params", "scale_replicas"]
