from repro.core.elastic.cluster import (
    ClusterConfig,
    ElasticCluster,
    ElasticResult,
    ReplicaSpec,
    ServeRequest,
)
from repro.core.elastic.remesh import (
    elastic_remesh_plan,
    measure_provision_delay,
    provisioned_cluster_config,
    remesh_params,
)

__all__ = ["ClusterConfig", "ElasticCluster", "ElasticResult", "ReplicaSpec",
           "ServeRequest", "elastic_remesh_plan", "measure_provision_delay",
           "provisioned_cluster_config", "remesh_params"]
