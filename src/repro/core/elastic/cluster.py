"""Elastic LLM-serving cluster driven by the paper's auto-scaling policies.

This is the paper's resource-management insight transplanted to TPU serving:

* unit of elasticity = a model REPLICA (a DP slice of the pod) -- TPU meshes
  are torus-wired, so capacity moves in whole replicas, not single chips;
* per-request service demand comes from a-priori request CLASSES
  (prefill_len, decode_len buckets) priced by the roofline step-times of the
  compiled dry-run (the LLM analogue of the paper's per-class Weibulls);
* the `load` policy estimates the drain time of everything in the system from
  a quantile of the class mixture, exactly as in the paper;
* the `appdata` policy watches a signal computed from the application's own
  OUTPUT stream (e.g. windowed mean score of generated answers: a burst of
  "breaking-news-shaped" queries shifts the output distribution minutes before
  the request-rate peak) and pre-provisions replicas;
* provisioning delay = checkpoint restore + re-mesh + recompile, and scale-in
  releases one replica at a time (Table III semantics retained).

The cluster itself is a discrete-time simulation (1 s steps) whose per-replica
throughput is derived from the dry-run roofline numbers, so policy behaviour
is faithful to what the real fleet would do; the *mechanism* (mesh rebuild +
parameter resharding) is real JAX, exercised by `remesh.py` + tests.

Table III mechanics and window accounting are delegated to the shared
:class:`repro.core.scaling.ScalingController`/:class:`SignalBus` control
plane; this module only models the replica fleet's service process.  The
primary signal channel is ``output_score`` (windowed mean score of generated
answers); requests may carry additional named channels in ``signals`` (e.g. a
refusal-rate or topic-shift stream), all observable by policies via
``Observation.signal(channel)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.autoscaler.base import Policy
from repro.core.scaling import (
    ControllerConfig,
    RunReport,
    ScalingController,
    SignalBus,
)


@dataclass(frozen=True)
class ReplicaSpec:
    """Capacity model of one serving replica, priced from the dry-run."""

    chips: int = 16
    prefill_tokens_per_s: float = 250_000.0   # roofline-derived
    decode_tokens_per_s: float = 20_000.0     # batched decode, all slots
    max_slots: int = 64


@dataclass
class ServeRequest:
    rid: int
    arrival_s: float
    prefill_len: int
    decode_len: int
    score: float = 0.5            # application-output signal carried by the reply
    done_s: float | None = None
    signals: dict[str, float] = field(default_factory=dict)   # extra named channels

    def work_prefill(self) -> float:
        return float(self.prefill_len)

    def work_decode(self) -> float:
        return float(self.decode_len)


@dataclass(frozen=True)
class ClusterConfig:
    replica: ReplicaSpec = ReplicaSpec()
    sla_s: float = 30.0                      # request completion SLA
    adapt_period_s: float = 15.0
    provision_delay_s: float = 45.0          # restore + remesh + warmup
    starting_replicas: int = 1
    max_replicas: int = 64
    app_window_s: float = 60.0
    step_s: float = 1.0
    signal_channel: str = "output_score"     # primary channel (legacy app_* tier)


class _ClassModel:
    """A-priori (prefill+decode cost) distribution over request classes --
    the `load` policy's quantile service model."""

    def __init__(self, spec: ReplicaSpec):
        self.spec = spec
        self._samples: list[float] = []

    def observe(self, req: ServeRequest):
        self._samples.append(self.seconds_of(req))
        if len(self._samples) > 50_000:
            del self._samples[: len(self._samples) // 2]

    def seconds_of(self, req: ServeRequest) -> float:
        s = self.spec
        return req.work_prefill() / s.prefill_tokens_per_s \
            + req.work_decode() / (s.decode_tokens_per_s / s.max_slots)

    def quantile_seconds(self, q: float) -> float:
        if not self._samples:
            return 1.0
        return float(np.quantile(np.asarray(self._samples), q))

    def mean_seconds(self) -> float:
        if not self._samples:
            return 1.0
        return float(np.mean(self._samples))


class ElasticCluster:
    """Discrete-time elastic serving fleet under a Policy (threshold / load /
    appdata composite from `repro.core.autoscaler`)."""

    def __init__(self, cfg: ClusterConfig, policy: Policy,
                 requests: list[ServeRequest]):
        self.cfg = cfg
        self.policy = policy
        self.incoming = sorted(requests, key=lambda r: r.arrival_s)
        self.class_model = _ClassModel(cfg.replica)
        for r in self.incoming:
            self.class_model.observe(r)   # a-priori knowledge (training data)

    # -- the load policy's expected-drain estimator --------------------------------
    def expected_delay(self, n_in_system: int, replicas: int, q: float) -> float:
        if replicas <= 0:
            return math.inf
        per = self.class_model.quantile_seconds(q)
        return n_in_system * per / replicas

    def run(self) -> RunReport:
        cfg = self.cfg
        bus = SignalBus((cfg.signal_channel,), bin_s=cfg.step_s)
        ctrl = ScalingController(
            self.policy,
            ControllerConfig(
                adapt_period_s=cfg.adapt_period_s,
                provision_delay_s=cfg.provision_delay_s,
                max_units=cfg.max_replicas,
                step_s=cfg.step_s,
                app_window_s=cfg.app_window_s,
                signal_channel=cfg.signal_channel,
            ),
            bus,
            starting_units=cfg.starting_replicas,
        )
        t = 0.0
        heads = 0
        # explicit work accounting: the queue and slots carry (remaining service
        # seconds, request) pairs priced by the class model at arrival
        queue: list[tuple[float, ServeRequest]] = []
        inflight: list[list] = []     # [remaining_work_s, req]
        done: list[ServeRequest] = []
        replica_seconds = 0.0
        hist_replicas = []

        horizon = self.incoming[-1].arrival_s + 1.0 if self.incoming else 1.0
        while True:
            replicas = ctrl.on_step_start(t)
            # arrivals
            new_arr = 0
            while heads < len(self.incoming) and self.incoming[heads].arrival_s <= t:
                r = self.incoming[heads]
                queue.append((self.class_model.seconds_of(r), r))
                heads += 1
                new_arr += 1
            # admit into slots
            capacity_slots = replicas * cfg.replica.max_slots
            while queue and len(inflight) < capacity_slots:
                work, r = queue.pop(0)
                inflight.append([work, r])
            # serve: processor sharing of replica-seconds across in-flight
            finished: list[ServeRequest] = []
            if inflight:
                capacity = replicas * cfg.step_s
                demand = sum(item[0] for item in inflight)
                busy = min(1.0, demand / capacity)
                share = capacity / len(inflight)
                nxt = []
                for item in inflight:
                    item[0] -= share
                    if item[0] <= 0.0:
                        req = item[1]
                        req.done_s = t + cfg.step_s
                        done.append(req)
                        finished.append(req)
                    else:
                        nxt.append(item)
                inflight = nxt
            else:
                busy = 0.0
            if finished:
                # signals indexed by ARRIVAL time (§V-B post-time indexing)
                arr = np.array([req.arrival_s for req in finished])
                bus.record(cfg.signal_channel,
                           arr, np.array([req.score for req in finished]))
                extra_channels: dict[str, list[tuple[float, float]]] = {}
                for req in finished:
                    for name, val in req.signals.items():
                        extra_channels.setdefault(name, []).append((req.arrival_s, val))
                for name, pairs in extra_channels.items():
                    ts, vs = zip(*pairs)
                    bus.record(name, np.array(ts), np.array(vs))
            replica_seconds += replicas * cfg.step_s
            hist_replicas.append(replicas)

            ctrl.note_step(busy, new_arr)
            ctrl.maybe_adapt(time=t, n_in_system=len(queue) + len(inflight))

            t += cfg.step_s
            if t > horizon and not queue and not inflight and heads >= len(self.incoming):
                break
            if t > horizon + 48 * 3600:
                raise RuntimeError("cluster failed to drain")

        lat = np.array([r.done_s - r.arrival_s for r in done])
        return RunReport(
            backend="elastic",
            workload=f"{len(self.incoming)} requests",
            policy=self.policy.describe(),
            sla_s=cfg.sla_s,
            latencies=lat,
            unit_seconds=replica_seconds,
            units_t=np.asarray(hist_replicas, dtype=np.int64),
            n_decisions_up=ctrl.n_up,
            n_decisions_down=ctrl.n_down,
            unit_name="replica",
            decisions=ctrl.decision_log,
            extra={"chip_hours": replica_seconds * cfg.replica.chips / 3600.0},
        )


__all__ = ["ClusterConfig", "ElasticCluster", "ReplicaSpec", "ServeRequest"]
