"""Elastic LLM-serving cluster driven by the paper's auto-scaling policies.

This is the paper's resource-management insight transplanted to TPU serving:

* unit of elasticity = a model REPLICA (a DP slice of the pod) -- TPU meshes
  are torus-wired, so capacity moves in whole replicas, not single chips;
* per-request service demand comes from a-priori request CLASSES
  (prefill_len, decode_len buckets) priced by the roofline step-times of the
  compiled dry-run (the LLM analogue of the paper's per-class Weibulls);
* the `load` policy estimates the drain time of everything in the system from
  a quantile of the class mixture, exactly as in the paper;
* the `appdata` policy watches a signal computed from the application's own
  OUTPUT stream (e.g. windowed mean score of generated answers: a burst of
  "breaking-news-shaped" queries shifts the output distribution minutes before
  the request-rate peak) and pre-provisions replicas;
* provisioning delay = checkpoint restore + re-mesh + recompile, and scale-in
  releases one replica at a time (Table III semantics retained).

The cluster itself is a discrete-time simulation (1 s steps) whose per-replica
throughput is derived from the dry-run roofline numbers, so policy behaviour
is faithful to what the real fleet would do; the *mechanism* (mesh rebuild +
parameter resharding) is real JAX, exercised by `remesh.py` + tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.autoscaler.base import Decision, Observation, Policy


@dataclass(frozen=True)
class ReplicaSpec:
    """Capacity model of one serving replica, priced from the dry-run."""

    chips: int = 16
    prefill_tokens_per_s: float = 250_000.0   # roofline-derived
    decode_tokens_per_s: float = 20_000.0     # batched decode, all slots
    max_slots: int = 64


@dataclass
class ServeRequest:
    rid: int
    arrival_s: float
    prefill_len: int
    decode_len: int
    score: float = 0.5            # application-output signal carried by the reply
    done_s: float | None = None

    def work_prefill(self) -> float:
        return float(self.prefill_len)

    def work_decode(self) -> float:
        return float(self.decode_len)


@dataclass(frozen=True)
class ClusterConfig:
    replica: ReplicaSpec = ReplicaSpec()
    sla_s: float = 30.0                      # request completion SLA
    adapt_period_s: float = 15.0
    provision_delay_s: float = 45.0          # restore + remesh + warmup
    starting_replicas: int = 1
    max_replicas: int = 64
    app_window_s: float = 60.0
    step_s: float = 1.0


class _ClassModel:
    """A-priori (prefill+decode cost) distribution over request classes --
    the `load` policy's quantile service model."""

    def __init__(self, spec: ReplicaSpec):
        self.spec = spec
        self._samples: list[float] = []

    def observe(self, req: ServeRequest):
        self._samples.append(self.seconds_of(req))
        if len(self._samples) > 50_000:
            del self._samples[: len(self._samples) // 2]

    def seconds_of(self, req: ServeRequest) -> float:
        s = self.spec
        return req.work_prefill() / s.prefill_tokens_per_s \
            + req.work_decode() / (s.decode_tokens_per_s / s.max_slots)

    def quantile_seconds(self, q: float) -> float:
        if not self._samples:
            return 1.0
        return float(np.quantile(np.asarray(self._samples), q))

    def mean_seconds(self) -> float:
        if not self._samples:
            return 1.0
        return float(np.mean(self._samples))


class ElasticCluster:
    """Discrete-time elastic serving fleet under a Policy (threshold / load /
    appdata composite from `repro.core.autoscaler`)."""

    def __init__(self, cfg: ClusterConfig, policy: Policy,
                 requests: list[ServeRequest]):
        self.cfg = cfg
        self.policy = policy
        self.incoming = sorted(requests, key=lambda r: r.arrival_s)
        self.class_model = _ClassModel(cfg.replica)
        for r in self.incoming:
            self.class_model.observe(r)   # a-priori knowledge (training data)

    # -- the load policy's expected-drain estimator --------------------------------
    def expected_delay(self, n_in_system: int, replicas: int, q: float) -> float:
        if replicas <= 0:
            return math.inf
        per = self.class_model.quantile_seconds(q)
        return n_in_system * per / replicas

    def run(self) -> dict:
        cfg = self.cfg
        self.policy.reset()
        t = 0.0
        heads = 0
        replicas = cfg.starting_replicas
        pending: list[tuple[float, int]] = []
        queue: list[ServeRequest] = []
        # work accounting: each replica serves work at 1 replica-second/second
        inflight: list[list] = []     # [remaining_work_s, req]
        done: list[ServeRequest] = []
        replica_seconds = 0.0
        hist_replicas = []
        win_busy: list[float] = []
        win_arr = 0
        score_bins_sum: dict[int, float] = {}
        score_bins_cnt: dict[int, int] = {}
        n_up = n_down = 0

        horizon = self.incoming[-1].arrival_s + 1.0 if self.incoming else 1.0
        while True:
            # provisioning
            ready = [p for p in pending if p[0] <= t]
            if ready:
                replicas = min(replicas + sum(c for _, c in ready), cfg.max_replicas)
                pending = [p for p in pending if p[0] > t]
            # arrivals
            new_arr = 0
            while heads < len(self.incoming) and self.incoming[heads].arrival_s <= t:
                r = self.incoming[heads]
                queue.append(r)
                inflightable = self.class_model.seconds_of(r)
                r._work = inflightable            # type: ignore[attr-defined]
                heads += 1
                new_arr += 1
            win_arr += new_arr
            # admit into slots
            capacity_slots = replicas * cfg.replica.max_slots
            while queue and len(inflight) < capacity_slots:
                r = queue.pop(0)
                inflight.append([r._work, r])     # type: ignore[attr-defined]
            # serve: processor sharing of replica-seconds across in-flight
            if inflight:
                capacity = replicas * cfg.step_s
                demand = sum(item[0] for item in inflight)
                busy = min(1.0, demand / capacity)
                share = capacity / len(inflight)
                nxt = []
                for item in inflight:
                    item[0] -= share
                    if item[0] <= 0.0:
                        req = item[1]
                        req.done_s = t + cfg.step_s
                        done.append(req)
                        b = int(req.arrival_s)
                        score_bins_sum[b] = score_bins_sum.get(b, 0.0) + req.score
                        score_bins_cnt[b] = score_bins_cnt.get(b, 0) + 1
                    else:
                        nxt.append(item)
                inflight = nxt
            else:
                busy = 0.0
            win_busy.append(busy)
            replica_seconds += replicas * cfg.step_s
            hist_replicas.append(replicas)

            # adapt
            if int(t + cfg.step_s) % int(cfg.adapt_period_s) == 0:
                w = int(cfg.app_window_s)
                now_b = int(t)
                def wmean(lo, hi):
                    ssum = sum(score_bins_sum.get(b, 0.0) for b in range(lo, hi))
                    cnt = sum(score_bins_cnt.get(b, 0) for b in range(lo, hi))
                    return (ssum / cnt if cnt else 0.0), cnt
                m1, c1 = wmean(now_b - w, now_b)
                m0, _ = wmean(now_b - 2 * w, now_b - w)
                obs = Observation(
                    time=t,
                    n_units=replicas,
                    n_pending=sum(c for _, c in pending),
                    utilization=float(np.mean(win_busy)) if win_busy else 0.0,
                    n_in_system=len(queue) + len(inflight),
                    input_rate=win_arr / cfg.adapt_period_s,
                    app_window_mean=m1,
                    app_prev_window_mean=m0,
                    app_window_count=c1,
                )
                d = self.policy.decide(obs)
                if d.delta > 0:
                    n_up += 1
                    pending.append((t + cfg.provision_delay_s, int(d.delta)))
                elif d.delta < 0 and replicas > 1:
                    n_down += 1
                    replicas -= 1
                win_busy, win_arr = [], 0

            t += cfg.step_s
            if t > horizon and not queue and not inflight and heads >= len(self.incoming):
                break
            if t > horizon + 48 * 3600:
                raise RuntimeError("cluster failed to drain")

        lat = np.array([r.done_s - r.arrival_s for r in done])
        return {
            "n_done": len(done),
            "violation_rate": float(np.mean(lat > cfg.sla_s)) if lat.size else 0.0,
            "mean_latency_s": float(lat.mean()) if lat.size else 0.0,
            "p99_latency_s": float(np.quantile(lat, 0.99)) if lat.size else 0.0,
            "replica_hours": replica_seconds / 3600.0,
            "chip_hours": replica_seconds * cfg.replica.chips / 3600.0,
            "max_replicas": int(max(hist_replicas) if hist_replicas else 0),
            "n_scale_ups": n_up,
            "n_scale_downs": n_down,
        }


__all__ = ["ClusterConfig", "ElasticCluster", "ReplicaSpec", "ServeRequest"]
