"""Elastic LLM-serving cluster driven by the paper's auto-scaling policies.

This is the paper's resource-management insight transplanted to TPU serving:

* unit of elasticity = a model REPLICA (a DP slice of the pod) -- TPU meshes
  are torus-wired, so capacity moves in whole replicas, not single chips;
* per-request service demand comes from a-priori request CLASSES
  (prefill_len, decode_len buckets) priced by the roofline step-times of the
  compiled dry-run (the LLM analogue of the paper's per-class Weibulls);
* the `load` policy estimates the drain time of everything in the system from
  a quantile of the class mixture, exactly as in the paper;
* the `appdata` policy watches a signal computed from the application's own
  OUTPUT stream (e.g. windowed mean score of generated answers: a burst of
  "breaking-news-shaped" queries shifts the output distribution minutes before
  the request-rate peak) and pre-provisions replicas;
* provisioning delay = checkpoint restore + re-mesh + recompile, and scale-in
  releases one replica at a time (Table III semantics retained).

The cluster itself is a discrete-time simulation (1 s steps) whose per-replica
throughput is derived from the dry-run roofline numbers, so policy behaviour
is faithful to what the real fleet would do; the *mechanism* (mesh rebuild +
parameter resharding) is real JAX, exercised by `remesh.py` + tests.

Table III mechanics and window accounting are delegated to the shared
:class:`repro.core.scaling.ScalingController`/:class:`SignalBus` control
plane, and the service process itself is the shared exact water-filling core
(:class:`repro.core.scaling.ServiceProcess`) -- the same Algorithm 1
machinery the tweet simulator runs on, so policy comparisons across backends
sit on an identical service model.  Admission is slot-capped from an
index-head queue (O(1) per admit, 100k+-request streams are cheap) and the
reported busy fraction is derived from work actually *consumed*
(``min(demand, capacity) / capacity``), not from pre-step demand.  The
primary signal channel is ``output_score`` (windowed mean score of generated
answers); requests may carry additional named channels in ``signals`` (e.g. a
refusal-rate or topic-shift stream), all observable by policies via
``Observation.signal(channel)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from repro.core.autoscaler.base import Policy

if TYPE_CHECKING:
    from repro.core.convergence.converger import ConvergerConfig
    from repro.core.convergence.faults import FaultSpec
    from repro.core.convergence.groups import ScalingGroup
from repro.core.scaling import (
    ControllerConfig,
    RunReport,
    ScalingController,
    ServiceProcess,
    SignalBus,
    Sla,
    UnitPool,
)


@dataclass(frozen=True)
class ReplicaSpec:
    """Capacity model of one serving replica, priced from the dry-run."""

    chips: int = 16
    prefill_tokens_per_s: float = 250_000.0   # roofline-derived
    decode_tokens_per_s: float = 20_000.0     # batched decode, all slots
    max_slots: int = 64


@dataclass
class ServeRequest:
    rid: int
    arrival_s: float
    prefill_len: int
    decode_len: int
    score: float = 0.5            # application-output signal carried by the reply
    done_s: float | None = None
    signals: dict[str, float] = field(default_factory=dict)   # extra named channels
    request_class: str = "standard"   # SLA class (per-class deadlines via Sla)

    def work_prefill(self) -> float:
        return float(self.prefill_len)

    def work_decode(self) -> float:
        return float(self.decode_len)


@dataclass(frozen=True)
class ClusterConfig:
    replica: ReplicaSpec = ReplicaSpec()
    sla_s: float = 30.0                      # request completion SLA
    adapt_period_s: float = 15.0
    provision_delay_s: float = 45.0          # restore + remesh + warmup
    starting_replicas: int = 1
    max_replicas: int = 64
    app_window_s: float = 60.0
    step_s: float = 1.0
    signal_channel: str = "output_score"     # primary channel (legacy app_* tier)
    pools: tuple[UnitPool, ...] | None = None   # typed replica pools (None: one
                                                # on-demand pool from the knobs above)
    sla: Sla | None = None                   # per-class deadlines (None: flat sla_s)
    convergence: bool = False                # desired-state reconciliation
                                             # (fault-free: bit-for-bit identical)
    converge: "ConvergerConfig | None" = None    # converger timeout/retry knobs
    faults: "tuple[FaultSpec, ...] | None" = None   # seeded fault injection or
                                                    # a duck-typed injector
    group: "ScalingGroup | None" = None      # scaling-group pools + scheduled
                                             # and webhook desired-state floors
    audit_path: str | None = None            # mirror the audit log to JSONL


class _ClassModel:
    """A-priori (prefill+decode cost) distribution over request classes --
    the `load` policy's quantile service model.

    The sorted sample array is cached between adapt ticks (quantiles are read
    every tick, samples only change on observe), so `quantile_seconds` is an
    O(1) interpolation instead of an O(n log n) re-sort of up to 50k samples.
    """

    def __init__(self, spec: ReplicaSpec):
        self.spec = spec
        self._samples: list[float] = []
        self._sorted: np.ndarray | None = None   # invalidated on observe

    def _trim(self):
        # a bulk observe can overshoot by more than 2x: keep halving (drop
        # oldest first) until the retained set is back under the cap
        while len(self._samples) > 50_000:
            del self._samples[: len(self._samples) // 2]
        self._sorted = None

    def observe(self, req: ServeRequest):
        self._samples.append(self.seconds_of(req))
        self._trim()

    def observe_seconds(self, seconds: np.ndarray):
        """Vectorized observe of pre-priced service times."""
        self._samples.extend(np.asarray(seconds, dtype=np.float64).tolist())
        self._trim()

    def seconds_of(self, req: ServeRequest) -> float:
        s = self.spec
        return req.work_prefill() / s.prefill_tokens_per_s \
            + req.work_decode() / (s.decode_tokens_per_s / s.max_slots)

    def price(self, prefill_len: np.ndarray, decode_len: np.ndarray) -> np.ndarray:
        """Vectorized `seconds_of` over per-request length arrays."""
        s = self.spec
        return (np.asarray(prefill_len, np.float64) / s.prefill_tokens_per_s
                + np.asarray(decode_len, np.float64)
                / (s.decode_tokens_per_s / s.max_slots))

    def quantile_seconds(self, q: float) -> float:
        if not self._samples:
            return 1.0
        if self._sorted is None:
            self._sorted = np.sort(np.asarray(self._samples, dtype=np.float64))
        s = self._sorted
        # linear interpolation at rank q * (n - 1): matches np.quantile's
        # default method on the same samples
        pos = q * (s.size - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, s.size - 1)
        return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))

    def mean_seconds(self) -> float:
        if not self._samples:
            return 1.0
        return float(np.mean(self._samples))


@dataclass
class ElasticResult(RunReport):
    """Elastic RunReport + the per-step service-process series the
    conservation tests and utilization figures need (not part of the summary
    row schema)."""

    util_t: np.ndarray = field(                      # consumed/capacity per step
        default_factory=lambda: np.empty(0, np.float32))
    demand_t: np.ndarray = field(                    # pre-step demand, replica-s
        default_factory=lambda: np.empty(0, np.float64))
    consumed_t: np.ndarray = field(                  # work consumed, replica-s
        default_factory=lambda: np.empty(0, np.float64))
    capacity_t: np.ndarray = field(                  # usable capacity, replica-s
        default_factory=lambda: np.empty(0, np.float64))
    in_system_t: np.ndarray = field(                 # queue + in-flight per step
        default_factory=lambda: np.empty(0, np.int64))


class ElasticCluster:
    """Discrete-time elastic serving fleet under a Policy (threshold / load /
    appdata composite from `repro.core.autoscaler`)."""

    def __init__(self, cfg: ClusterConfig, policy: Policy,
                 requests: list[ServeRequest], *, on_step=None):
        self.cfg = cfg
        self.policy = policy
        # chaos-drill hook: called as on_step(cluster, t) right after capacity
        # convergence each step (kill timing, mid-incident webhook fires)
        self.on_step = on_step
        self.incoming = sorted(requests, key=lambda r: r.arrival_s)
        n = len(self.incoming)
        # struct-of-arrays view of the request stream (vectorized service core)
        self._arrival = np.array([r.arrival_s for r in self.incoming],
                                 dtype=np.float64)
        self._score = np.array([r.score for r in self.incoming],
                               dtype=np.float64)
        self._cls = np.array([r.request_class for r in self.incoming])
        self.class_model = _ClassModel(cfg.replica)
        self._work = self.class_model.price(
            np.array([r.prefill_len for r in self.incoming], dtype=np.float64),
            np.array([r.decode_len for r in self.incoming], dtype=np.float64))
        # extra named channels as dense columns (NaN where a request doesn't
        # carry the channel)
        self._extra: dict[str, np.ndarray] = {}
        for i, r in enumerate(self.incoming):
            for name, val in r.signals.items():
                self._extra.setdefault(name, np.full(n, np.nan))[i] = val
        self.class_model.observe_seconds(self._work)   # a-priori knowledge

    # -- the load policy's expected-drain estimator --------------------------------
    def expected_delay(self, n_in_system: int, replicas: int, q: float) -> float:
        if replicas <= 0:
            return math.inf
        per = self.class_model.quantile_seconds(q)
        return n_in_system * per / replicas

    def run(self) -> RunReport:
        cfg = self.cfg
        bus = SignalBus((cfg.signal_channel,), bin_s=cfg.step_s)
        ctrl = ScalingController(
            self.policy,
            ControllerConfig(
                adapt_period_s=cfg.adapt_period_s,
                provision_delay_s=cfg.provision_delay_s,
                max_units=cfg.max_replicas,
                step_s=cfg.step_s,
                app_window_s=cfg.app_window_s,
                signal_channel=cfg.signal_channel,
                pools=cfg.pools,
                convergence=cfg.convergence,
                converge=cfg.converge,
                faults=cfg.faults,
                group=cfg.group,
                audit_path=cfg.audit_path,
            ),
            bus,
            starting_units=cfg.starting_replicas,
        )
        self.controller = ctrl      # post-run inspection (audit log, meters)
        n = len(self.incoming)
        arrival, work, score = self._arrival, self._work, self._score

        # shared water-filling service core; the sorted in-flight arrays carry
        # the request index plus (arrival, score) payload columns
        proc = ServiceProcess({"idx": np.int64,
                               "arrival": np.float64,
                               "score": np.float64})
        t = 0.0
        n_arrived = 0     # requests with arrival_s <= t (entered the system)
        q_head = 0        # index-head queue: next request not yet in a slot
        done_t = np.zeros(n, dtype=np.float64)
        replica_seconds = 0.0
        hist_replicas: list[int] = []
        util_hist: list[float] = []
        demand_hist: list[float] = []
        consumed_hist: list[float] = []
        capacity_hist: list[float] = []
        insys_hist: list[int] = []

        horizon = float(arrival[-1]) + 1.0 if n else 1.0
        while True:
            replicas = ctrl.on_step_start(t)
            if self.on_step is not None:
                self.on_step(self, t)
                replicas = ctrl.plan.total_live   # the hook may move capacity
            # arrivals (arrival-sorted, so the queue is the contiguous index
            # range [q_head, n_arrived))
            hi = int(np.searchsorted(arrival, t, side="right"))
            new_arr = hi - n_arrived
            n_arrived = hi
            # slot-capped admission from the queue head, FIFO
            capacity_slots = replicas * cfg.replica.max_slots
            k_adm = min(max(capacity_slots - len(proc), 0), n_arrived - q_head)
            instant = None
            if k_adm > 0:
                idx = np.arange(q_head, q_head + k_adm, dtype=np.int64)
                instant = proc.admit(work[idx], idx=idx,
                                     arrival=arrival[idx], score=score[idx])
                q_head += k_adm
            # serve: exact water-filling of replica-seconds across in-flight
            capacity = replicas * cfg.step_s
            sr = proc.step(capacity)
            fin_idx = sr.finished["idx"]
            fin_arr = sr.finished["arrival"]
            fin_score = sr.finished["score"]
            if instant is not None:       # zero-work requests finish instantly
                fin_idx = np.concatenate([instant["idx"], fin_idx])
                fin_arr = np.concatenate([instant["arrival"], fin_arr])
                fin_score = np.concatenate([instant["score"], fin_score])
            if fin_idx.size:
                done_t[fin_idx] = t + cfg.step_s
                # signals indexed by ARRIVAL time (§V-B post-time indexing)
                bus.record(cfg.signal_channel, fin_arr, fin_score)
                for name, col in self._extra.items():
                    vals = col[fin_idx]
                    carried = ~np.isnan(vals)
                    if carried.any():
                        bus.record(name, fin_arr[carried], vals[carried])
            replica_seconds += replicas * cfg.step_s
            hist_replicas.append(replicas)
            util_hist.append(sr.busy)
            demand_hist.append(sr.demand)
            consumed_hist.append(sr.consumed)
            capacity_hist.append(capacity)
            insys_hist.append((n_arrived - q_head) + len(proc))

            ctrl.note_step(sr.busy, new_arr)
            ctrl.maybe_adapt(time=t, n_in_system=insys_hist[-1])

            t += cfg.step_s
            if t > horizon and len(proc) == 0 and q_head >= n:
                break
            if t > horizon + 48 * 3600:
                raise RuntimeError("cluster failed to drain")

        if ctrl.audit is not None:       # terminal marker: the run completed
            ctrl.audit.seal(t)
            ctrl.audit.close()
        for i, r in enumerate(self.incoming):     # keep the request-object API
            r.done_s = float(done_t[i]) if done_t[i] > 0.0 else None
        done_mask = done_t > 0.0
        lat = (done_t - arrival)[done_mask]
        return ElasticResult(
            backend="elastic",
            workload=f"{n} requests",
            policy=self.policy.describe(),
            sla_s=cfg.sla_s,
            latencies=lat,
            unit_seconds=replica_seconds,
            units_t=np.asarray(hist_replicas, dtype=np.int64),
            n_decisions_up=ctrl.n_up,
            n_decisions_down=ctrl.n_down,
            unit_name="replica",
            decisions=ctrl.decision_log,
            sla=cfg.sla,
            classes=self._cls[done_mask],
            extra={"chip_hours": replica_seconds * cfg.replica.chips / 3600.0},
            **ctrl.plan.report_kwargs(),
            util_t=np.asarray(util_hist, dtype=np.float32),
            demand_t=np.asarray(demand_hist, dtype=np.float64),
            consumed_t=np.asarray(consumed_hist, dtype=np.float64),
            capacity_t=np.asarray(capacity_hist, dtype=np.float64),
            in_system_t=np.asarray(insys_hist, dtype=np.int64),
        )


__all__ = ["ClusterConfig", "ElasticCluster", "ElasticResult", "ReplicaSpec",
           "ServeRequest"]
