"""The desired-state model: what the fleet *should* look like.

The paper's controllers emit imperative deltas ("add 1 CPU") and assume every
action succeeds.  The convergence plane instead keeps a :class:`DesiredGroup`
-- per-pool target counts with floors and ceilings -- and continuously
reconciles observed capacity toward it, so capacity lost to revocation,
unit-loss faults, or stuck builds is healed without the policy noticing.

:func:`derive_desired` is the thin adapter that lets every existing policy
work unchanged: it folds a policy ``Decision``'s per-pool deltas into the
previous desired state using exactly the imperative controller's semantics
(ceiling-clamped upscales; net downscale capped per tick and distributed
expensive-first, cancellable-pending before live-above-floor).  With no
faults injected the derived target always equals what the imperative path
would have actuated, which is what keeps the golden parity tests bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

from repro.core.scaling.capacity import PoolStats


@dataclass(frozen=True)
class PoolTarget:
    """Desired unit count for one pool, with its actuation bounds."""

    target: int
    min_units: int = 0
    max_units: int = 4096

    def __post_init__(self):
        if self.target < 0:
            raise ValueError(f"target must be >= 0, got {self.target}")


@dataclass(frozen=True)
class DesiredGroup:
    """Per-pool targets the converger reconciles the fleet toward.

    ``generation`` is the desired-state epoch: the converger bumps it on
    every intent change (policy tick, webhook floor, schedule edge) and
    stamps it onto the steps it plans, so retry/backoff state belonging to
    a superseded intent can be discarded instead of resumed, and the audit
    log can prove no step ever contradicted the latest desired state.
    """

    targets: Mapping[str, PoolTarget]
    generation: int = 0

    @property
    def total(self) -> int:
        return sum(t.target for t in self.targets.values())

    def target_of(self, name: str) -> int:
        t = self.targets.get(name)
        return t.target if t is not None else 0

    def with_target(self, name: str, target: int) -> "DesiredGroup":
        cur = self.targets[name]
        new = dict(self.targets)
        new[name] = PoolTarget(target=int(target), min_units=cur.min_units,
                               max_units=cur.max_units)
        return DesiredGroup(new, generation=self.generation)


def observed_group(stats: Mapping[str, PoolStats]) -> DesiredGroup:
    """Desired state that ratifies what is currently observed (live+pending)."""
    return DesiredGroup({
        name: PoolTarget(target=ps.units + ps.pending,
                         min_units=ps.min_units, max_units=ps.max_units)
        for name, ps in stats.items()
    })


def derive_desired(prev: DesiredGroup | None,
                   stats: Mapping[str, PoolStats],
                   deltas: Mapping[str, int],
                   *, downscale_cap: int = 1) -> DesiredGroup:
    """Fold a policy decision's per-pool ``deltas`` into the desired state.

    Mirrors ``ScalingController.maybe_adapt``'s imperative actuation exactly:

    * positive per-pool deltas raise that pool's target, clamped to its
      ceiling (the request-time headroom clamp);
    * the net negative delta is capped at ``downscale_cap`` per tick and
      distributed most-expensive-first, reducing targets by what a release
      could actually reclaim right now (observed cancellable pending first,
      then observed live above the floor).

    ``prev=None`` starts from the observed state, so a pool the policy never
    touches keeps whatever it started with.
    """
    for name in deltas:
        if name not in stats:
            raise ValueError(f"unknown pool {name!r}; observed pools: "
                             f"{list(stats)}")
    base = prev if prev is not None else observed_group(stats)
    targets = {
        name: (base.target_of(name) if name in base.targets
               else ps.units + ps.pending)
        for name, ps in stats.items()
    }
    for name, d in deltas.items():
        if d > 0:
            targets[name] = min(targets[name] + d, stats[name].max_units)
    down_req = -sum(d for d in deltas.values() if d < 0)
    if down_req > 0:
        want = min(downscale_cap, down_req)
        index = {name: i for i, name in enumerate(stats)}
        order = sorted(stats.items(),
                       key=lambda kv: (kv[1].cost_rate, index[kv[0]]),
                       reverse=True)
        for name, ps in order:                 # pass 1: cancellable pending
            take = min(want, ps.pending, targets[name])
            targets[name] -= take
            want -= take
        for name, ps in order:                 # pass 2: live above floor
            take = min(want, max(ps.units - ps.min_units, 0), targets[name])
            targets[name] -= take
            want -= take
    return DesiredGroup({
        name: PoolTarget(target=targets[name], min_units=ps.min_units,
                         max_units=ps.max_units)
        for name, ps in stats.items()
    })


__all__ = ["DesiredGroup", "PoolTarget", "derive_desired", "observed_group"]
