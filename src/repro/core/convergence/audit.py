"""Append-only structured audit log for the convergence plane.

Every state transition the converger either caused (steps) or witnessed
(landings, revocations, injected faults) becomes one flat JSON record, so an
operator -- or a test -- can reconstruct *why* the fleet looks the way it
does.  Record kinds:

* ``init``     -- starting live units per pool
* ``desired``  -- a new desired state was set (per-pool targets + reason)
* ``events``   -- witnessed meter deltas since the last converge call:
  ``landed`` / ``revoked`` / ``lost`` / ``overflow_landed`` per pool
* ``plan``     -- the steps the planner emitted this tick
* ``step``     -- one executed step and its outcome (kind, pool, asked,
  applied, plus ``queued`` for replacements)
* ``backoff`` / ``gave_up`` -- retry bookkeeping on stuck pools
* ``decision`` -- the policy decision that produced a desired change

:func:`replay` folds the records back into per-pool ``{live, pending}``
state; tests and the fault benchmark assert it matches the actual final
``CapacityPlan`` state, which proves the log is a complete account of every
capacity transition.
"""
from __future__ import annotations

import json
import zlib
from typing import IO, Iterable, Mapping


class AuditIntegrityError(ValueError):
    """The on-disk audit log is corrupt, truncated, or unsealed."""


class AuditLog:
    """In-memory audit trail, optionally mirrored to an append-only JSONL file.

    :meth:`seal` appends a terminal record carrying the payload record count
    and a CRC over every preceding serialized line -- the JSONL analogue of
    the checkpoint store's ``.ok`` marker: a log whose last record is not a
    matching seal was cut off (or edited) mid-incident, and
    ``load(path, verify=True)`` reports exactly where.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self._records: list[dict] = []
        self._crc = 0
        self._fh: IO[str] | None = open(path, "a") if path else None

    @property
    def records(self) -> list[dict]:
        return self._records

    def append(self, time: float, kind: str, **payload) -> dict:
        rec = {"t": float(time), "kind": str(kind), **payload}
        line = json.dumps(rec, sort_keys=True)
        self._records.append(rec)
        self._crc = zlib.crc32(line.encode(), self._crc)
        if self._fh is not None:
            self._fh.write(line + "\n")
            self._fh.flush()
        return rec

    def seal(self, time: float) -> dict:
        """Terminal marker: record count + CRC of everything before it.
        Must be the last record -- appending after a seal invalidates it."""
        n, crc = len(self._records), self._crc
        return self.append(time, "seal", n=n, crc=crc)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    @staticmethod
    def load(path: str, verify: bool = False) -> list[dict]:
        """Read a JSONL audit log back.  With ``verify=True`` the log must
        end in a valid :meth:`seal` record whose count and CRC match the
        preceding lines; corrupt, truncated, or unsealed logs raise
        :class:`AuditIntegrityError` naming the offending line."""
        records: list[dict] = []
        crc = 0
        with open(path) as fh:
            for lineno, line in enumerate(fh, start=1):
                if not line.strip():
                    continue
                try:
                    records.append(json.loads(line))
                except json.JSONDecodeError as e:
                    if verify:
                        raise AuditIntegrityError(
                            f"{path}:{lineno}: corrupt record "
                            f"({e.msg}); the tail of this log cannot be "
                            f"trusted") from e
                    raise
                if records[-1].get("kind") != "seal":
                    crc = zlib.crc32(line.rstrip("\n").encode(), crc)
        if not verify:
            return records
        if not records or records[-1].get("kind") != "seal":
            raise AuditIntegrityError(
                f"{path}: no terminal seal record -- the log was truncated "
                f"or the run never completed (last kind: "
                f"{records[-1]['kind'] if records else 'none'!r})")
        seal = records[-1]
        n = len(records) - 1
        if seal.get("n") != n:
            raise AuditIntegrityError(
                f"{path}: seal claims {seal.get('n')} records but "
                f"{n} precede it -- lines were dropped or injected")
        if any(r.get("kind") == "seal" for r in records[:-1]):
            raise AuditIntegrityError(
                f"{path}: records were appended after a seal")
        if seal.get("crc") != crc:
            raise AuditIntegrityError(
                f"{path}: payload CRC mismatch (seal {seal.get('crc')}, "
                f"recomputed {crc}) -- a record was altered in place")
        return records


def replay(records: Iterable[Mapping]) -> dict[str, dict[str, int]]:
    """Fold audit records into final per-pool ``{"live": n, "pending": n}``.

    Only capacity-bearing kinds move state (``init`` / ``events`` / ``step``);
    everything else is narrative.  The result must equal the plan's actual
    final state -- see ``tests/test_convergence.py`` and the fault benchmark.
    """
    state: dict[str, dict[str, int]] = {}

    def pool(name: str) -> dict[str, int]:
        return state.setdefault(name, {"live": 0, "pending": 0})

    for rec in records:
        kind = rec["kind"]
        if kind == "init":
            for name, live in rec["pools"].items():
                state[name] = {"live": int(live), "pending": 0}
        elif kind == "events":
            p = pool(rec["pool"])
            landed = int(rec.get("landed", 0))
            p["live"] += landed - int(rec.get("revoked", 0)) - int(
                rec.get("lost", 0))
            p["pending"] -= landed + int(rec.get("overflow_landed", 0))
        elif kind == "step":
            p = pool(rec["pool"])
            step = rec["step"]
            applied = int(rec.get("applied", 0))
            if step == "LaunchUnit":
                p["pending"] += applied
            elif step == "CancelPending":
                p["pending"] -= applied
            elif step == "DrainUnit":
                p["live"] -= applied
            elif step == "ReplaceUnhealthy":
                p["live"] -= applied
                p["pending"] += int(rec.get("queued", 0))
    return state


def verify_plan_replay(records: Iterable[Mapping]) -> tuple[int, list[dict]]:
    """Re-run the pure planner over every ``plan`` record's logged inputs and
    compare against the steps the converger actually recorded.

    Each ``plan`` record carries the full planner inputs (observed stats,
    overdue counts, blocked sets) and the generation of the desired state it
    served; ``desired`` records carry targets + bounds + generation.  Because
    ``plan_steps`` is pure, replaying those inputs must reproduce the logged
    steps byte-for-byte -- and every plan's generation must equal the latest
    desired generation at that point (a stale-generation plan is a converger
    acting on superseded intent).

    Returns ``(n_plans_checked, mismatches)``; an empty mismatch list is the
    proof.  Each mismatch dict names the record index, the divergence kind
    (``steps`` or ``generation``), and the logged-vs-replayed values.
    """
    from repro.core.scaling.capacity import PoolStats

    from .desired import DesiredGroup, PoolTarget
    from .planner import plan_steps, step_record

    desired: DesiredGroup | None = None
    checked = 0
    mismatches: list[dict] = []
    for i, rec in enumerate(records):
        kind = rec.get("kind")
        if kind == "desired":
            bounds = rec.get("bounds", {})
            desired = DesiredGroup(
                {n: PoolTarget(target=int(t),
                               min_units=int(bounds.get(n, (0, 4096))[0]),
                               max_units=int(bounds.get(n, (0, 4096))[1]))
                 for n, t in rec["targets"].items()},
                generation=int(rec.get("gen", 0)))
        elif kind == "plan":
            inputs = rec.get("inputs")
            if inputs is None or desired is None:
                continue    # pre-generation log: nothing to replay against
            if int(rec.get("gen", 0)) != desired.generation:
                mismatches.append({
                    "index": i, "kind": "generation",
                    "logged": rec.get("gen"), "latest": desired.generation})
            stats = {n: PoolStats(units=int(s["units"]),
                                  pending=int(s["pending"]),
                                  cost_rate=0.0,
                                  min_units=int(s["min_units"]),
                                  unhealthy=int(s["unhealthy"]))
                     for n, s in inputs["stats"].items()}
            steps = plan_steps(
                desired, stats,
                overdue={n: int(v) for n, v in inputs["overdue"].items()},
                launch_blocked=set(inputs["launch_blocked"]),
                replace_blocked=set(inputs["replace_blocked"]))
            replayed = [step_record(s) for s in steps]
            logged = [{k: v for k, v in s.items()} for s in rec["steps"]]
            checked += 1
            if replayed != logged:
                mismatches.append({"index": i, "kind": "steps",
                                   "logged": logged, "replayed": replayed})
    return checked, mismatches


__all__ = ["AuditIntegrityError", "AuditLog", "replay", "verify_plan_replay"]
