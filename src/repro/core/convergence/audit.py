"""Append-only structured audit log for the convergence plane.

Every state transition the converger either caused (steps) or witnessed
(landings, revocations, injected faults) becomes one flat JSON record, so an
operator -- or a test -- can reconstruct *why* the fleet looks the way it
does.  Record kinds:

* ``init``     -- starting live units per pool
* ``desired``  -- a new desired state was set (per-pool targets + reason)
* ``events``   -- witnessed meter deltas since the last converge call:
  ``landed`` / ``revoked`` / ``lost`` / ``overflow_landed`` per pool
* ``plan``     -- the steps the planner emitted this tick
* ``step``     -- one executed step and its outcome (kind, pool, asked,
  applied, plus ``queued`` for replacements)
* ``backoff`` / ``gave_up`` -- retry bookkeeping on stuck pools
* ``decision`` -- the policy decision that produced a desired change

:func:`replay` folds the records back into per-pool ``{live, pending}``
state; tests and the fault benchmark assert it matches the actual final
``CapacityPlan`` state, which proves the log is a complete account of every
capacity transition.
"""
from __future__ import annotations

import json
from typing import IO, Iterable, Mapping


class AuditLog:
    """In-memory audit trail, optionally mirrored to an append-only JSONL file."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._records: list[dict] = []
        self._fh: IO[str] | None = open(path, "a") if path else None

    @property
    def records(self) -> list[dict]:
        return self._records

    def append(self, time: float, kind: str, **payload) -> dict:
        rec = {"t": float(time), "kind": str(kind), **payload}
        self._records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
            self._fh.flush()
        return rec

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    @staticmethod
    def load(path: str) -> list[dict]:
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]


def replay(records: Iterable[Mapping]) -> dict[str, dict[str, int]]:
    """Fold audit records into final per-pool ``{"live": n, "pending": n}``.

    Only capacity-bearing kinds move state (``init`` / ``events`` / ``step``);
    everything else is narrative.  The result must equal the plan's actual
    final state -- see ``tests/test_convergence.py`` and the fault benchmark.
    """
    state: dict[str, dict[str, int]] = {}

    def pool(name: str) -> dict[str, int]:
        return state.setdefault(name, {"live": 0, "pending": 0})

    for rec in records:
        kind = rec["kind"]
        if kind == "init":
            for name, live in rec["pools"].items():
                state[name] = {"live": int(live), "pending": 0}
        elif kind == "events":
            p = pool(rec["pool"])
            landed = int(rec.get("landed", 0))
            p["live"] += landed - int(rec.get("revoked", 0)) - int(
                rec.get("lost", 0))
            p["pending"] -= landed + int(rec.get("overflow_landed", 0))
        elif kind == "step":
            p = pool(rec["pool"])
            step = rec["step"]
            applied = int(rec.get("applied", 0))
            if step == "LaunchUnit":
                p["pending"] += applied
            elif step == "CancelPending":
                p["pending"] -= applied
            elif step == "DrainUnit":
                p["live"] -= applied
            elif step == "ReplaceUnhealthy":
                p["live"] -= applied
                p["pending"] += int(rec.get("queued", 0))
    return state


__all__ = ["AuditLog", "replay"]
