"""The converger loop: reconcile observed capacity toward the desired state.

Runs once per controller step (not just per adapt tick), so healing starts
the step after a fault is observable.  Each call:

1. audits witnessed meter deltas since the last call (landings, revocations,
   losses) so the audit log stays a complete account;
2. observes ``plan.stats()`` and queries the build-status API for overdue
   builds (pending whose expected landing is more than ``build_timeout_s``
   ago -- the observable symptom of a stuck build);
3. asks the pure planner for steps, withholding launches from pools that are
   in retry backoff or have exhausted their retry budget, and replacements
   from pools inside the flap-damping window;
4. executes the steps against the capacity plane, recording per-step
   outcomes.

Retry discipline: cancelling a stuck build counts as a failed launch
attempt; the relaunch waits ``backoff_base_s * 2**(attempt-1)`` (capped at
``backoff_max_s``).  A landing in the pool resets the attempt counter; after
``max_retries`` failed attempts the pool is parked (audited as ``gave_up``)
until the policy next changes its target.  Partial failures need no special
handling -- an under-applied step is just diff the next call re-plans.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace
from typing import Iterable, Protocol, runtime_checkable

from repro.core.scaling.capacity import CapacityPlan

from .audit import AuditLog
from .desired import DesiredGroup
from .planner import (
    CancelPending, DrainUnit, LaunchUnit, ReplaceUnhealthy, Step, plan_steps,
    step_record,
)


@runtime_checkable
class StepExecutor(Protocol):
    """What a converger actuates steps against.

    The default :class:`PlanExecutor` mutates CapacityPlan counters (the
    virtual capacity model); ``repro.serving.fleet.FleetExecutor`` spawns and
    drains real ServingEngine replicas and keeps the plan's ledger in sync as
    a side effect.  Each method returns the count actually applied (for
    ``replace_unhealthy``: ``(drained, queued)``)."""

    def launch(self, pool: str, count: int, now: float) -> int: ...
    def cancel_pending(self, pool: str, count: int, now: float) -> int: ...
    def drain(self, pool: str, count: int, now: float) -> int: ...
    def replace_unhealthy(self, pool: str, count: int,
                          now: float) -> tuple[int, int]: ...


class PlanExecutor:
    """Default executor: steps mutate the CapacityPlan's virtual counters
    (exactly the pre-fleet behavior, which keeps the golden parity tests)."""

    def __init__(self, plan: CapacityPlan):
        self.plan = plan

    def launch(self, pool: str, count: int, now: float) -> int:
        return self.plan.request(pool, count, now)

    def cancel_pending(self, pool: str, count: int, now: float) -> int:
        return self.plan.cancel_pending(pool, count)

    def drain(self, pool: str, count: int, now: float) -> int:
        return self.plan.drain(pool, count)

    def replace_unhealthy(self, pool: str, count: int,
                          now: float) -> tuple[int, int]:
        return self.plan.replace_unhealthy(pool, count, now)


@dataclass(frozen=True)
class ConvergerConfig:
    """Timeout / retry / backoff knobs for the converger loop."""

    build_timeout_s: float = 30.0    # pending overdue by this much => stuck
    max_retries: int = 5             # failed launch attempts before parking
    backoff_base_s: float = 5.0      # first retry delay; doubles per attempt
    backoff_max_s: float = 120.0
    replace_backoff_s: float = 30.0  # min gap between replacements per pool

    def __post_init__(self):
        if self.build_timeout_s < 0.0:
            raise ValueError(f"build_timeout_s must be >= 0, got "
                             f"{self.build_timeout_s}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.backoff_base_s <= 0.0 or self.backoff_max_s < self.backoff_base_s:
            raise ValueError(f"need 0 < backoff_base_s <= backoff_max_s, got "
                             f"[{self.backoff_base_s}, {self.backoff_max_s}]")

    def backoff_s(self, attempt: int) -> float:
        return min(self.backoff_base_s * 2.0 ** max(attempt - 1, 0),
                   self.backoff_max_s)


@dataclass(frozen=True)
class StepOutcome:
    """One executed step: ``applied`` units actuated of ``step.count`` asked;
    ``queued`` is the replacement count for ReplaceUnhealthy steps."""

    time: float
    step: Step
    applied: int
    queued: int = 0

    @property
    def ok(self) -> bool:
        return self.applied >= self.step.count


class Converger:
    """Executes convergence steps against a :class:`CapacityPlan`."""

    def __init__(self, plan: CapacityPlan, cfg: ConvergerConfig | None = None,
                 audit: AuditLog | None = None,
                 executor: StepExecutor | None = None):
        self.plan = plan
        self.cfg = cfg or ConvergerConfig()
        self.audit = audit
        self.executor: StepExecutor = executor or PlanExecutor(plan)
        self.desired: DesiredGroup | None = None
        self.generation = 0                     # desired-state epoch counter
        self._attempts: dict[str, int] = {}     # failed launch attempts
        self._gate: dict[str, float] = {}       # no launches before this time
        self._gate_gen: dict[str, int] = {}     # epoch each gate was armed in
        self._pool_gen: dict[str, int] = {}     # epoch of last intent change
        self._replace_gate: dict[str, float] = {}
        self._last_meters = plan.meters()

    # -- desired state ----------------------------------------------------------
    def set_desired(self, desired: DesiredGroup, now: float,
                    reason: str = "", refresh: Iterable[str] = ()) -> None:
        """Install a new desired state.

        A pool whose target changed -- or that is named in ``refresh``
        (webhook floors renew intent even when the numeric target is
        unchanged, e.g. an operator re-asserting a floor on a parked pool)
        -- gets its retry budget and backoff gate DISCARDED, not resumed:
        the backoff belonged to the superseded intent, and waiting it out
        would let a stale retry outrank the operator.  Any intent change
        bumps the desired-state ``generation``, which is stamped onto the
        planned steps and every audit record so the log can prove no step
        contradicted the latest desired state.
        """
        refresh = set(refresh)
        superseding = set()
        if self.desired is None:
            superseding = set(desired.targets) | refresh
        else:
            for name in desired.targets:
                if (desired.target_of(name) != self.desired.target_of(name)
                        or name in refresh):
                    superseding.add(name)
        if superseding:
            self.generation += 1
        desired = _dc_replace(desired, generation=self.generation)
        for name in superseding:
            self._pool_gen[name] = self.generation
            # new intent un-parks the pool and restarts its budget
            attempts = self._attempts.pop(name, None)
            gate = self._gate.pop(name, None)
            self._gate_gen.pop(name, None)
            stale = ((gate is not None and gate > now)
                     or (attempts is not None
                         and attempts > self.cfg.max_retries))
            if stale and self.audit is not None:
                # a live backoff / parked pool was superseded mid-retry
                self.audit.append(now, "superseded", pool=name,
                                  gen=self.generation,
                                  gate=gate if gate is not None else 0.0,
                                  attempts=attempts or 0)
        self.desired = desired
        if self.audit is not None and superseding:
            self.audit.append(now, "desired", reason=reason,
                              gen=self.generation,
                              targets={n: t.target
                                       for n, t in desired.targets.items()},
                              bounds={n: [t.min_units, t.max_units]
                                      for n, t in desired.targets.items()})

    # -- the loop ---------------------------------------------------------------
    def converge(self, now: float) -> list[StepOutcome]:
        if self.desired is None:
            return []
        prev_meters = self._last_meters
        self._audit_events(now)
        # a landing proves the build path works again: reset retry budgets
        for name in list(self._attempts):
            last = prev_meters.get(name)
            cur = self._last_meters.get(name)
            if last is not None and cur is not None and cur.landed > last.landed:
                self._attempts.pop(name, None)
                self._gate.pop(name, None)
                self._gate_gen.pop(name, None)
        # defense in depth: a gate armed under an older epoch than the pool's
        # latest intent change is stale and must not withhold launches --
        # set_desired discards these eagerly, so firing here means desired
        # state was mutated behind its back; still audited, never honored
        for name in list(self._gate):
            if self._gate_gen.get(name, 0) < self._pool_gen.get(name, 0):
                gate = self._gate.pop(name)
                self._gate_gen.pop(name, None)
                self._attempts.pop(name, None)
                if self.audit is not None:
                    self.audit.append(now, "superseded", pool=name,
                                      gen=self._pool_gen.get(name, 0),
                                      gate=gate, attempts=0)
        stats = self.plan.stats()
        overdue: dict[str, int] = {}
        for name in stats:
            od = self.plan.overdue_pending(name, now, self.cfg.build_timeout_s)
            if od > 0:
                overdue[name] = od
                self._note_failed_attempt(name, now)
        blocked = set()
        for name in stats:
            attempts = self._attempts.get(name, 0)
            if attempts > self.cfg.max_retries:
                blocked.add(name)
            elif now < self._gate.get(name, -1.0):
                blocked.add(name)
        replace_blocked = {name for name, until in self._replace_gate.items()
                           if now < until}
        steps = plan_steps(self.desired, stats, overdue=overdue,
                           launch_blocked=blocked,
                           replace_blocked=replace_blocked)
        if steps and self.audit is not None:
            # the planner's full inputs ride along so a replay can re-run the
            # pure planner and reproduce these exact steps (audit.verify_plan_replay)
            self.audit.append(now, "plan", gen=self.desired.generation,
                steps=[step_record(s) for s in steps],
                inputs={
                    "stats": {n: {"units": ps.units, "pending": ps.pending,
                                  "unhealthy": ps.unhealthy,
                                  "min_units": ps.min_units}
                              for n, ps in stats.items()},
                    "overdue": dict(overdue),
                    "launch_blocked": sorted(blocked),
                    "replace_blocked": sorted(replace_blocked)})
        return [self._execute(s, now) for s in steps]

    # -- internals --------------------------------------------------------------
    def _execute(self, step: Step, now: float) -> StepOutcome:
        queued = 0
        if isinstance(step, LaunchUnit):
            applied = self.executor.launch(step.pool, step.count, now)
        elif isinstance(step, CancelPending):
            applied = self.executor.cancel_pending(step.pool, step.count, now)
        elif isinstance(step, DrainUnit):
            applied = self.executor.drain(step.pool, step.count, now)
        elif isinstance(step, ReplaceUnhealthy):
            applied, queued = self.executor.replace_unhealthy(
                step.pool, step.count, now)
            self._replace_gate[step.pool] = now + self.cfg.replace_backoff_s
        else:  # pragma: no cover - the planner only emits the four kinds
            raise TypeError(f"unknown step {step!r}")
        out = StepOutcome(time=now, step=step, applied=applied, queued=queued)
        if self.audit is not None:
            rec = {"step": type(step).__name__, "pool": step.pool,
                   "asked": step.count, "applied": applied, "gen": step.gen}
            if isinstance(step, CancelPending):
                rec["reason"] = step.reason
            if isinstance(step, ReplaceUnhealthy):
                rec["queued"] = queued
            self.audit.append(now, "step", **rec)
        return out

    def _note_failed_attempt(self, name: str, now: float) -> None:
        attempts = self._attempts.get(name, 0) + 1
        self._attempts[name] = attempts
        if attempts > self.cfg.max_retries:
            if self.audit is not None:
                self.audit.append(now, "gave_up", pool=name, attempts=attempts)
            return
        delay = self.cfg.backoff_s(attempts)
        self._gate[name] = now + delay
        self._gate_gen[name] = self._pool_gen.get(name, 0)
        if self.audit is not None:
            self.audit.append(now, "backoff", pool=name, attempts=attempts,
                              until=now + delay)

    def _audit_events(self, now: float) -> None:
        meters = self.plan.meters()
        if self.audit is not None:
            for name, m in meters.items():
                last = self._last_meters.get(name)
                if last is None:
                    continue
                deltas = {
                    "landed": m.landed - last.landed,
                    "revoked": m.revoked - last.revoked,
                    "lost": m.lost - last.lost,
                    "overflow_landed": m.overflow_landed - last.overflow_landed,
                }
                if any(deltas.values()):
                    self.audit.append(now, "events", pool=name, **deltas)
        self._last_meters = meters


__all__ = ["Converger", "ConvergerConfig", "PlanExecutor", "StepExecutor",
           "StepOutcome"]
