"""Scaling-group configuration: validated pools + desired-state changes.

A *scaling group* names a set of :class:`UnitPool`\\ s plus two kinds of
declarative desired-state changes layered over whatever the policy asks for:

* **scheduled** floors -- "hold at least N units of pool P during [at, end)"
  (the paper's pre-provisioning idea, expressed as desired state rather than
  the delta-voting :class:`ScheduledPolicy`);
* **webhook** floors -- the same, but armed by an external event
  (``group.fire("breaking-news", now)``) and held for ``hold_s`` seconds.

Configs are plain dicts validated by a hand-rolled schema walker (no
dependency on a schema library): unknown keys, wrong types, and targets
naming undeclared pools all raise ``ValueError`` with the offending path,
e.g. ``pools[1].cost_rate: expected number, got str``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

from repro.core.scaling.capacity import UnitPool

from .desired import DesiredGroup, PoolTarget

_MISSING = object()


def _get(cfg: Mapping, key: str, types, path: str, *, default=_MISSING):
    """One schema-walker step: presence + type check with a path-qualified error."""
    if key not in cfg:
        if default is _MISSING:
            raise ValueError(f"{path}{key}: required key missing")
        return default
    val = cfg[key]
    if types is bool:
        ok = isinstance(val, bool)
    elif types is int:
        ok = isinstance(val, int) and not isinstance(val, bool)
    elif types is float:   # "number": int or float, but not bool
        ok = isinstance(val, (int, float)) and not isinstance(val, bool)
    else:
        ok = isinstance(val, types)
    if not ok:
        want = {bool: "bool", int: "int", float: "number",
                str: "str", dict: "dict", list: "list"}.get(types, str(types))
        raise ValueError(f"{path}{key}: expected {want}, "
                         f"got {type(val).__name__}")
    return val


def _no_unknown(cfg: Mapping, allowed: set, path: str) -> None:
    unknown = set(cfg) - allowed
    if unknown:
        raise ValueError(f"{path}: unknown key(s) {sorted(unknown)}; "
                         f"allowed: {sorted(allowed)}")


def _targets(cfg: Mapping, pool_names: set, path: str) -> dict[str, int]:
    raw = _get(cfg, "targets", dict, path)
    out = {}
    for pool, n in raw.items():
        if pool not in pool_names:
            raise ValueError(f"{path}targets: unknown pool {pool!r}; "
                             f"declared pools: {sorted(pool_names)}")
        if not isinstance(n, int) or isinstance(n, bool) or n < 0:
            raise ValueError(f"{path}targets[{pool!r}]: expected int >= 0, "
                             f"got {n!r}")
        out[pool] = n
    return out


_POOL_KEYS = {"name", "provision_delay_s", "cost_rate", "min_units",
              "max_units", "starting_units", "preemptible", "revoke_rate",
              "revoke_seed"}


def validate_group_config(cfg: Mapping) -> dict:
    """Validate a scaling-group config dict; returns a normalized copy.

    Schema::

        {"name": str,
         "pools": [{"name": str, "provision_delay_s"?: number,
                    "cost_rate"?: number, "min_units"?: int,
                    "max_units"?: int, "starting_units"?: int,
                    "preemptible"?: bool, "revoke_rate"?: number,
                    "revoke_seed"?: int}, ...],          # >= 1 pool
         "schedule"?: [{"at_s": number, "end_s": number,
                        "targets": {pool: int}}, ...],
         "webhooks"?: [{"name": str, "hold_s": number,
                        "targets": {pool: int}}, ...]}
    """
    if not isinstance(cfg, Mapping):
        raise ValueError(f"group config: expected dict, "
                         f"got {type(cfg).__name__}")
    _no_unknown(cfg, {"name", "pools", "schedule", "webhooks"}, "group config")
    name = _get(cfg, "name", str, "")
    if not name:
        raise ValueError("name: must be non-empty")
    raw_pools = _get(cfg, "pools", list, "")
    if not raw_pools:
        raise ValueError("pools: need at least one pool")
    pools = []
    for i, pc in enumerate(raw_pools):
        path = f"pools[{i}]."
        if not isinstance(pc, Mapping):
            raise ValueError(f"pools[{i}]: expected dict, "
                             f"got {type(pc).__name__}")
        _no_unknown(pc, _POOL_KEYS, f"pools[{i}]")
        pool = {"name": _get(pc, "name", str, path)}
        for key, typ in (("provision_delay_s", float), ("cost_rate", float),
                         ("min_units", int), ("max_units", int),
                         ("starting_units", int), ("preemptible", bool),
                         ("revoke_rate", float), ("revoke_seed", int)):
            val = _get(pc, key, typ, path, default=None)
            if val is not None:
                pool[key] = val
        pools.append(pool)
    pool_names = {p["name"] for p in pools}
    schedule = []
    for i, sc in enumerate(_get(cfg, "schedule", list, "", default=[])):
        path = f"schedule[{i}]."
        if not isinstance(sc, Mapping):
            raise ValueError(f"schedule[{i}]: expected dict, "
                             f"got {type(sc).__name__}")
        _no_unknown(sc, {"at_s", "end_s", "targets"}, f"schedule[{i}]")
        at = _get(sc, "at_s", float, path)
        end = _get(sc, "end_s", float, path)
        if end <= at:
            raise ValueError(f"{path}end_s: must be > at_s ({at}), got {end}")
        schedule.append({"at_s": float(at), "end_s": float(end),
                         "targets": _targets(sc, pool_names, path)})
    webhooks = []
    for i, wc in enumerate(_get(cfg, "webhooks", list, "", default=[])):
        path = f"webhooks[{i}]."
        if not isinstance(wc, Mapping):
            raise ValueError(f"webhooks[{i}]: expected dict, "
                             f"got {type(wc).__name__}")
        _no_unknown(wc, {"name", "hold_s", "targets"}, f"webhooks[{i}]")
        hold = _get(wc, "hold_s", float, path)
        if hold <= 0:
            raise ValueError(f"{path}hold_s: must be > 0, got {hold}")
        webhooks.append({"name": _get(wc, "name", str, path),
                         "hold_s": float(hold),
                         "targets": _targets(wc, pool_names, path)})
    wh_names = [w["name"] for w in webhooks]
    if len(set(wh_names)) != len(wh_names):
        raise ValueError(f"webhooks: duplicate names {wh_names}")
    return {"name": name, "pools": pools, "schedule": schedule,
            "webhooks": webhooks}


@dataclass(frozen=True)
class ScheduledChange:
    at_s: float
    end_s: float
    targets: Mapping[str, int]

    def floors_at(self, now: float) -> Mapping[str, int]:
        return self.targets if self.at_s <= now < self.end_s else {}


@dataclass(frozen=True)
class WebhookTrigger:
    name: str
    hold_s: float
    targets: Mapping[str, int]


@dataclass
class ScalingGroup:
    """Validated pools + scheduled/webhook desired-state floors."""

    name: str
    pools: tuple[UnitPool, ...]
    schedule: tuple[ScheduledChange, ...] = ()
    webhooks: tuple[WebhookTrigger, ...] = ()
    _fired: list[tuple[float, WebhookTrigger]] = field(default_factory=list)

    @classmethod
    def from_config(cls, cfg: Mapping) -> "ScalingGroup":
        norm = validate_group_config(cfg)
        return cls(
            name=norm["name"],
            pools=tuple(UnitPool(**pc) for pc in norm["pools"]),
            schedule=tuple(ScheduledChange(at_s=sc["at_s"], end_s=sc["end_s"],
                                           targets=sc["targets"])
                           for sc in norm["schedule"]),
            webhooks=tuple(WebhookTrigger(name=wc["name"], hold_s=wc["hold_s"],
                                          targets=wc["targets"])
                           for wc in norm["webhooks"]),
        )

    def reset(self) -> None:
        self._fired.clear()

    def fire(self, name: str, now: float) -> WebhookTrigger:
        """Arm webhook ``name`` at ``now``; its floors hold for ``hold_s``."""
        for trig in self.webhooks:
            if trig.name == name:
                self._fired.append((float(now), trig))
                return trig
        raise ValueError(f"unknown webhook {name!r}; declared: "
                         f"{[t.name for t in self.webhooks]}")

    def floors_at(self, now: float) -> dict[str, int]:
        """Active per-pool floors from the schedule and armed webhooks."""
        floors: dict[str, int] = {}
        for sc in self.schedule:
            for pool, n in sc.floors_at(now).items():
                floors[pool] = max(floors.get(pool, 0), n)
        for t0, trig in self._fired:
            if t0 <= now < t0 + trig.hold_s:
                for pool, n in trig.targets.items():
                    floors[pool] = max(floors.get(pool, 0), n)
        return floors

    def overlay(self, desired: DesiredGroup, now: float) -> DesiredGroup:
        """Raise desired targets to any active floors (ceiling-clamped)."""
        floors = self.floors_at(now)
        if not floors:
            return desired
        targets = dict(desired.targets)
        for pool, floor in floors.items():
            cur = targets.get(pool)
            if cur is None:
                continue
            raised = min(max(cur.target, floor), cur.max_units)
            if raised != cur.target:
                targets[pool] = PoolTarget(target=raised,
                                           min_units=cur.min_units,
                                           max_units=cur.max_units)
        return DesiredGroup(targets, generation=desired.generation)

    def as_policy(self, lead_s: float = 0.0):
        """Imperative-mode fallback: the group's schedule and webhooks as a
        delta-voting policy (reuses :class:`ScheduledPolicy` semantics)."""
        from repro.core.autoscaler.policies import WebhookPolicy
        total_sched = tuple(
            (sc.at_s - lead_s, sc.end_s, sum(sc.targets.values()))
            for sc in self.schedule)
        pol = WebhookPolicy(
            triggers={t.name: (sum(t.targets.values()), t.hold_s)
                      for t in self.webhooks},
            schedule=total_sched)
        return pol


__all__ = ["ScalingGroup", "ScheduledChange", "WebhookTrigger",
           "validate_group_config"]
