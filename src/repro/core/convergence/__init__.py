"""Convergence control plane: desired-state reconciliation for the fleet.

The imperative :class:`~repro.core.scaling.ScalingController` actuates policy
deltas directly and assumes every provisioning action succeeds.  This package
adds the production-style alternative (``ControllerConfig(convergence=True)``):
policies still vote deltas, but a thin adapter folds them into a *desired
state* (:mod:`.desired`), a pure planner diffs desired vs observed capacity
into typed steps (:mod:`.planner`), and a converger loop executes the steps
with build timeouts, bounded retries and exponential backoff (:mod:`.converger`)
-- healing capacity lost to the seeded fault processes in :mod:`.faults`.
Every observation, plan, step, and outcome lands in an append-only JSONL audit
log (:mod:`.audit`) that tests replay back to the exact final plan state.
:mod:`.groups` adds dict-schema-validated scaling-group configs with scheduled
and webhook-triggered desired-state changes.

With no faults injected, a converged fleet plans zero steps and the whole
plane is bit-for-bit equivalent to the imperative path (pinned by parity
tests against the simulator goldens).
"""
from .audit import AuditIntegrityError, AuditLog, replay, verify_plan_replay
from .converger import (
    Converger, ConvergerConfig, PlanExecutor, StepExecutor, StepOutcome,
)
from .desired import DesiredGroup, PoolTarget, derive_desired, observed_group
from .faults import FaultInjector, FaultSpec, ScriptedFault, ScriptedFaults
from .groups import (
    ScalingGroup, ScheduledChange, WebhookTrigger, validate_group_config,
)
from .planner import (
    CancelPending, DrainUnit, LaunchUnit, ReplaceUnhealthy, Step, plan_steps,
    step_record,
)

__all__ = [
    "AuditIntegrityError",
    "AuditLog",
    "CancelPending",
    "Converger",
    "ConvergerConfig",
    "DesiredGroup",
    "DrainUnit",
    "FaultInjector",
    "FaultSpec",
    "LaunchUnit",
    "PlanExecutor",
    "PoolTarget",
    "ReplaceUnhealthy",
    "StepExecutor",
    "ScalingGroup",
    "ScheduledChange",
    "ScriptedFault",
    "ScriptedFaults",
    "Step",
    "StepOutcome",
    "WebhookTrigger",
    "derive_desired",
    "observed_group",
    "plan_steps",
    "replay",
    "step_record",
    "validate_group_config",
    "verify_plan_replay",
]
