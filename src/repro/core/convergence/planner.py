"""The pure planner: diff desired vs observed capacity into typed steps.

``plan_steps`` is a pure function of (desired, observed) -- no clocks, no
randomness, no plan mutation -- so it is trivially testable and *idempotent*:
on a converged fleet it returns ``[]``.  The converger executes whatever it
emits and simply re-plans on the next tick, which is what makes partial
failures safe: an under-applied step just shows up as remaining diff.

Step ordering within one plan: cancellations of stuck builds first (they
free ceiling headroom), then replacements of unhealthy units, then scale-down
steps, then launches (which can use the headroom the earlier steps freed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Union

from repro.core.scaling.capacity import PoolStats

from .desired import DesiredGroup


@dataclass(frozen=True)
class LaunchUnit:
    """Queue ``count`` new builds of ``pool``.  ``gen`` is the desired-state
    generation the step serves (0 = ungenerationed, pre-epoch callers)."""

    pool: str
    count: int
    gen: int = 0


@dataclass(frozen=True)
class CancelPending:
    """Cancel ``count`` pending builds of ``pool`` (``reason``: surplus or
    stuck)."""

    pool: str
    count: int
    reason: str = "surplus"
    gen: int = 0


@dataclass(frozen=True)
class DrainUnit:
    """Voluntarily drain ``count`` live units of ``pool`` (floor-respecting)."""

    pool: str
    count: int
    gen: int = 0


@dataclass(frozen=True)
class ReplaceUnhealthy:
    """Tear down ``count`` unhealthy units of ``pool`` and queue replacements."""

    pool: str
    count: int
    gen: int = 0


Step = Union[LaunchUnit, CancelPending, DrainUnit, ReplaceUnhealthy]


def step_record(s: Step) -> dict:
    """Canonical audit-record form of one step (what ``plan`` records carry
    and what the replay verifier recomputes -- one serializer, no drift)."""
    rec = {"step": type(s).__name__, "pool": s.pool, "count": s.count}
    if isinstance(s, CancelPending):
        rec["reason"] = s.reason
    return rec


def plan_steps(desired: DesiredGroup,
               stats: Mapping[str, PoolStats],
               *,
               overdue: Mapping[str, int] | None = None,
               launch_blocked: frozenset | set = frozenset(),
               replace_blocked: frozenset | set = frozenset()) -> list[Step]:
    """Diff ``desired`` against observed ``stats`` and emit convergence steps.

    ``overdue`` carries per-pool counts of builds considered stuck (expected
    landing more than the build timeout ago); they are cancelled and their
    replacement launch re-planned, subject to ``launch_blocked`` (pools in
    retry backoff or given up).  ``replace_blocked`` damps health-flap thrash.
    """
    overdue = overdue or {}
    gen = desired.generation
    stuck_cancels: list[Step] = []
    replaces: list[Step] = []
    downs: list[Step] = []
    ups: list[Step] = []
    for name, ps in stats.items():
        od = min(overdue.get(name, 0), ps.pending)
        if od > 0:
            stuck_cancels.append(CancelPending(name, od, reason="stuck",
                                               gen=gen))
        if ps.unhealthy > 0 and name not in replace_blocked:
            replaces.append(ReplaceUnhealthy(name, ps.unhealthy, gen=gen))
        have = ps.units + ps.pending - od
        target = desired.target_of(name) if name in desired.targets else have
        if have > target:
            surplus = have - target
            cancel = min(ps.pending - od, surplus)
            if cancel > 0:
                downs.append(CancelPending(name, cancel, gen=gen))
                surplus -= cancel
            drainable = min(surplus, max(ps.units - ps.min_units, 0))
            if drainable > 0:
                downs.append(DrainUnit(name, drainable, gen=gen))
        elif have < target and name not in launch_blocked:
            ups.append(LaunchUnit(name, target - have, gen=gen))
    return stuck_cancels + replaces + downs + ups


__all__ = ["CancelPending", "DrainUnit", "LaunchUnit", "ReplaceUnhealthy",
           "Step", "plan_steps", "step_record"]
