"""Seeded fault-injection processes threaded through the capacity plane.

Three fault kinds, matching the failure taxonomy the ROADMAP's convergence
item names (the scenarios an imperative delta controller cannot express):

* **unit loss** -- live units vanish abruptly (hardware failure, AZ event):
  each live unit is lost within a step with probability
  ``1 - exp(-loss_rate * step_s)``.
* **stuck builds** -- a queued allocation never lands (hung image build,
  exhausted capacity pool behind the API): each unit of a request sticks
  with probability ``stuck_p``.  Stuck builds occupy pending capacity -- and
  ceiling headroom -- until something cancels them, which is exactly what
  clogs the imperative baseline.
* **flapping health** -- live units oscillate between healthy and unhealthy
  with hazards ``flap_rate`` / ``heal_rate``.
* **provisioning brownouts** -- builds land, but ``brownout_factor`` times
  later than promised (degraded control plane, capacity crunch behind the
  API).  Unlike stuck builds these eventually arrive; the converger sees
  them as overdue-but-alive and must decide between waiting and relaunching.
* **correlated loss** -- one AZ-scale event takes a ``corr_loss_frac``
  fraction of EVERY affected pool's live units in the same step (probability
  ``corr_loss_p`` per step while the window is active).  Independent
  per-unit hazards can never produce this covariance, which is what makes
  it the interesting recovery drill.

Each :class:`FaultSpec` is windowed (``start_s``..``end_s``) and carries its
own seed; the injector keeps one RNG stream per (spec, fault-kind) so the
unit-loss process a run experiences does not depend on how many requests the
controller happened to issue.  ``CapacityPlan`` holds the injector behind a
duck-typed attach point (``stuck_builds`` / ``step_draws`` / ``reset``), so
the scaling package never imports this module.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class FaultSpec:
    """One windowed, seeded fault process; ``pool=None`` hits every pool."""

    pool: str | None = None
    loss_rate: float = 0.0       # per-unit hazard of abrupt unit loss, 1/s
    stuck_p: float = 0.0         # probability a queued build never lands
    flap_rate: float = 0.0       # per-unit hazard healthy -> unhealthy, 1/s
    heal_rate: float = 0.0       # per-unit hazard unhealthy -> healthy, 1/s
    brownout_factor: float = 1.0  # provisioning-delay inflation (1.0 = none)
    corr_loss_p: float = 0.0     # per-step probability of an AZ-scale event
    corr_loss_frac: float = 1.0  # fraction of live units the event takes
    start_s: float = 0.0
    end_s: float = math.inf
    seed: int = 0

    def __post_init__(self):
        for f in ("loss_rate", "flap_rate", "heal_rate"):
            if getattr(self, f) < 0.0:
                raise ValueError(f"{f} must be >= 0, got {getattr(self, f)}")
        if not 0.0 <= self.stuck_p <= 1.0:
            raise ValueError(f"stuck_p must be in [0, 1], got {self.stuck_p}")
        if self.brownout_factor < 1.0:
            raise ValueError(f"brownout_factor must be >= 1, got "
                             f"{self.brownout_factor}")
        if not 0.0 <= self.corr_loss_p <= 1.0:
            raise ValueError(f"corr_loss_p must be in [0, 1], got "
                             f"{self.corr_loss_p}")
        if not 0.0 < self.corr_loss_frac <= 1.0:
            raise ValueError(f"corr_loss_frac must be in (0, 1], got "
                             f"{self.corr_loss_frac}")
        if self.end_s < self.start_s:
            raise ValueError(f"end_s {self.end_s} < start_s {self.start_s}")

    def active(self, pool: str, now: float) -> bool:
        return ((self.pool is None or self.pool == pool)
                and self.start_s <= now < self.end_s)


class FaultInjector:
    """Seeded draws for a set of :class:`FaultSpec` processes.

    Deterministic given the specs' seeds and the sequence of calls; streams
    are split per fault kind so loss draws stay aligned between runs whose
    request patterns differ (e.g. imperative vs convergence mode).
    """

    def __init__(self, specs):
        self.specs = tuple(specs)
        self._rngs: list[dict[str, np.random.Generator]] = []
        self._corr_cache: dict[tuple[int, float], bool] = {}
        self.reset()

    def reset(self) -> None:
        # "corr" is appended so the (seed, index) streams of the original
        # kinds stay bit-identical to pre-brownout injectors
        self._rngs = [
            {kind: np.random.default_rng((spec.seed, i))
             for i, kind in enumerate(("loss", "stuck", "flap", "heal",
                                       "corr"))}
            for spec in self.specs
        ]
        self._corr_cache = {}

    def stuck_builds(self, pool: str, count: int, now: float) -> int:
        """How many of ``count`` units just queued for ``pool`` will stick."""
        stuck = 0
        for spec, rngs in zip(self.specs, self._rngs):
            if spec.stuck_p > 0.0 and spec.active(pool, now):
                stuck += int(rngs["stuck"].binomial(count - stuck, spec.stuck_p))
                if stuck >= count:
                    return count
        return stuck

    def step_draws(self, pool: str, live: int, unhealthy: int, now: float,
                   step_s: float) -> tuple[int, int, int]:
        """Per-step fault draws for ``pool``: (lost, flapped, healed)."""
        lost = flapped = healed = 0
        for spec, rngs in zip(self.specs, self._rngs):
            if not spec.active(pool, now):
                continue
            if spec.loss_rate > 0.0 and live - lost > 0:
                p = -math.expm1(-spec.loss_rate * step_s)
                lost += int(rngs["loss"].binomial(live - lost, p))
            healthy = max(live - lost - unhealthy, 0)
            if spec.flap_rate > 0.0 and healthy - flapped > 0:
                p = -math.expm1(-spec.flap_rate * step_s)
                flapped += int(rngs["flap"].binomial(healthy - flapped, p))
            if spec.heal_rate > 0.0 and unhealthy - healed > 0:
                p = -math.expm1(-spec.heal_rate * step_s)
                healed += int(rngs["heal"].binomial(unhealthy - healed, p))
        return lost, flapped, healed

    def delay_factor(self, pool: str, now: float) -> float:
        """Provisioning-delay inflation for a build queued on ``pool`` now
        (product of all active brownout windows; 1.0 = healthy)."""
        factor = 1.0
        for spec in self.specs:
            if spec.brownout_factor > 1.0 and spec.active(pool, now):
                factor *= spec.brownout_factor
        return factor

    def corr_loss(self, pool: str, live: int, now: float,
                  step_s: float) -> int:
        """Units of ``pool`` taken by correlated AZ-scale events this step.

        Whether an event fires is drawn ONCE per (spec, step) and cached, so
        every pool a spec covers is hit in the same step -- that shared draw
        is the correlation.  ``step_s`` is accepted for signature symmetry
        with :meth:`step_draws`; the event probability is per step.
        """
        del step_s
        lost = 0
        for i, (spec, rngs) in enumerate(zip(self.specs, self._rngs)):
            if spec.corr_loss_p <= 0.0 or not spec.active(pool, now):
                continue
            key = (i, float(now))
            fired = self._corr_cache.get(key)
            if fired is None:
                fired = bool(rngs["corr"].random() < spec.corr_loss_p)
                self._corr_cache[key] = fired
            if fired:
                lost += math.ceil(spec.corr_loss_frac * max(live - lost, 0))
        return min(lost, live)


_SCRIPT_KINDS = ("lose", "corr_lose", "flap", "heal", "stick", "brownout")


@dataclass(frozen=True)
class ScriptedFault:
    """One deterministic fault occurrence on a chaos-drill timeline.

    Point events (``lose`` / ``corr_lose`` / ``flap`` / ``heal``) fire in the
    step containing ``at_s``; window events (``stick`` / ``brownout``) are
    active over ``[at_s, until_s)``.  ``corr_lose`` takes ``frac`` of every
    matching pool's live units in the SAME step -- the correlation is the
    shared timeline, no draw needed.  ``pool=None`` hits every pool.
    """

    at_s: float
    kind: str
    pool: str | None = None
    count: int = 1               # units for lose / flap / heal
    frac: float = 1.0            # fraction for corr_lose
    until_s: float = math.inf    # window end for stick / brownout
    factor: float = 2.0          # delay inflation for brownout

    def __post_init__(self):
        if self.kind not in _SCRIPT_KINDS:
            raise ValueError(f"kind must be one of {_SCRIPT_KINDS}, "
                             f"got {self.kind!r}")
        if self.at_s < 0.0:
            raise ValueError(f"at_s must be >= 0, got {self.at_s}")
        if self.count < 1:
            raise ValueError(f"count must be >= 1, got {self.count}")
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {self.frac}")
        if self.kind in ("stick", "brownout") and self.until_s <= self.at_s:
            raise ValueError(f"until_s {self.until_s} must be > at_s "
                             f"{self.at_s} for {self.kind!r} windows")
        if self.kind == "brownout" and self.factor <= 1.0:
            raise ValueError(f"brownout factor must be > 1, got {self.factor}")

    def hits(self, pool: str) -> bool:
        return self.pool is None or self.pool == pool

    def fires(self, pool: str, now: float, step_s: float) -> bool:
        """Point event lands in the step ``[now, now + step_s)``?"""
        return self.hits(pool) and now <= self.at_s < now + step_s

    def window_active(self, pool: str, now: float) -> bool:
        return self.hits(pool) and self.at_s <= now < self.until_s


class ScriptedFaults:
    """Script-driven injector: the same duck-typed attach point as
    :class:`FaultInjector` (``stuck_builds`` / ``step_draws`` /
    ``delay_factor`` / ``corr_loss`` / ``reset``) but with EXACT timed
    events instead of seeded hazards, so a chaos drill replays identically
    -- same faults at the same virtual times -- on every run.  Stateless:
    every answer is a pure function of (pool, time), which is what makes
    same-seed audit logs byte-identical."""

    def __init__(self, events):
        self.events = tuple(events)
        for ev in self.events:
            if not isinstance(ev, ScriptedFault):
                raise TypeError(f"expected ScriptedFault, got {ev!r}")

    def reset(self) -> None:
        """Nothing to rewind: the timeline is immutable."""

    def stuck_builds(self, pool: str, count: int, now: float) -> int:
        for ev in self.events:
            if ev.kind == "stick" and ev.window_active(pool, now):
                return int(count)
        return 0

    def step_draws(self, pool: str, live: int, unhealthy: int, now: float,
                   step_s: float) -> tuple[int, int, int]:
        lost = flapped = healed = 0
        for ev in self.events:
            if not ev.fires(pool, now, step_s):
                continue
            if ev.kind == "lose":
                lost += ev.count
            elif ev.kind == "flap":
                flapped += ev.count
            elif ev.kind == "heal":
                healed += ev.count
        lost = min(lost, live)
        flapped = min(flapped, max(live - lost - unhealthy, 0))
        healed = min(healed, unhealthy)
        return lost, flapped, healed

    def delay_factor(self, pool: str, now: float) -> float:
        factor = 1.0
        for ev in self.events:
            if ev.kind == "brownout" and ev.window_active(pool, now):
                factor *= ev.factor
        return factor

    def corr_loss(self, pool: str, live: int, now: float,
                  step_s: float) -> int:
        lost = 0
        for ev in self.events:
            if ev.kind == "corr_lose" and ev.fires(pool, now, step_s):
                lost += math.ceil(ev.frac * max(live - lost, 0))
        return min(lost, live)


__all__ = ["FaultInjector", "FaultSpec", "ScriptedFault", "ScriptedFaults"]
